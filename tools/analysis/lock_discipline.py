"""Lock-discipline pass: every access to state declared shared must be
lexically under the lock that guards it.

Classes declare their locking contract with the zero-cost
``@guarded_by(lock, *attrs, holds=(...))`` decorator
(``repro.runtime.guards``): *attrs* name the instance attributes the
*lock* protects, and *holds* names private methods whose CALLERS must
hold the lock (the method itself may then touch guarded state freely).

Codes:

* **LOCK001** — a method reads or writes a guarded attribute outside a
  ``with self.<lock>:`` block (``__init__`` and friends are exempt —
  the object is not yet shared during construction).
* **LOCK002** — a method calls a ``holds=`` method without holding the
  lock it assumes.

The check is LEXICAL: a ``with self._lock:`` anywhere up the statement
tree satisfies it, including closures/lambdas defined inside the block
(they execute there in this codebase's patterns — e.g.
``Condition.wait_for`` predicates).  That makes the pass conservative
in the right direction: lock acquisition through aliases or helper
indirection is reported, and the fix is to make the locking visible.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

SCOPE = (
    "src/repro/serving",
    "src/repro/sched",
    "src/repro/store",
    "src/repro/runtime",
)

_CTOR_EXEMPT = {"__init__", "__post_init__", "__new__", "__del__"}


def _parse_guarded_by(cls: ast.ClassDef):
    """(attr -> lock, lock -> set of holds-methods) from stacked
    ``@guarded_by`` decorators; ``None`` when the class has none."""
    attr_to_lock: dict[str, str] = {}
    holds: dict[str, set[str]] = {}
    found = False
    for dec in cls.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        name = dec.func
        dotted = (
            name.id if isinstance(name, ast.Name)
            else name.attr if isinstance(name, ast.Attribute)
            else ""
        )
        if dotted != "guarded_by":
            continue
        found = True
        consts = [
            a.value for a in dec.args
            if isinstance(a, ast.Constant) and isinstance(a.value, str)
        ]
        if not consts:
            continue
        lock, attrs = consts[0], consts[1:]
        for a in attrs:
            attr_to_lock[a] = lock
        holds.setdefault(lock, set())
        for kw in dec.keywords:
            if kw.arg == "holds" and isinstance(
                kw.value, (ast.Tuple, ast.List)
            ):
                for el in kw.value.elts:
                    if (
                        isinstance(el, ast.Constant)
                        and isinstance(el.value, str)
                    ):
                        holds[lock].add(el.value)
    return (attr_to_lock, holds) if found else None


class _MethodChecker:
    """Lexical with-lock tracking over one method body."""

    def __init__(
        self,
        relpath: str,
        clsname: str,
        method: ast.FunctionDef,
        self_name: str,
        attr_to_lock: dict[str, str],
        holds: dict[str, set[str]],
        assumed: frozenset,
        findings: list[Finding],
    ) -> None:
        self.relpath = relpath
        self.scope = f"{clsname}.{method.name}"
        self.self_name = self_name
        self.attr_to_lock = attr_to_lock
        self.holds = holds
        self.findings = findings
        self.method = method
        self.assumed = assumed

    def check(self) -> None:
        for stmt in self.method.body:
            self._visit(stmt, self.assumed)

    def _locks_in_with(self, node) -> frozenset:
        got = set()
        for item in node.items:
            ctx = item.context_expr
            if (
                isinstance(ctx, ast.Attribute)
                and isinstance(ctx.value, ast.Name)
                and ctx.value.id == self.self_name
                and ctx.attr in self.holds
            ):
                got.add(ctx.attr)
        return frozenset(got)

    def _visit(self, node: ast.AST, held: frozenset) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                self._visit(item.context_expr, held)
            inner = held | self._locks_in_with(node)
            for stmt in node.body:
                self._visit(stmt, inner)
            return
        if isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == self.self_name
            ):
                for lock, methods in self.holds.items():
                    if f.attr in methods and lock not in held:
                        self.findings.append(Finding(
                            code="LOCK002",
                            path=self.relpath,
                            line=node.lineno,
                            scope=self.scope,
                            subject=f.attr,
                            message=(
                                f"call to {f.attr}() requires holding "
                                f"self.{lock} (declared via "
                                f"guarded_by holds=) but no enclosing "
                                f"'with self.{lock}:' is visible"
                            ),
                        ))
                # fall through: also check args below
        if isinstance(node, ast.Attribute):
            if (
                isinstance(node.value, ast.Name)
                and node.value.id == self.self_name
            ):
                lock = self.attr_to_lock.get(node.attr)
                if lock is not None and lock not in held:
                    self.findings.append(Finding(
                        code="LOCK001",
                        path=self.relpath,
                        line=node.lineno,
                        scope=self.scope,
                        subject=node.attr,
                        message=(
                            f"access to self.{node.attr} (guarded by "
                            f"self.{lock}) outside a "
                            f"'with self.{lock}:' block"
                        ),
                    ))
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)


def _check_class(
    relpath: str, cls: ast.ClassDef, findings: list[Finding]
) -> None:
    parsed = _parse_guarded_by(cls)
    if parsed is None:
        return
    attr_to_lock, holds = parsed
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if item.name in _CTOR_EXEMPT:
            continue
        if not item.args.args:
            continue  # staticmethod with no receiver — nothing to track
        self_name = item.args.args[0].arg
        assumed = frozenset(
            lock for lock, methods in holds.items()
            if item.name in methods
        )
        _MethodChecker(
            relpath, cls.name, item, self_name,
            attr_to_lock, holds, assumed, findings,
        ).check()


def run_pass(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for sub in SCOPE:
        for path in sorted((root / sub).glob("*.py")):
            relpath = str(path.relative_to(root))
            tree = ast.parse(path.read_text(), filename=str(path))
            for node in tree.body:
                if isinstance(node, ast.ClassDef):
                    _check_class(relpath, node, findings)
    return findings

"""Finding records and baseline management for repro-lint.

A ``Finding`` is one violation emitted by an analysis pass.  Its
``fingerprint`` deliberately excludes the line number: baselined
findings must survive unrelated edits that shift code up or down, so
the identity is (code, file, enclosing symbol, subject) — the subject
being a pass-chosen stable token such as the attribute name, frame
tag, or offending call text.

The baseline file (``tools/analysis/baseline.json``) maps accepted
fingerprints to a one-line justification.  ``repro_lint --baseline``
fails only on findings NOT in the baseline, which is how the linter
gates CI from day one without requiring the whole history to be clean
first.  (This repo's baseline ships empty: every pre-existing true
positive was fixed rather than baselined.)
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path


@dataclass(frozen=True)
class Finding:
    """One static-analysis violation.

    ``scope`` is the enclosing ``Class.method`` / function qualname (or
    ``"<module>"``); ``subject`` is the pass-specific stable identity of
    the violating object (attribute name, frame tag, call text, ...).
    """

    code: str
    path: str
    line: int
    scope: str
    subject: str
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.code}:{self.path}:{self.scope}:{self.subject}"

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        scope = f" [{self.scope}]" if self.scope else ""
        return f"{self.code} {where}{scope}: {self.message}"


@dataclass
class Baseline:
    """Accepted-findings ledger: fingerprint -> justification."""

    path: Path
    accepted: dict[str, str] = field(default_factory=dict)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls(path=path)
        data = json.loads(path.read_text())
        if not isinstance(data, dict) or "accepted" not in data:
            raise ValueError(f"malformed baseline file: {path}")
        accepted = data["accepted"]
        if not isinstance(accepted, dict):
            raise ValueError(
                f"baseline 'accepted' must map fingerprint -> reason: {path}"
            )
        return cls(path=path, accepted=dict(accepted))

    def save(self) -> None:
        payload = {
            "version": 1,
            "accepted": dict(sorted(self.accepted.items())),
        }
        self.path.write_text(json.dumps(payload, indent=2) + "\n")

    def filter_new(self, findings: list[Finding]) -> list[Finding]:
        """The findings not covered by this baseline (i.e. the ones that
        should fail the build)."""
        return [f for f in findings if f.fingerprint not in self.accepted]

    def stale_entries(self, findings: list[Finding]) -> list[str]:
        """Baselined fingerprints that no longer fire — candidates for
        removal so the baseline only ever shrinks."""
        live = {f.fingerprint for f in findings}
        return [fp for fp in self.accepted if fp not in live]

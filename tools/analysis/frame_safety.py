"""Frame-safety pass: byte-format reads must be bounds-clamped, frame
writers must seal, and writer/reader pairs must be field-symmetric.

Codes:

* **FRAME001** — ``struct.unpack(fmt, stream.read(n))``: a short read
  surfaces as ``struct.error`` instead of a typed
  ``TruncatedFrameError``.  Use ``core.framing.read_struct`` (which
  clamps via ``_read_exact``).
* **FRAME002** — ``assert`` on a ``.read()`` result: framing checks
  must raise typed errors, not ``AssertionError`` (and asserts vanish
  under ``-O``).  Use ``expect_magic`` / ``_check_length``.
* **FRAME003** — a registered frame writer does not seal its output
  with ``with_crc`` (docs/format.md §8).
* **FRAME004** — a registered writer's wire shape diverges from the
  declared schema (or contains divergent ``if`` arms / untyped raw
  writes).
* **FRAME005** — a registered reader's wire shape diverges from the
  declared schema, or skips ``check_crc``/``expect_magic``.
* **FRAME006** — ``open(path, "wb")`` in serialization scope outside
  ``core/framing.py``: frame writes must go through
  ``atomic_write_bytes`` (temp + fsync + rename) so a crash cannot
  leave a torn frame at the final path.

Scope: ``src/repro/core`` and ``src/repro/store`` (the layers that own
byte formats).  Direct ``open()`` READ handles with explicit length
checks are fine — only the unpack-on-read nesting and writer-side
atomicity are patterns, not every ``.read`` call.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding
from .frame_schema import (
    REGISTRY,
    ModuleIndex,
    extract_shape,
    render_shape,
)

SCOPE = ("src/repro/core", "src/repro/store")


def _scope_files(root: Path) -> list[Path]:
    out: list[Path] = []
    for sub in SCOPE:
        out.extend(sorted((root / sub).glob("*.py")))
    return out


class _ScopeVisitor(ast.NodeVisitor):
    """FRAME001/002/006 over one module."""

    def __init__(self, relpath: str, findings: list[Finding]) -> None:
        self.relpath = relpath
        self.findings = findings
        self._scope: list[str] = []

    @property
    def scope(self) -> str:
        return ".".join(self._scope) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # -- FRAME001 -------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        if _is_unpack(node.func):
            for arg in node.args:
                if _is_read_call(arg):
                    self.findings.append(Finding(
                        code="FRAME001",
                        path=self.relpath,
                        line=node.lineno,
                        scope=self.scope,
                        subject="struct.unpack-on-read",
                        message=(
                            "bare struct.unpack on a stream read — a "
                            "short read raises struct.error, not a "
                            "typed TruncatedFrameError; use "
                            "core.framing.read_struct"
                        ),
                    ))
        if _is_wb_open(node) and not self.relpath.endswith(
            "core/framing.py"
        ):
            self.findings.append(Finding(
                code="FRAME006",
                path=self.relpath,
                line=node.lineno,
                scope=self.scope,
                subject="open-wb",
                message=(
                    "raw open(..., 'wb') in serialization scope — a "
                    "crash mid-write leaves a torn frame at the final "
                    "path; use core.framing.atomic_write_bytes"
                ),
            ))
        self.generic_visit(node)

    # -- FRAME002 -------------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Call) and _is_read_call(sub):
                self.findings.append(Finding(
                    code="FRAME002",
                    path=self.relpath,
                    line=node.lineno,
                    scope=self.scope,
                    subject="assert-on-read",
                    message=(
                        "assert on a stream read — framing checks "
                        "must raise typed FramingError subclasses "
                        "(asserts vanish under -O); use expect_magic "
                        "/ _check_length"
                    ),
                ))
                break
        self.generic_visit(node)


def _is_unpack(func: ast.expr) -> bool:
    if isinstance(func, ast.Attribute) and func.attr in (
        "unpack", "unpack_from"
    ):
        return isinstance(func.value, ast.Name) and func.value.id == "struct"
    if isinstance(func, ast.Name) and func.id in ("unpack", "unpack_from"):
        return True
    return False


def _is_read_call(node: ast.expr) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "read"
    )


def _is_wb_open(node: ast.Call) -> bool:
    if not (isinstance(node.func, ast.Name) and node.func.id == "open"):
        return False
    mode = None
    if len(node.args) > 1:
        mode = node.args[1]
    for kw in node.keywords:
        if kw.arg == "mode":
            mode = kw.value
    return (
        isinstance(mode, ast.Constant)
        and isinstance(mode.value, str)
        and "w" in mode.value
        and "b" in mode.value
    )


def _check_registry(root: Path, findings: list[Finding]) -> None:
    """FRAME003/004/005 for every registered frame."""
    for spec in REGISTRY:
        path = root / spec.module
        if not path.exists():
            # analyzing a partial tree (test fixtures); the registry
            # only constrains modules that are present
            continue
        try:
            index = ModuleIndex.parse(path)
            w = extract_shape(index, spec.writer)
            r = extract_shape(index, spec.reader)
        except (LookupError, OSError, SyntaxError) as e:
            findings.append(Finding(
                code="FRAME004",
                path=spec.module,
                line=1,
                scope=spec.writer,
                subject=f"{spec.tag}-missing",
                message=f"cannot analyze {spec.tag} frame pair: {e}",
            ))
            continue
        wfn = index.resolve(spec.writer)
        rfn = index.resolve(spec.reader)
        if spec.sealed and not w.calls_with_crc:
            findings.append(Finding(
                code="FRAME003",
                path=spec.module,
                line=wfn.lineno,
                scope=spec.writer,
                subject=f"{spec.tag}-unsealed",
                message=(
                    f"{spec.tag} writer does not seal with with_crc "
                    "(docs/format.md §8 requires a CRC1 trailer on "
                    "every top-level frame)"
                ),
            ))
        if w.shape != spec.schema:
            findings.append(Finding(
                code="FRAME004",
                path=spec.module,
                line=wfn.lineno,
                scope=spec.writer,
                subject=f"{spec.tag}-writer-shape",
                message=(
                    f"{spec.tag} writer diverges from the declared "
                    f"schema;\n    declared: "
                    f"{render_shape(spec.schema)}\n    written:  "
                    f"{render_shape(w.shape)}"
                ),
            ))
        if spec.sealed and not r.calls_check_crc:
            findings.append(Finding(
                code="FRAME005",
                path=spec.module,
                line=rfn.lineno,
                scope=spec.reader,
                subject=f"{spec.tag}-no-crc-check",
                message=(
                    f"{spec.tag} reader does not verify the CRC1 "
                    "trailer via check_crc"
                ),
            ))
        if not r.has_magic:
            findings.append(Finding(
                code="FRAME005",
                path=spec.module,
                line=rfn.lineno,
                scope=spec.reader,
                subject=f"{spec.tag}-no-magic",
                message=(
                    f"{spec.tag} reader does not validate the magic "
                    "via expect_magic"
                ),
            ))
        if r.shape != spec.schema:
            findings.append(Finding(
                code="FRAME005",
                path=spec.module,
                line=rfn.lineno,
                scope=spec.reader,
                subject=f"{spec.tag}-reader-shape",
                message=(
                    f"{spec.tag} reader diverges from the declared "
                    f"schema;\n    declared: "
                    f"{render_shape(spec.schema)}\n    read:     "
                    f"{render_shape(r.shape)}"
                ),
            ))


def run_pass(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in _scope_files(root):
        relpath = str(path.relative_to(root))
        tree = ast.parse(path.read_text(), filename=str(path))
        _ScopeVisitor(relpath, findings).visit(tree)
    _check_registry(root, findings)
    return findings

"""repro-lint: domain-specific static analysis for the forest-compression
repo (frame safety, determinism, lock discipline, kernel invariants).

Run via ``python tools/analysis/repro_lint.py``; see docs/analysis.md.
"""

"""Kernel-invariants pass: every Pallas kernel entry point carries the
float32 precision guard, declares its memory layout explicitly, and has
a reference twin the tests can compare against.

Background (docs/architecture.md, kernels/tree_predict): the TPU
engines traverse trees with float32 arithmetic over integer-coded
features/thresholds.  float32 holds integers exactly only below
``2**24``, so every public entry validates its inputs with
``_validate_f32_exact`` before launching — dropping that guard turns an
out-of-range feature code into a silently wrong prediction.  Each
kernel also has a pure-JAX reference implementation (``ref.py``) with a
matching signature; CI equivalence tests depend on the pairing.

Codes:

* **KERN001** — a public function that (transitively) launches
  ``pl.pallas_call`` without ``_validate_f32_exact`` on any path into
  it.  A function counts as guarded if it calls the validator itself
  or if every callee through which it reaches a kernel is guarded.
* **KERN002** — a ``pl.pallas_call`` without explicit ``out_shape`` /
  ``in_specs`` / ``out_specs``, or a ``pl.BlockSpec()`` with neither a
  block shape nor an explicit ``memory_space``: implicit defaults hide
  where tensors live (ANY vs VMEM vs SMEM) and break the next reader.
* **KERN003** — a kernel entry missing its reference twin, or a twin
  whose positional parameters are not an ordered subsequence of the
  kernel's (the kernel may take extra tuning/precomputed args; the
  shared science parameters must line up by name and order).
* **KERN004** — a function that calls ``pl.pallas_call`` directly but
  is unreachable from every registered entry point: dead or orphaned
  kernel code that the equivalence tests cannot be exercising.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

PACKAGE = "src/repro/kernels/tree_predict"

#: kernel entry -> reference twin in ref.py.  The sharded engine
#: reuses the packed reference: identical math, device-count-invariant.
KERNEL_TWINS: dict[str, str] = {
    "forest_predict": "forest_predict_reference",
    "forest_predict_agg": "forest_predict_agg_reference",
    "forest_predict_agg_segmented":
        "forest_predict_agg_segmented_reference",
    "forest_predict_agg_segmented_packed":
        "forest_predict_agg_segmented_packed_reference",
    "forest_predict_agg_segmented_sharded":
        "forest_predict_agg_segmented_packed_reference",
}

#: the module whose public kernels MUST each have a twin registered
KERNEL_MODULE = "tree_predict.py"
VALIDATOR = "_validate_f32_exact"


class _FnInfo:
    def __init__(self, module: str, node: ast.FunctionDef) -> None:
        self.module = module
        self.node = node
        self.name = node.name
        self.refs: set[str] = set()
        self.calls_validator = False
        self.pallas_calls: list[ast.Call] = []
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name):
                self.refs.add(sub.id)
            if isinstance(sub, ast.Call):
                dotted = _dotted(sub.func)
                if dotted.split(".")[-1] == "pallas_call":
                    self.pallas_calls.append(sub)
                if dotted == VALIDATOR:
                    self.calls_validator = True

    @property
    def public(self) -> bool:
        return not self.name.startswith("_")

    @property
    def direct_pallas(self) -> bool:
        return bool(self.pallas_calls)

    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]


def _dotted(node: ast.expr) -> str:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_subsequence(needle: list[str], hay: list[str]) -> bool:
    it = iter(hay)
    return all(any(h == n for h in it) for n in needle)


class _Package:
    """All functions in the kernel package, with reference edges."""

    def __init__(self, root: Path) -> None:
        self.root = root
        self.fns: dict[str, _FnInfo] = {}       # name -> info
        self.by_module: dict[str, list[_FnInfo]] = {}
        for path in sorted((root / PACKAGE).glob("*.py")):
            tree = ast.parse(path.read_text(), filename=str(path))
            infos = [
                _FnInfo(path.name, n)
                for n in tree.body
                if isinstance(n, ast.FunctionDef)
            ]
            # function-level defs referenced via `from .sibling import x`
            # resolve by bare name: the package universe is flat and
            # names are unique across its modules.
            for info in infos:
                self.fns[info.name] = info
            self.by_module[path.name] = infos
        self._reach_memo: dict[str, bool] = {}
        self._guard_memo: dict[str, bool] = {}

    def edges(self, fn: _FnInfo) -> list[_FnInfo]:
        return [
            self.fns[r] for r in fn.refs
            if r in self.fns and self.fns[r].name != fn.name
        ]

    def reaches_pallas(self, name: str, _stack: frozenset = frozenset()
                       ) -> bool:
        if name in self._reach_memo:
            return self._reach_memo[name]
        if name in _stack:
            return False
        fn = self.fns[name]
        if fn.direct_pallas:
            self._reach_memo[name] = True
            return True
        got = any(
            self.reaches_pallas(e.name, _stack | {name})
            for e in self.edges(fn)
        )
        self._reach_memo[name] = got
        return got

    def guarded(self, name: str, _stack: frozenset = frozenset()) -> bool:
        """True if every path from ``name`` into a pallas_call passes
        through ``_validate_f32_exact`` first."""
        if name in self._guard_memo:
            return self._guard_memo[name]
        if name in _stack:
            return True  # optimistic on cycles; the entry still checks
        fn = self.fns[name]
        if fn.calls_validator:
            self._guard_memo[name] = True
            return True
        if fn.direct_pallas:
            self._guard_memo[name] = False
            return False
        reaching = [
            e for e in self.edges(fn)
            if self.reaches_pallas(e.name)
        ]
        got = bool(reaching) and all(
            self.guarded(e.name, _stack | {name}) for e in reaching
        )
        self._guard_memo[name] = got
        return got


def run_pass(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    pkg = _Package(root)
    relpath = {
        m: f"{PACKAGE}/{m}" for m in pkg.by_module
    }

    ref_fns = {f.name: f for f in pkg.by_module.get("ref.py", [])}

    # ---- KERN001: precision guard on public entries -------------------
    for fn in pkg.fns.values():
        if not fn.public or not pkg.reaches_pallas(fn.name):
            continue
        if not pkg.guarded(fn.name):
            findings.append(Finding(
                code="KERN001",
                path=relpath[fn.module],
                line=fn.node.lineno,
                scope=fn.name,
                subject=fn.name,
                message=(
                    f"public kernel entry {fn.name} launches "
                    f"pl.pallas_call without {VALIDATOR} on every "
                    "path — inputs above 2**24 would traverse wrong "
                    "silently (float32 integer-exactness bound)"
                ),
            ))

    # ---- KERN002: explicit layout on every pallas_call ----------------
    for fn in pkg.fns.values():
        for call in fn.pallas_calls:
            kwargs = {kw.arg for kw in call.keywords}
            missing = [
                k for k in ("out_shape", "in_specs", "out_specs")
                if k not in kwargs
            ]
            if missing:
                findings.append(Finding(
                    code="KERN002",
                    path=relpath[fn.module],
                    line=call.lineno,
                    scope=fn.name,
                    subject="pallas_call",
                    message=(
                        "pl.pallas_call without explicit "
                        f"{'/'.join(missing)} — memory layout must "
                        "be declared, not defaulted"
                    ),
                ))
        for sub in ast.walk(fn.node):
            if (
                isinstance(sub, ast.Call)
                and _dotted(sub.func).split(".")[-1] == "BlockSpec"
            ):
                kwargs = {kw.arg for kw in sub.keywords}
                if not sub.args and "memory_space" not in kwargs:
                    findings.append(Finding(
                        code="KERN002",
                        path=relpath[fn.module],
                        line=sub.lineno,
                        scope=fn.name,
                        subject="BlockSpec",
                        message=(
                            "pl.BlockSpec with neither a block shape "
                            "nor memory_space — declare where the "
                            "operand lives (VMEM block / SMEM / ANY)"
                        ),
                    ))

    # ---- KERN003: reference twins -------------------------------------
    for entry, twin in KERNEL_TWINS.items():
        fn = pkg.fns.get(entry)
        if fn is None:
            findings.append(Finding(
                code="KERN003",
                path=PACKAGE,
                line=1,
                scope=entry,
                subject=entry,
                message=f"registered kernel entry {entry} not found",
            ))
            continue
        ref = ref_fns.get(twin)
        if ref is None:
            findings.append(Finding(
                code="KERN003",
                path=relpath[fn.module],
                line=fn.node.lineno,
                scope=entry,
                subject=twin,
                message=(
                    f"kernel entry {entry} has no reference twin "
                    f"{twin} in ref.py"
                ),
            ))
            continue
        if not _is_subsequence(ref.params(), fn.params()):
            findings.append(Finding(
                code="KERN003",
                path=relpath[fn.module],
                line=fn.node.lineno,
                scope=entry,
                subject=twin,
                message=(
                    f"reference twin {twin}{tuple(ref.params())} is "
                    "not an ordered parameter subsequence of "
                    f"{entry}{tuple(fn.params())} — the equivalence "
                    "tests cannot pair them positionally"
                ),
            ))
    # every public kernel in the kernel module must be registered
    for fn in pkg.by_module.get(KERNEL_MODULE, []):
        if (
            fn.public
            and pkg.reaches_pallas(fn.name)
            and fn.name not in KERNEL_TWINS
        ):
            findings.append(Finding(
                code="KERN003",
                path=relpath[fn.module],
                line=fn.node.lineno,
                scope=fn.name,
                subject=fn.name,
                message=(
                    f"public kernel {fn.name} is not registered in "
                    "KERNEL_TWINS — add a reference twin in ref.py "
                    "and register the pair"
                ),
            ))

    # ---- KERN004: no orphaned kernels ---------------------------------
    entries = set(KERNEL_TWINS) | {
        f.name for f in pkg.fns.values()
        if f.public and pkg.reaches_pallas(f.name)
    }
    reachable: set[str] = set()
    frontier = [e for e in entries if e in pkg.fns]
    while frontier:
        cur = frontier.pop()
        if cur in reachable:
            continue
        reachable.add(cur)
        frontier.extend(e.name for e in pkg.edges(pkg.fns[cur]))
    for fn in pkg.fns.values():
        if fn.direct_pallas and fn.name not in reachable:
            findings.append(Finding(
                code="KERN004",
                path=relpath[fn.module],
                line=fn.node.lineno,
                scope=fn.name,
                subject=fn.name,
                message=(
                    f"{fn.name} calls pl.pallas_call but is "
                    "unreachable from every registered kernel entry "
                    "— orphaned kernel code the equivalence tests "
                    "cannot exercise"
                ),
            ))
    return findings

"""Determinism pass: serialized bytes and scheduling decisions must not
depend on hash order, wall clocks, or unseeded randomness.

Codes:

* **DET001** — wall-clock call (``time.time`` / ``time.monotonic`` /
  ``datetime.now`` / ...) in ``core/`` or ``store/``: codec and store
  behavior must be a pure function of its inputs (artifact diffing,
  golden tests, and the recovery replay all depend on it).
* **DET002** — unseeded randomness in ``core/`` or ``store/``:
  ``np.random.default_rng()`` with no seed, the legacy ``np.random.*``
  global distributions, or the ``random`` module.  Every stochastic
  routine takes an explicit ``seed`` and threads it through.
* **DET003** — iteration over an unsorted ``dict``/``set`` view inside
  an EMIT function (one that writes framing primitives or is named
  ``to_bytes``): dict order is insertion order, so the emitted bytes
  silently depend on construction history — two stores with identical
  content serialize differently.  Wrap in ``sorted(...)``.
* **DET004** — wall-clock use in ``sched/`` outside ``clock.py``: the
  scheduler is virtual-clock-driven by design (tests replay traffic
  deterministically); only the ``Clock`` implementations may touch
  ``time``.

``# repro-lint: allow-wallclock`` on the offending line suppresses
DET001/DET004 for the rare legitimate site (none today).
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding

PURE_SCOPE = ("src/repro/core", "src/repro/store")
SCHED_SCOPE = "src/repro/sched"
SCHED_CLOCK_EXEMPT = "clock.py"

_ALLOW_MARK = "repro-lint: allow-wallclock"

_WALL_CLOCK_ATTRS = {
    "time", "monotonic", "perf_counter", "process_time", "time_ns",
    "monotonic_ns", "perf_counter_ns",
}
_DATETIME_NOW = {"now", "utcnow", "today"}
_LEGACY_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "sample",
    "ranf", "choice", "shuffle", "permutation", "seed", "uniform",
    "normal", "standard_normal", "beta", "binomial", "poisson",
}
_EMIT_CALLS = {
    "write_arr", "write_bytes", "write_u16", "write_u32", "with_crc",
}
_VIEW_ATTRS = {"items", "keys", "values"}
_ORDER_FIXERS = {"sorted", "min", "max", "sum", "len", "frozenset", "set"}


def _allowed(source_lines: list[str], lineno: int) -> bool:
    if 1 <= lineno <= len(source_lines):
        return _ALLOW_MARK in source_lines[lineno - 1]
    return False


class _ClockVisitor(ast.NodeVisitor):
    """DET001 / DET004: wall-clock and unseeded-random call sites."""

    def __init__(
        self,
        relpath: str,
        code: str,
        findings: list[Finding],
        lines: list[str],
        flag_random: bool,
    ) -> None:
        self.relpath = relpath
        self.code = code
        self.findings = findings
        self.lines = lines
        self.flag_random = flag_random
        self._scope: list[str] = []

    @property
    def scope(self) -> str:
        return ".".join(self._scope) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name:
            self._check_call(node, name)
        self.generic_visit(node)

    def _emit(
        self, node: ast.AST, subject: str, message: str,
        code: str | None = None,
    ) -> None:
        self.findings.append(Finding(
            code=code or self.code,
            path=self.relpath,
            line=node.lineno,
            scope=self.scope,
            subject=subject,
            message=message,
        ))

    def _check_call(self, node: ast.Call, name: str) -> None:
        parts = name.split(".")
        # time.time(), time.monotonic(), ...
        if (
            len(parts) == 2
            and parts[0] == "time"
            and parts[1] in _WALL_CLOCK_ATTRS
            and not _allowed(self.lines, node.lineno)
        ):
            self._emit(
                node, name,
                f"wall-clock call {name}() — this layer must be "
                "clock-free (inject a Clock / take timestamps as "
                "arguments)",
            )
            return
        # datetime.now() / datetime.datetime.now() / date.today()
        if (
            parts[-1] in _DATETIME_NOW
            and any(p in ("datetime", "date") for p in parts[:-1])
            and not _allowed(self.lines, node.lineno)
        ):
            self._emit(
                node, name,
                f"wall-clock call {name}() — this layer must be "
                "clock-free",
            )
            return
        if not self.flag_random:
            return
        # np.random.default_rng() with no seed argument
        if (
            parts[-1] == "default_rng"
            and "random" in parts
            and not node.args
            and not node.keywords
        ):
            self._emit(
                node, name,
                "np.random.default_rng() without a seed — stochastic "
                "routines must take an explicit seed",
                code="DET002",
            )
            return
        # legacy np.random.<dist>() globals
        if (
            len(parts) >= 2
            and parts[-2] == "random"
            and parts[0] in ("np", "numpy")
            and parts[-1] in _LEGACY_NP_RANDOM
        ):
            self._emit(
                node, name,
                f"legacy global-state RNG {name}() — use a seeded "
                "np.random.default_rng(seed) Generator",
                code="DET002",
            )
            return
        # stdlib random module
        if len(parts) == 2 and parts[0] == "random" and parts[1] in (
            "random", "randint", "randrange", "choice", "shuffle",
            "sample", "uniform", "seed", "gauss",
        ):
            self._emit(
                node, name,
                f"stdlib {name}() uses hidden global state — use a "
                "seeded np.random.default_rng(seed)",
                code="DET002",
            )


class _EmitOrderVisitor(ast.NodeVisitor):
    """DET003: unsorted dict/set-view iteration inside emit functions."""

    def __init__(self, relpath: str, findings: list[Finding]) -> None:
        self.relpath = relpath
        self.findings = findings
        self._scope: list[str] = []

    @property
    def scope(self) -> str:
        return ".".join(self._scope) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scope.append(node.name)
        if _is_emit_function(node):
            self._check_emit_fn(node)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def _check_emit_fn(self, fn: ast.FunctionDef) -> None:
        iters: list[ast.expr] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.GeneratorExp, ast.DictComp)):
                for g in node.generators:
                    iters.append(g.iter)
        for it in iters:
            for view in _unsorted_views(it):
                self.findings.append(Finding(
                    code="DET003",
                    path=self.relpath,
                    line=view.lineno,
                    scope=self.scope,
                    subject=f".{view.func.attr}()",
                    message=(
                        "iterating an unsorted dict view in an emit "
                        "function — serialized bytes would depend on "
                        "insertion order; wrap in sorted(...)"
                    ),
                ))


def _is_emit_function(fn: ast.FunctionDef) -> bool:
    if fn.name == "to_bytes":
        return True
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in _EMIT_CALLS
        ):
            return True
    return False


def _unsorted_views(expr: ast.expr) -> list[ast.Call]:
    """``.items()/.keys()/.values()`` calls in ``expr`` that are not
    under a ``sorted(...)`` (or another order-fixing) call."""
    out: list[ast.Call] = []

    def walk(node: ast.AST, ordered: bool) -> None:
        if isinstance(node, ast.Call):
            if (
                isinstance(node.func, ast.Name)
                and node.func.id in _ORDER_FIXERS
            ):
                for child in ast.iter_child_nodes(node):
                    walk(child, True)
                return
            if (
                not ordered
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _VIEW_ATTRS
                and not node.args
                and not node.keywords
            ):
                out.append(node)
        for child in ast.iter_child_nodes(node):
            walk(child, ordered)

    walk(expr, False)
    return out


def _dotted(node: ast.expr) -> str:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def run_pass(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for sub in PURE_SCOPE:
        for path in sorted((root / sub).glob("*.py")):
            relpath = str(path.relative_to(root))
            text = path.read_text()
            tree = ast.parse(text, filename=str(path))
            lines = text.splitlines()
            _ClockVisitor(
                relpath, "DET001", findings, lines, flag_random=True
            ).visit(tree)
            _EmitOrderVisitor(relpath, findings).visit(tree)
    for path in sorted((root / SCHED_SCOPE).glob("*.py")):
        if path.name == SCHED_CLOCK_EXEMPT:
            continue
        relpath = str(path.relative_to(root))
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        lines = text.splitlines()
        _ClockVisitor(
            relpath, "DET004", findings, lines, flag_random=False
        ).visit(tree)
    return findings

"""Declarative frame-schema registry and AST wire-shape extraction.

Every byte format this repo writes (docs/format.md) has exactly one
writer/reader pair.  This module gives the frame-safety pass two
things:

1. **A registry** (``REGISTRY``): for each frame tag, where the writer
   and reader live and the NORMALIZED WIRE SHAPE both must produce —
   a token tree in a tiny vocabulary (``u8 u16 u32 i16 arr bytes
   magic`` plus ``("loop", (...))`` groups).  The shapes below were
   transcribed from docs/format.md's field tables; they are the
   single point of truth the code is checked against.

2. **An extractor** (``extract_shape``): walks a writer or reader
   function's AST and recovers the shape it actually implements, by
   recognizing the ``core.framing`` primitives (``write_u16`` /
   ``read_struct`` / ``write_arr`` / ...), ``struct.pack`` inside
   ``out.write``, magic-constant writes, and loops/branches — and by
   INLINING module-local helpers (``_write_component`` et al.), so a
   frame's full shape is visible even when it is factored into
   records.  ``if``/``else`` arms that serialize identically collapse;
   arms that differ surface as a ``("branch", ...)`` marker, which
   never matches a schema — divergent-arm serialization is itself a
   defect.

A writer and reader that both match the declared schema are
field-symmetric by construction; a drifted edit to either side shows
up as a shape mismatch (FRAME004/FRAME005) the moment it is made.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path

# ---------------------------------------------------------------------------
# token vocabulary
# ---------------------------------------------------------------------------

U8, U16, U32 = "u8", "u16", "u32"
I8, I16, I32, I64 = "i8", "i16", "i32", "i64"
U64, F32, F64 = "u64", "f32", "f64"
ARR, BYTES, MAGIC = "arr", "bytes", "magic"
#: an ``out.write(...)`` of something the extractor cannot type
RAW = "raw"


def loop(*items: object) -> tuple:
    """A repeated group in a wire shape."""
    return ("loop", tuple(items))


_STRUCT_TOKENS = {
    "b": I8, "B": U8,
    "h": I16, "H": U16,
    "i": I32, "I": U32,
    "q": I64, "Q": U64,
    "f": F32, "d": F64,
}


def expand_fmt(fmt: str) -> list[str]:
    """``struct`` format string -> token list (``"<HIB"`` -> u16 u32 u8)."""
    toks: list[str] = []
    count = ""
    for ch in fmt:
        if ch in "<>=!@ ":
            continue
        if ch.isdigit():
            count += ch
            continue
        n = int(count) if count else 1
        count = ""
        if ch == "x":
            continue
        if ch == "s":
            toks.append(f"s{n}")
            continue
        tok = _STRUCT_TOKENS.get(ch, f"?{ch}")
        toks.extend([tok] * n)
    return toks


def normalize(items) -> tuple:
    """Canonical shape: drop empty loops, collapse identical branch arms."""
    out: list = []
    for it in items:
        if isinstance(it, tuple) and it and it[0] == "loop":
            body = normalize(it[1])
            if body:
                out.append(("loop", body))
        elif isinstance(it, tuple) and it and it[0] == "branch":
            arms = [normalize(a) for a in it[1:]]
            arms = [a for a in arms if a]
            if not arms:
                continue
            if all(a == arms[0] for a in arms):
                out.extend(arms[0])
            else:
                out.append(("branch",) + tuple(arms))
        else:
            out.append(it)
    return tuple(out)


def render_shape(shape: tuple) -> str:
    """Human-readable one-line rendering for diagnostics."""
    parts = []
    for it in shape:
        if isinstance(it, tuple) and it and it[0] == "loop":
            parts.append(f"loop({render_shape(it[1])})")
        elif isinstance(it, tuple) and it and it[0] == "branch":
            arms = " | ".join(render_shape(a) for a in it[1:])
            parts.append(f"branch({arms})")
        else:
            parts.append(str(it))
    return " ".join(parts)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrameSpec:
    """One frame format: where its writer/reader live and the wire shape
    both must implement.

    ``documented`` marks tags with a normative section in docs/format.md
    (the registry-vs-docs test keys on this); RFC1 is the pre-store
    inline format that §7 declares specified by its implementation.
    """

    tag: str
    module: str              # repo-relative path
    writer: str              # qualname, e.g. "SharedCodebook.to_bytes"
    reader: str              # qualname, e.g. "SharedCodebook.from_bytes"
    schema: tuple            # normalized token tree (magic included)
    sealed: bool = True      # must end in a CRC1 trailer (format.md §8)
    documented: bool = True  # has a numbered section in docs/format.md


# docs/format.md §2.1 COMPONENT
_RFS1_COMPONENT = (U8, U16, U32, loop(ARR))
# docs/format.md §3.1 DELTA-COMPONENT
_RFD1_COMPONENT = (U8, ARR, U16, loop(ARR), U16, loop(I16, U32, BYTES))
# RFC1 COMPONENT (legacy inline format; see _write_rfc_component)
_RFC1_COMPONENT = (U8, ARR, U16, loop(ARR, U32, BYTES))

REGISTRY: tuple[FrameSpec, ...] = (
    FrameSpec(
        tag="RFS1",
        module="src/repro/store/codebook.py",
        writer="SharedCodebook.to_bytes",
        reader="SharedCodebook.from_bytes",
        schema=normalize((
            MAGIC,
            U16, U32, U8, U16, U16, U32,     # header "<HIBHHI"
            ARR,                             # n_bins_per_feature
            ARR,                             # categorical
            *_RFS1_COMPONENT,                # vars component
            U16, loop(U16, *_RFS1_COMPONENT),  # split components
            *_RFS1_COMPONENT,                # fits component
            ARR,                             # fleet_fit_values
        )),
    ),
    FrameSpec(
        tag="RFD1",
        module="src/repro/store/delta.py",
        writer="UserDelta.to_bytes",
        reader="UserDelta.from_bytes",
        schema=normalize((
            MAGIC,
            U16, U32, U16, U32, U32,         # header "<HIHII"
            ARR,                             # zaks_lengths
            BYTES,                           # zaks_payload
            *_RFD1_COMPONENT,                # vars delta component
            U16, loop(U16, *_RFD1_COMPONENT),  # split components
            *_RFD1_COMPONENT,                # fits component
            ARR,                             # fit_map
            ARR,                             # extra_fit_values
        )),
    ),
    FrameSpec(
        tag="RFT1",
        module="src/repro/store/runtime.py",
        writer="ForestStore.to_bytes",
        reader="ForestStore.from_bytes",
        schema=normalize((
            MAGIC,
            U16, loop(BYTES),                # retained codebook frames
            U32, loop(BYTES, BYTES),         # (user_id, delta frame)
        )),
    ),
    FrameSpec(
        tag="RFM1",
        module="src/repro/store/lifecycle.py",
        writer="RemapTable.to_bytes",
        reader="RemapTable.from_bytes",
        schema=normalize((
            MAGIC,
            U16, U16,                        # old/new generation
            U8,                              # fit_table_prefix flag
            ARR,                             # vars_map
            U16, loop(U16, ARR),             # per-variable split maps
            ARR,                             # fits_map
        )),
    ),
    FrameSpec(
        tag="RFJ1",
        module="src/repro/store/lifecycle.py",
        writer="MigrationJournal.to_bytes",
        reader="MigrationJournal.from_bytes",
        schema=normalize((
            MAGIC,
            U8,                              # state index
            BYTES,                           # mode
            U16, U16,                        # old/new generation
            BYTES, BYTES,                    # codebook frame, remap frame
            U32, loop(BYTES, U8, BYTES, BYTES),  # per-user entries
        )),
    ),
    FrameSpec(
        tag="RFN1",
        module="src/repro/store/durable.py",
        writer="Manifest.to_bytes",
        reader="Manifest.from_bytes",
        schema=normalize((
            MAGIC,
            U32, U16, U32, U32,              # epoch, slab_shards, next ids
            U32,                             # n_slabs
            loop(
                U32, U32, U32, U16,          # slab header
                loop(U32, U8, U8, U16, BYTES, U32, U32, U32),  # shards
            ),
        )),
    ),
    FrameSpec(
        tag="RFC1",
        module="src/repro/core/forest_codec.py",
        writer="CompressedForest.to_bytes",
        reader="CompressedForest.from_bytes",
        documented=False,                    # legacy inline format (§7)
        schema=normalize((
            MAGIC,
            U32, U32, U16, U32, U8,          # header "<IIHIB"
            U16, U32,                        # structure header "<HI"
            ARR, ARR,                        # n_bins, categorical
            ARR,                             # zaks_lengths
            BYTES,                           # zaks_payload
            *_RFC1_COMPONENT,                # vars component
            U16, loop(U16, *_RFC1_COMPONENT),  # split components
            *_RFC1_COMPONENT,                # fits component
            ARR,                             # fit_values
        )),
    ),
)


_DOC_TAG_RE = re.compile(r"^##\s+\d+\.\s+`(RF[A-Z]\d)`", re.MULTILINE)


def documented_tags(format_md: Path) -> set[str]:
    """Frame tags with a numbered ``## N. `TAG``` section in format.md."""
    return set(_DOC_TAG_RE.findall(format_md.read_text()))


# ---------------------------------------------------------------------------
# AST shape extraction
# ---------------------------------------------------------------------------

_WRITE_PRIMS = {
    "write_u16": U16, "write_u32": U32,
    "write_arr": ARR, "write_bytes": BYTES,
}
_READ_PRIMS = {
    "read_u16": U16, "read_u32": U32,
    "read_arr": ARR, "read_bytes": BYTES,
}


@dataclass
class ModuleIndex:
    """Parsed module with its top-level defs and bytes constants."""

    path: Path
    tree: ast.Module
    functions: dict[str, ast.FunctionDef]
    classes: dict[str, ast.ClassDef]
    bytes_constants: dict[str, bytes]

    @classmethod
    def parse(cls, path: Path) -> "ModuleIndex":
        tree = ast.parse(path.read_text(), filename=str(path))
        functions: dict[str, ast.FunctionDef] = {}
        classes: dict[str, ast.ClassDef] = {}
        consts: dict[str, bytes] = {}
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                classes[node.name] = node
            elif isinstance(node, ast.Assign):
                if (
                    isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, bytes)
                ):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            consts[t.id] = node.value.value
        return cls(path, tree, functions, classes, consts)

    def resolve(self, qualname: str) -> ast.FunctionDef:
        """Find ``func`` or ``Class.method`` in this module."""
        if "." in qualname:
            cname, mname = qualname.split(".", 1)
            cls_node = self.classes.get(cname)
            if cls_node is not None:
                for item in cls_node.body:
                    if (
                        isinstance(
                            item, (ast.FunctionDef, ast.AsyncFunctionDef)
                        )
                        and item.name == mname
                    ):
                        return item
            raise LookupError(f"{qualname} not found in {self.path}")
        fn = self.functions.get(qualname)
        if fn is None:
            raise LookupError(f"{qualname} not found in {self.path}")
        return fn


@dataclass
class ShapeResult:
    """What ``extract_shape`` recovered from one function."""

    shape: tuple
    calls_with_crc: bool
    calls_check_crc: bool
    has_magic: bool

    @property
    def sealed(self) -> bool:
        return self.calls_with_crc or self.calls_check_crc


class _Extractor:
    """In-order AST walk producing the wire-token stream of a function,
    inlining module-local helper calls (cycle-guarded)."""

    def __init__(self, index: ModuleIndex) -> None:
        self.index = index
        self.calls_with_crc = False
        self.calls_check_crc = False
        self._inline_stack: list[str] = []

    # -- entry ----------------------------------------------------------
    def extract(self, fn: ast.FunctionDef) -> list:
        env = {
            n.name: n
            for n in fn.body
            if isinstance(n, ast.FunctionDef)
        }
        return self._stmts(fn.body, env)

    # -- statements -----------------------------------------------------
    def _stmts(self, stmts, env) -> list:
        out: list = []
        for s in stmts:
            out.extend(self._stmt(s, env))
        return out

    def _stmt(self, s: ast.stmt, env) -> list:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef, ast.Import, ast.ImportFrom,
                          ast.Global, ast.Nonlocal, ast.Pass)):
            return []
        if isinstance(s, (ast.For, ast.AsyncFor)):
            head = self._expr(s.iter, env)
            body = self._stmts(s.body, env) + self._stmts(s.orelse, env)
            return head + ([("loop", tuple(body))] if body else [])
        if isinstance(s, ast.While):
            head = self._expr(s.test, env)
            body = self._stmts(s.body, env)
            return head + ([("loop", tuple(body))] if body else [])
        if isinstance(s, ast.If):
            head = self._expr(s.test, env)
            arms = (
                tuple(self._stmts(s.body, env)),
                tuple(self._stmts(s.orelse, env)),
            )
            return head + [("branch",) + arms]
        if isinstance(s, (ast.With, ast.AsyncWith)):
            out: list = []
            for item in s.items:
                out.extend(self._expr(item.context_expr, env))
            return out + self._stmts(s.body, env)
        if isinstance(s, ast.Try):
            out = self._stmts(s.body, env)
            for h in s.handlers:
                out.extend(self._stmts(h.body, env))
            out.extend(self._stmts(s.orelse, env))
            out.extend(self._stmts(s.finalbody, env))
            return out
        if isinstance(s, ast.Return):
            return self._expr(s.value, env)
        if isinstance(s, ast.Assign):
            out = self._expr(s.value, env)
            for t in s.targets:
                out.extend(self._expr(t, env))
            return out
        if isinstance(s, ast.AugAssign):
            return self._expr(s.value, env) + self._expr(s.target, env)
        if isinstance(s, ast.AnnAssign):
            return self._expr(s.value, env)
        if isinstance(s, ast.Expr):
            return self._expr(s.value, env)
        if isinstance(s, (ast.Raise, ast.Assert, ast.Delete)):
            out = []
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    out.extend(self._expr(child, env))
            return out
        # anything else: walk expression children in order
        out = []
        for child in ast.iter_child_nodes(s):
            if isinstance(child, ast.expr):
                out.extend(self._expr(child, env))
        return out

    # -- expressions ----------------------------------------------------
    def _expr(self, e, env) -> list:
        if e is None or not isinstance(e, ast.expr):
            return []
        if isinstance(e, ast.Call):
            return self._call(e, env)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            gen = e.generators[0]
            head = self._expr(gen.iter, env)
            inner: list = []
            for g in e.generators[1:]:
                inner.extend(self._expr(g.iter, env))
            for g in e.generators:
                for cond in g.ifs:
                    inner.extend(self._expr(cond, env))
            if isinstance(e, ast.DictComp):
                inner.extend(self._expr(e.key, env))
                inner.extend(self._expr(e.value, env))
            else:
                inner.extend(self._expr(e.elt, env))
            return head + ([("loop", tuple(inner))] if inner else [])
        if isinstance(e, ast.IfExp):
            head = self._expr(e.test, env)
            arms = (
                tuple(self._expr(e.body, env)),
                tuple(self._expr(e.orelse, env)),
            )
            return head + [("branch",) + arms]
        if isinstance(e, (ast.Lambda,)):
            return []
        out: list = []
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                out.extend(self._expr(child, env))
        return out

    # -- calls ----------------------------------------------------------
    def _call(self, c: ast.Call, env) -> list:
        name = _dotted(c.func)
        bare = name.split(".")[-1] if name else ""

        def args_toks() -> list:
            out: list = []
            for a in c.args:
                out.extend(self._expr(a, env))
            for kw in c.keywords:
                out.extend(self._expr(kw.value, env))
            return out

        # sealing markers (no wire tokens of their own)
        if bare == "with_crc":
            self.calls_with_crc = True
            return args_toks()
        if bare == "check_crc":
            self.calls_check_crc = True
            return args_toks()

        if isinstance(c.func, ast.Name):
            if c.func.id in _WRITE_PRIMS:
                return args_toks() + [_WRITE_PRIMS[c.func.id]]
            if c.func.id in _READ_PRIMS:
                return args_toks() + [_READ_PRIMS[c.func.id]]
            if c.func.id == "read_struct":
                fmt = _const_str(c.args[1]) if len(c.args) > 1 else None
                return list(expand_fmt(fmt)) if fmt else ["?fmt"]
            if c.func.id == "expect_magic":
                return [MAGIC]
            # module-local helper (nested def shadows module-level)
            target = env.get(c.func.id) or self.index.functions.get(
                c.func.id
            )
            if target is not None:
                return args_toks() + self._inline(target)
            return args_toks()

        # struct.unpack(fmt, ...) used directly as a reader
        if name == "struct.unpack" or bare == "unpack":
            fmt = _const_str(c.args[0]) if c.args else None
            return args_toks() + (
                list(expand_fmt(fmt)) if fmt else ["?fmt"]
            )

        # out.write(...)
        if bare == "write" and len(c.args) == 1 and not c.keywords:
            return self._write_arg(c.args[0], env)

        # unhandled call: walk func + args in evaluation order
        out = self._expr(c.func, env)
        return out + args_toks()

    def _write_arg(self, a: ast.expr, env) -> list:
        """Tokens for the single argument of an ``out.write(...)``."""
        if isinstance(a, ast.Call):
            nm = _dotted(a.func)
            if nm == "struct.pack" or nm.endswith(".pack"):
                fmt = _const_str(a.args[0]) if a.args else None
                return list(expand_fmt(fmt)) if fmt else ["?fmt"]
        if isinstance(a, ast.Constant) and isinstance(a.value, bytes):
            return [MAGIC] if len(a.value) == 4 else [RAW]
        if isinstance(a, ast.Name):
            const = self.index.bytes_constants.get(a.id)
            if const is not None:
                return [MAGIC] if len(const) == 4 else [RAW]
        # opaque write: visible as RAW so asymmetry surfaces
        return self._expr(a, env) + [RAW]

    def _inline(self, fn: ast.FunctionDef) -> list:
        if fn.name in self._inline_stack:
            return []  # recursion: shape cannot be expressed, stop
        self._inline_stack.append(fn.name)
        try:
            return self.extract(fn)
        finally:
            self._inline_stack.pop()


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted name of a call target (``"struct.pack"``)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ".".join(reversed(parts))


def _const_str(node: ast.expr) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def extract_shape(index: ModuleIndex, qualname: str) -> ShapeResult:
    """The normalized wire shape implemented by ``qualname`` in the
    module, plus its sealing/magic facts."""
    fn = index.resolve(qualname)
    ex = _Extractor(index)
    raw = ex.extract(fn)
    shape = normalize(raw)
    return ShapeResult(
        shape=shape,
        calls_with_crc=ex.calls_with_crc,
        calls_check_crc=ex.calls_check_crc,
        has_magic=MAGIC in _flatten(shape),
    )


def _flatten(shape) -> list:
    out: list = []
    for it in shape:
        if isinstance(it, tuple) and it and it[0] in ("loop", "branch"):
            for sub in it[1:]:
                out.extend(_flatten(sub))
        else:
            out.append(it)
    return out

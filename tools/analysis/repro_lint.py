#!/usr/bin/env python3
"""repro-lint: domain-specific static analysis for this repository.

Four passes over the source tree (no imports of the analyzed code —
pure ``ast``), each encoding an invariant the test suite can only
sample but the analyzer can check exhaustively:

* ``frame-safety``     FRAME001..FRAME006  (see frame_safety.py)
* ``determinism``      DET001..DET004      (see determinism.py)
* ``lock-discipline``  LOCK001..LOCK002    (see lock_discipline.py)
* ``kernel-invariants``KERN001..KERN004    (see kernel_invariants.py)

Usage::

    python tools/analysis/repro_lint.py                  # everything
    python tools/analysis/repro_lint.py --baseline       # CI gate
    python tools/analysis/repro_lint.py --passes determinism,frame-safety
    python tools/analysis/repro_lint.py --format json
    python tools/analysis/repro_lint.py --write-baseline # accept current

Exit status: 0 when no (non-baselined) findings, 1 otherwise.  With
``--baseline``, findings whose fingerprint appears in
``tools/analysis/baseline.json`` are reported as baselined but do not
fail the run — new findings always do.  The baseline in this repo is
EMPTY by policy: every pre-existing true positive was fixed in the PR
that introduced the linter, so any entry added later needs a written
justification in the baseline file.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

if __package__ in (None, ""):  # executed as a script
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from analysis import (  # type: ignore[no-redef]
        determinism,
        frame_safety,
        kernel_invariants,
        lock_discipline,
    )
    from analysis.findings import Baseline, Finding  # type: ignore
else:
    from . import (
        determinism,
        frame_safety,
        kernel_invariants,
        lock_discipline,
    )
    from .findings import Baseline, Finding

PASSES = {
    "frame-safety": frame_safety.run_pass,
    "determinism": determinism.run_pass,
    "lock-discipline": lock_discipline.run_pass,
    "kernel-invariants": kernel_invariants.run_pass,
}

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def run(root: Path, passes: list[str]) -> list[Finding]:
    findings: list[Finding] = []
    for name in passes:
        findings.extend(PASSES[name](root))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint", description=__doc__.split("\n\n")[0]
    )
    ap.add_argument(
        "--root", type=Path,
        default=Path(__file__).resolve().parents[2],
        help="repository root (default: two levels above this file)",
    )
    ap.add_argument(
        "--passes", default=",".join(PASSES),
        help=f"comma-separated subset of: {', '.join(PASSES)}",
    )
    ap.add_argument(
        "--baseline", nargs="?", const=str(DEFAULT_BASELINE),
        default=None, metavar="PATH",
        help="tolerate findings recorded in the baseline file "
             f"(default path: {DEFAULT_BASELINE.name})",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="record every current finding into the baseline and exit 0",
    )
    ap.add_argument(
        "--format", choices=("text", "json"), default="text",
    )
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        ap.error(f"unknown pass(es): {', '.join(unknown)}")

    findings = run(args.root, passes)

    baseline_path = (
        Path(args.baseline) if args.baseline else DEFAULT_BASELINE
    )
    if args.write_baseline:
        bl = Baseline(path=baseline_path)
        for f in findings:
            bl.accepted[f.fingerprint] = f.message.split("\n")[0]
        bl.save()
        print(
            f"wrote {len(bl.accepted)} fingerprint(s) to {baseline_path}"
        )
        return 0

    if args.baseline is not None:
        bl = Baseline.load(baseline_path)
        gating = bl.filter_new(findings)
        baselined = len(findings) - len(gating)
        stale = bl.stale_entries(findings)
    else:
        gating, baselined, stale = findings, 0, []

    if args.format == "json":
        print(json.dumps(
            {
                "findings": [f.__dict__ for f in gating],
                "baselined": baselined,
                "stale_baseline_entries": stale,
            },
            indent=2,
        ))
    else:
        for f in gating:
            print(f.render())
        if baselined:
            print(f"({baselined} baselined finding(s) suppressed)")
        for fp in stale:
            print(
                f"note: baseline entry no longer fires, remove it: {fp}"
            )
        summary = (
            f"repro-lint: {len(gating)} finding(s) across "
            f"{len(passes)} pass(es)"
        )
        print(summary if gating else f"{summary} — clean")
    return 1 if gating else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""``ForestServer`` — the unified serving session facade (ISSUE 4
tentpole).

One public API replaces the three divergent entry points PR 1-3 grew
(``predict_compressed`` stays as the pure decode-side reference oracle;
the ``serve_compressed_forest`` / ``serve_store_batch`` shims that
bridged PR 1-3 callers have since been removed):

    server = ForestServer(store)            # fleet session
    plan = server.plan(requests)            # host-only: grouping, sort,
                                            # engine cost model, signature
    preds = server.execute(plan, X)         # pack -> gather -> kernel ->
                                            # finalize
    server.serve(requests)                  # plan + execute convenience
    server.serve_safe(requests)             # fault-isolating serve:
                                            # per-user typed statuses

The session owns the store, its device ``TileArena``, the decoded
``TileCache``, and a ``PlanCache`` that memoizes plans AND arena-gathered
packs across batches by the batch's user-run signature.  Invalidation is
PER USER (ISSUE 5): each memoized entry carries the registry versions —
and, for packs, the arena run-admission tokens — of exactly the users it
covers, so re-registering, migrating, or evicting user A drops only the
entries containing A; a warm session crossing a codebook migration keeps
serving untouched users from cache.  Single-forest serving is a one-user
session (``ForestServer.from_forest(...)``).

Graceful degradation (ISSUE 6): ``serve_safe`` QUARANTINES users whose
deltas fail integrity checks or entropy decode (typed per-user status,
healthy users in the same batch still served), retries transient arena
admission faults with bounded exponential backoff, and — when retries
exhaust — degrades the batch to the arena-free ``simple`` engine instead
of failing it.  ``stats()["health"]`` surfaces the quarantine set,
failure counters, and the store's recluster-journal state.
"""
from __future__ import annotations

import contextlib
import time
from collections import Counter, deque
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from ..store.runtime import ForestStore, TileCache, make_schema_arena
from . import engines
from .cache import PlanCache
from .plan import ENGINE_BLOCKS, ServePlan, build_plan

Request = tuple[str, np.ndarray]


@dataclass
class RequestStatus:
    """Per-request outcome of a fault-isolating ``serve_safe`` batch.

    ``status`` is ``"ok"`` (``prediction`` holds the result, identical to
    what ``serve`` would return) or ``"quarantined"`` (``prediction`` is
    ``None`` and ``detail`` carries the decode/integrity failure that
    sidelined the user).  ``degraded`` is True when the batch fell back
    to the arena-free simple engine after transient-fault retries
    exhausted — the prediction is still exact, only slower."""

    user_id: str
    status: str
    prediction: np.ndarray | None = None
    detail: str = ""
    degraded: bool = False


class SingleForestStore(ForestStore):
    """The ForestStore surface the serving engines need, backed by ONE
    inline ``CompressedForest`` — no fleet codebook, no deltas.  This is
    what makes single-forest serving a one-user session instead of a
    separate code path."""

    def __init__(
        self,
        comp,
        user_id: str = "forest",
        tile_cache_trees: int = 4096,
        arena_capacity_trees: int = 16384,
    ) -> None:
        # deliberately NOT calling ForestStore.__init__: there is no
        # SharedCodebook — comp.meta carries every schema field the
        # serving layer reads (task, n_classes, n_features, bins)
        self.shared = comp.meta
        self._comp = comp
        self._user = user_id
        self._deltas = {}
        self._hydrated = {}
        self._tile_counts = {}
        self.cache = TileCache(tile_cache_trees)
        self.version = 0
        self.lossy = None
        self.residency = None  # no durable tier behind a one-user session
        self.arena = make_schema_arena(
            comp.meta.n_features, comp.meta.n_bins_per_feature,
            arena_capacity_trees,
        )

    # ---------------- one-user registry ------------------------------------
    @property
    def user_ids(self) -> list[str]:
        return [self._user]

    def __contains__(self, user_id: str) -> bool:
        return user_id == self._user

    def _check(self, user_id: str) -> None:
        if user_id != self._user:
            raise KeyError(
                f"single-forest session serves {self._user!r}, "
                f"not {user_id!r}"
            )

    def n_trees(self, user_id: str) -> int:
        """Tree count of the session's one forest."""
        self._check(user_id)
        return self._comp.n_trees

    def max_depth(self, user_id: str) -> int:
        """Max tree depth of the session's one forest."""
        self._check(user_id)
        return self._comp.max_depth

    def hydrate(self, user_id: str):
        self._check(user_id)
        return self._comp

    def predict(self, user_id: str, x_binned: np.ndarray) -> np.ndarray:
        from ..core.compressed_predict import predict_compressed

        self._check(user_id)
        return predict_compressed(self._comp, x_binned)

    def user_version(self, user_id: str) -> int:
        """Per-user validity token (the registry never mutates here, so
        this is the constant store version)."""
        self._check(user_id)
        return self.version

    def drift_stats(self, exclude: tuple = ()) -> dict | None:
        """No fleet codebook, hence no codebook lifecycle to monitor."""
        return None

    # the multi-tenant registry/serialization surface does not apply
    def _unsupported(self, *_a, **_k):
        """Registry/serialization operation unavailable on the one-user
        serving adapter — raises ``TypeError``."""
        raise TypeError(
            "SingleForestStore is a read-only one-user serving adapter; "
            "build a ForestStore for registry operations"
        )

    add_user = add_delta = delta = reconstruct = _unsupported
    to_bytes = size_report = _unsupported


class ForestServer:
    """Session-level serving facade: plan/execute IR over one store."""

    def __init__(
        self,
        store: ForestStore,
        plan_cache_size: int = 64,
        interpret: bool | None = None,
        max_retries: int = 3,
        retry_backoff_s: float = 0.01,
        repairer: "Callable[[str], bool] | None" = None,
    ) -> None:
        self.store = store
        self.plan_cache = PlanCache(plan_cache_size)
        self.interpret = interpret
        self.engine_counts: Counter[str] = Counter()
        # per-engine execute wall-times (bounded window per engine),
        # surfaced as stats()["engine_timings"] for SLO dashboards
        self._engine_times: dict[str, deque[float]] = {}
        self.timing_window = 1024
        # graceful degradation (ISSUE 6): quarantine registry + retry
        # policy + health counters, surfaced via stats()["health"]
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        # user -> {"reason", "user_version": version at quarantine time}
        self._quarantined: dict[str, dict] = {}
        self.integrity_failures = 0
        self.transient_retries = 0
        self.degraded_batches = 0
        # auto-repair (ISSUE 8): optional hook called for a user whose
        # delta fails integrity — returns True after repairing + re-
        # registering the delta (``store.durable.attach_auto_repair``
        # wires it to parity reconstruction).  A failed repair is
        # remembered per quarantine entry, so an unrepairable user costs
        # one attempt, not one per batch.
        self.repairer = repairer
        self.repair_attempts = 0
        self.repairs = 0
        self.last_repair_error: str | None = None

    @classmethod
    def from_forest(
        cls,
        forest,
        user_id: str = "forest",
        tile_cache_trees: int = 4096,
        arena_capacity_trees: int = 16384,
        **kwargs,
    ) -> "ForestServer":
        """One-user session over a single forest: accepts a plain
        ``Forest`` (compressed on the way in) or an already-compressed
        ``CompressedForest`` — serving always runs from the compressed
        format (paper §5)."""
        from ..core.forest_codec import compress_forest
        from ..core.tree import Forest

        comp = compress_forest(forest) if isinstance(forest, Forest) \
            else forest
        store = SingleForestStore(
            comp, user_id,
            tile_cache_trees=tile_cache_trees,
            arena_capacity_trees=arena_capacity_trees,
        )
        return cls(store, **kwargs)

    # ---------------- plan ------------------------------------------------
    def plan(
        self,
        requests: Sequence[Request],
        engine: str | None = None,
        block_trees: int | None = None,
        block_obs: int | None = None,
    ) -> ServePlan:
        """Compile a request batch into a ``ServePlan``.  Each request is
        ``(user_id, rows)`` where ``rows`` is the (n, d) row block or just
        its row COUNT — plans depend only on the batch signature, so they
        can be built (and cached) without the data.  Memoized across
        batches; invalidated when the store registry changes."""
        request_users = tuple(u for u, _ in requests)
        row_counts = tuple(
            int(x) if isinstance(x, (int, np.integer)) else len(x)
            for _, x in requests
        )
        key = (
            tuple(zip(request_users, row_counts)),
            engine, block_trees, block_obs,
        )
        # validity token: the PER-USER registry versions of this batch's
        # users — re-registering or migrating user A invalidates only
        # plans containing A (partial invalidation)
        token = self._plan_token(request_users)
        plan = self.plan_cache.get_plan(key, token)
        if plan is None:
            plan = build_plan(
                self.store, request_users, row_counts,
                engine=engine, block_trees=block_trees, block_obs=block_obs,
            )
            self.plan_cache.put_plan(key, token, plan)
        return plan

    def _plan_token(self, users) -> tuple:
        """Plan validity token: each distinct user's registry version."""
        return tuple(
            self.store.user_version(u) for u in dict.fromkeys(users)
        )

    def _pack_token(self, users) -> tuple:
        """Pack validity token: each user's (registry version, arena
        run-admission token) pair — stale as soon as any covered user is
        re-registered, migrated with new bytes, evicted from the arena,
        or re-admitted."""
        arena = self.store.arena
        return tuple(
            (self.store.user_version(u), arena.run_token(u)) for u in users
        )

    # ---------------- execute ---------------------------------------------
    def execute(
        self,
        plan: ServePlan,
        X: Sequence[np.ndarray],
        interpret: bool | None = None,
    ) -> list[np.ndarray]:
        """Run pack -> gather -> kernel -> finalize for one row batch under
        a plan.  ``X`` holds one (n_i, d) int32 row block per request, in
        plan order.  Returns one prediction array per request (majority
        vote / ensemble mean), matching per-user ``predict_compressed``
        (vote counts are integer-exact; the regression mean accumulates in
        float32 on device)."""
        if len(X) != len(plan.row_counts):
            raise ValueError(
                f"plan covers {len(plan.row_counts)} requests, "
                f"got {len(X)} row blocks"
            )
        for i, (x, n) in enumerate(zip(X, plan.row_counts)):
            if len(x) != n:
                raise ValueError(
                    f"request {i}: plan expects {n} rows, got {len(x)}"
                )
        if self._plan_token(plan.users) != plan.user_tokens:
            raise ValueError(
                "stale plan: one of the plan's users was re-registered "
                "or migrated since it was built — call plan() again"
            )
        if not plan.request_users:
            return []
        if plan.n_rows == 0:
            return [np.zeros(len(x), np.float64) for x in X]
        from .pack import concat_rows

        xb = concat_rows(X)
        if interpret is None:
            interpret = self.interpret
        name = plan.engine.name
        self.engine_counts[name] += 1
        residency = getattr(self.store, "residency", None)
        if residency is not None:
            # absorb prefetch-staged deltas on THIS (serving) thread —
            # the prefetcher never mutates serving structures — then
            # hold the batch's users resident across pack + kernel: a
            # budget demotion between arena_ensure and gather would
            # drop a run the gather is about to index
            residency.absorb_staged()
            cm = residency.pin(plan.users)
        else:
            cm = contextlib.nullcontext()
        t0 = time.perf_counter()
        with cm:
            if name == "simple":
                total = engines.run_simple(self.store, plan, xb, interpret)
            else:
                pack = self._gathered_pack(plan)
                run = (
                    engines.run_pipelined if name == "pipelined"
                    else engines.run_sharded
                )
                total = run(self.store, plan, pack, xb, interpret)
            out = self._finalize(plan, total)
        self._record_timing(name, time.perf_counter() - t0)
        return out

    def _record_timing(self, engine: str, elapsed_s: float) -> None:
        times = self._engine_times.get(engine)
        if times is None:
            times = self._engine_times[engine] = deque(
                maxlen=self.timing_window
            )
        times.append(elapsed_s)

    def engine_timings(self) -> dict:
        """Per-engine execute wall-time summary over the last
        ``timing_window`` executions: count (lifetime), mean/p50/p99/max
        in milliseconds over the window."""
        out: dict[str, dict] = {}
        for name, times in self._engine_times.items():
            arr = np.array(times)
            out[name] = {
                "count": int(self.engine_counts[name]),
                "window": len(arr),
                "mean_ms": round(float(arr.mean()) * 1e3, 4),
                "p50_ms": round(float(np.percentile(arr, 50)) * 1e3, 4),
                "p99_ms": round(float(np.percentile(arr, 99)) * 1e3, 4),
                "max_ms": round(float(arr.max()) * 1e3, 4),
            }
        return out

    def _gathered_pack(self, plan: ServePlan):
        """Cross-batch gather memoization: reuse the arena-gathered pack
        for this plan signature unless one of ITS users changed underneath
        it (re-registration, migration, arena eviction/re-admission).
        Unrelated admissions and evictions leave the pack alone — the
        per-run partial invalidation a codebook migration relies on.  The
        eager sweep still drops every pack holding an evicted user, so
        gathered device copies never outlive the arena's capacity
        accounting."""
        arena = self.store.arena
        self.plan_cache.sweep_packs(self._pack_token)
        pack = self.plan_cache.get_pack(
            plan.signature, self._pack_token(plan.users)
        )
        if pack is not None:
            # keep the eviction policy honest: a served-from-cache batch
            # must still count as an access for its users' runs
            arena.touch_users(plan.users)
            return pack
        build = (
            engines.build_pipelined_pack if plan.engine.name == "pipelined"
            else engines.build_sharded_pack
        )
        pack = build(self.store, plan)
        # token read AFTER building: cold admissions inside the gather
        # assign run tokens, and the entry must be valid for the arena
        # as-left
        self.plan_cache.put_pack(
            plan.signature, plan.users, self._pack_token(plan.users), pack
        )
        return pack

    def _finalize(self, plan: ServePlan, total: np.ndarray):
        task = self.store.shared.task
        out: list[np.ndarray] = []
        for user_id, sl in zip(plan.request_users, plan.row_slices):
            if task == "classification":
                out.append(total[sl].argmax(-1).astype(np.float64))
            else:
                out.append(
                    total[sl].astype(np.float64)
                    / max(self.store.n_trees(user_id), 1)
                )
        return out

    # ---------------- conveniences ----------------------------------------
    def serve(
        self,
        requests: Sequence[Request],
        engine: str | None = None,
        block_trees: int | None = None,
        block_obs: int | None = None,
        interpret: bool | None = None,
    ) -> list[np.ndarray]:
        """plan + execute in one call.  Raises on any per-user fault —
        ``serve_safe`` is the fault-isolating variant."""
        if not requests:
            return []
        plan = self.plan(
            requests, engine=engine,
            block_trees=block_trees, block_obs=block_obs,
        )
        return self.execute(
            plan, [x for _, x in requests], interpret=interpret
        )

    # ---------------- graceful degradation (ISSUE 6) ----------------------
    @property
    def quarantined_users(self) -> list[str]:
        """Users currently sidelined by ``serve_safe`` (sorted)."""
        return sorted(self._quarantined)

    def release_quarantine(self, user_id: str) -> bool:
        """Manually lift a user's quarantine (e.g. after repairing their
        delta out of band).  Returns True if the user was quarantined.
        ``serve_safe`` re-probes them on the next batch."""
        return self._quarantined.pop(user_id, None) is not None

    def _quarantine(self, user_id: str, exc: Exception) -> None:
        from ..core.framing import FramingError

        self.integrity_failures += 1
        self._quarantined[user_id] = {
            "reason": f"{type(exc).__name__}: {exc}",
            "kind": (
                "integrity" if isinstance(exc, FramingError) else "decode"
            ),
            "user_version": self.store.user_version(user_id),
        }

    def _refresh_quarantine(self) -> None:
        """Release quarantined users whose delta changed since quarantine
        — a re-registered or migrated delta may be healthy again, and the
        next ``serve_safe`` batch re-probes it."""
        for u in list(self._quarantined):
            if u not in self.store:
                del self._quarantined[u]
            elif (
                self.store.user_version(u)
                != self._quarantined[u]["user_version"]
            ):
                del self._quarantined[u]

    def attach_repairer(self, repairer: Callable[[str], bool]) -> None:
        """Install the auto-repair hook (see ``__init__``) and forget
        past repair failures — newly repairable faults get a fresh
        attempt."""
        self.repairer = repairer
        for info in self._quarantined.values():
            info.pop("repair_failed", None)

    def _try_repair(self, user_id: str) -> bool:
        """Attempt auto-repair of one user's delta.  True = the repairer
        repaired AND re-registered the delta (caller re-probes before
        serving — release is verified, never assumed).  A raise or False
        from the repairer marks the user's quarantine entry
        ``repair_failed`` so the attempt is not repeated every batch."""
        if self.repairer is None:
            return False
        info = self._quarantined.get(user_id)
        if info is not None and info.get("repair_failed"):
            return False
        self.repair_attempts += 1
        try:
            ok = bool(self.repairer(user_id))
        except Exception as exc:  # noqa: BLE001 — typed UnrepairableError
            # and any unexpected repairer fault both mean "not repaired"
            self.last_repair_error = f"{type(exc).__name__}: {exc}"
            ok = False
        if ok:
            self.repairs += 1
            self._quarantined.pop(user_id, None)
        elif info is not None:
            info["repair_failed"] = True
        return ok

    def _probe_block_trees(self, engine: str | None) -> int:
        """Tree-block size the health probe decodes with — matched to the
        engine the batch will run under, so the probe's decoded tiles land
        in the same ``TileCache`` entries the engine reads (the probe is
        then warm-up, not extra work)."""
        name = engine or (
            "simple" if self.store.arena is None else "pipelined"
        )
        return ENGINE_BLOCKS.get(name, (8, 128))[0]

    def _probe_user(self, user_id: str, block_trees: int) -> Exception | None:
        """Decode one user's tiles end to end (entropy decode included);
        returns the exception on failure.  ``KeyError`` (unknown user) is
        a caller bug, not a data fault, and propagates."""
        try:
            self.store.tiles(user_id, block_trees)
            return None
        except KeyError:
            raise
        except Exception as e:  # noqa: BLE001 — any decode fault
            # quarantines (FramingError, EOF in entropy decode, shape
            # mismatches from logically-corrupt streams, ...)
            return e

    def _serve_with_retry(
        self, requests: Sequence[Request], **kwargs
    ) -> tuple[list[np.ndarray], bool]:
        """``serve`` with bounded exponential backoff on transient arena
        admission faults; when retries exhaust, degrade the batch to the
        arena-free ``simple`` engine (exact result, no device residency)
        rather than failing it.  Returns ``(predictions, degraded)``."""
        from ..runtime.chaos import TransientError

        for attempt in range(self.max_retries + 1):
            try:
                return self.serve(requests, **kwargs), False
            except TransientError:
                self.transient_retries += 1
                if attempt < self.max_retries:
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
        self.degraded_batches += 1
        kwargs = dict(kwargs)
        kwargs["engine"] = "simple"
        return self.serve(requests, **kwargs), True

    def serve_safe(
        self,
        requests: Sequence[Request],
        engine: str | None = None,
        block_trees: int | None = None,
        block_obs: int | None = None,
        interpret: bool | None = None,
    ) -> list[RequestStatus]:
        """Fault-isolating ``serve``: one typed ``RequestStatus`` per
        request, in request order.

        Users whose deltas fail integrity checks or entropy decode are
        QUARANTINED — their requests come back ``status="quarantined"``
        with the failure in ``detail``, while every healthy user in the
        batch is served normally (one bad delta must not fail the
        batch).  Quarantine is sticky across batches until the user's
        delta changes (re-registration or migration bumps their registry
        version, triggering a re-probe) or ``release_quarantine``.
        Transient arena admission faults are retried with exponential
        backoff; if they persist, the batch degrades to the arena-free
        simple engine (exact predictions, no device residency) instead
        of failing."""
        if not requests:
            return []
        self._refresh_quarantine()
        probe_bt = block_trees or self._probe_block_trees(engine)
        for u in dict.fromkeys(u for u, _ in requests):
            if u in self._quarantined:
                # quarantine -> repair -> verify -> release (ISSUE 8):
                # a successful repair re-registers the delta; the probe
                # below then re-verifies the decode end to end before
                # the user is served again
                if not self._try_repair(u):
                    continue
            exc = self._probe_user(u, probe_bt)
            if exc is not None and self._try_repair(u):
                exc = self._probe_user(u, probe_bt)
            if exc is not None:
                was_attempted = self.repairer is not None
                self._quarantine(u, exc)
                if was_attempted:
                    # repair already failed (or did not survive the
                    # re-probe) — don't retry it every batch
                    self._quarantined[u]["repair_failed"] = True
        healthy = [
            (u, x) for u, x in requests if u not in self._quarantined
        ]
        preds: list[np.ndarray] = []
        degraded = False
        if healthy:
            preds, degraded = self._serve_with_retry(
                healthy, engine=engine, block_trees=block_trees,
                block_obs=block_obs, interpret=interpret,
            )
        it = iter(preds)
        out: list[RequestStatus] = []
        for u, _ in requests:
            if u in self._quarantined:
                out.append(RequestStatus(
                    user_id=u, status="quarantined",
                    detail=self._quarantined[u]["reason"],
                ))
            else:
                out.append(RequestStatus(
                    user_id=u, status="ok", prediction=next(it),
                    degraded=degraded,
                ))
        return out

    def predict(
        self, x_binned: np.ndarray, user_id: str | None = None, **kwargs
    ) -> np.ndarray:
        """Single-user convenience: one request, one prediction array.
        ``user_id`` defaults to the sole user of a one-user session."""
        if user_id is None:
            users = self.store.user_ids
            if len(users) != 1:
                raise ValueError(
                    f"store has {len(users)} users; pass user_id"
                )
            user_id = users[0]
        x = np.ascontiguousarray(x_binned, np.int32)
        return self.serve([(user_id, x)], **kwargs)[0]

    def stats(self) -> dict:
        """One dict for admission-control dashboards: arena occupancy,
        tile-cache per-user hit rates, plan-cache hit/miss counts, engine
        usage, the store's codebook-lifecycle drift summary (generation +
        fallback-cluster fraction — ``None`` for single-forest sessions;
        quarantined users are EXCLUDED from drift accounting, not counted
        as fallback users), the store's lossy report when quantization is
        on, the ``residency`` section when a residency budget is
        attached (``store.residency.attach_residency`` — ``None``
        otherwise), and the ``health`` section: quarantine set,
        integrity/retry/degradation counters, and the recluster journal
        state when a journaled lifecycle operation has run."""
        arena = self.store.arena
        journal = getattr(self.store, "journal", None)
        residency = getattr(self.store, "residency", None)
        return {
            "engine_counts": dict(self.engine_counts),
            "engine_timings": self.engine_timings(),
            "plan_cache": self.plan_cache.stats(),
            "tile_cache": self.store.cache.stats(),
            "arena": arena.stats() if arena is not None else None,
            "store": self.store.drift_stats(
                exclude=tuple(sorted(self._quarantined))
            ),
            "lossy": getattr(self.store, "lossy", None),
            "residency": (
                residency.stats() if residency is not None else None
            ),
            "health": {
                "n_quarantined": len(self._quarantined),
                "quarantined": {
                    u: {
                        "reason": info["reason"], "kind": info["kind"],
                    }
                    for u, info in sorted(self._quarantined.items())
                },
                "integrity_failures": self.integrity_failures,
                "transient_retries": self.transient_retries,
                "degraded_batches": self.degraded_batches,
                "repair_attempts": self.repair_attempts,
                "repairs": self.repairs,
                "last_repair_error": self.last_repair_error,
                "max_retries": self.max_retries,
                "retry_backoff_s": self.retry_backoff_s,
                "journal": (
                    journal.summary() if journal is not None else None
                ),
            },
        }

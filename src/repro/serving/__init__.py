"""repro.serving — the unified serving session API (ISSUE 4).

``ForestServer`` is the one public way to serve predictions from the
compressed format (paper §5): it owns the store, the device tile arena,
the decoded tile cache, and a cross-batch plan cache, and splits every
request batch into an explicit plan/execute IR —

    server = ForestServer(store)          # or .from_forest(comp)
    plan = server.plan(requests)          # grouping + cost-model engine
    preds = server.execute(plan, X)       # pack -> gather -> kernel ->
                                          # finalize

``serve_safe`` is the fault-isolating variant (ISSUE 6): per-request
typed statuses, quarantine of integrity-failing users, bounded retry +
degradation on transient arena faults.  The PR 1-3 legacy entry points
(``serve_compressed_forest``, ``serve_store_batch``) have been removed;
``core.compressed_predict.predict_compressed`` remains the pure
decode-side reference oracle every engine is verified against.
"""

from .cache import PlanCache
from .pack import iter_heap_tiles, pad_heap_width, tree_to_heap
from .plan import ENGINE_BLOCKS, EngineChoice, ServePlan, choose_engine
from .server import ForestServer, RequestStatus, SingleForestStore

__all__ = [
    "ENGINE_BLOCKS",
    "EngineChoice",
    "ForestServer",
    "PlanCache",
    "RequestStatus",
    "ServePlan",
    "SingleForestStore",
    "choose_engine",
    "iter_heap_tiles",
    "pad_heap_width",
    "tree_to_heap",
]

"""The serving session's plan IR (ISSUE 4 tentpole).

``ForestServer.plan(requests)`` compiles a mixed-user request batch into an
explicit ``ServePlan``: grouped users (segment ids), the segment-sort
permutation, per-request row slices, padded shapes, and a resolved
``EngineChoice`` picked by a COST MODEL instead of string kwargs.  Plans
are pure host metadata — hashable by the batch's user-run signature — so
``PlanCache`` can memoize both the plan and (keyed by the same signature)
the arena-gathered device pack it resolves to at execute time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .pack import batch_layout

#: Per-engine (block_trees, block_obs) sweet spots (PR 3 tuning).
ENGINE_BLOCKS = {
    "simple": (32, 256),
    "pipelined": (8, 128),
    "sharded": (8, 128),
}

#: Sharding only pays when the greedy bin-pack spreads the batch's trees
#: reasonably evenly — below this predicted speedup the collective plus
#: replicated-batch overhead wins and the cost model stays single-device.
MIN_SHARD_SPEEDUP = 1.3

#: Tree totals below this aren't worth a cross-device collective.
MIN_SHARD_TREES = 64


@dataclass(frozen=True)
class EngineChoice:
    """A resolved serving engine: name + block sizes.  ``reason`` records
    why the cost model picked it (excluded from equality/hash so plans
    keyed on the choice don't fragment on prose)."""

    name: str  # "simple" | "pipelined" | "sharded"
    block_trees: int
    block_obs: int
    reason: str = field(default="", compare=False)


@dataclass
class ServePlan:
    """The plan half of the plan/execute IR: everything about a request
    batch that does not depend on the row VALUES — grouping, sort order,
    padded shapes, engine choice — plus the hashable ``signature`` the
    cross-batch ``PlanCache`` keys gathered packs by."""

    signature: tuple  # ((user, rows)..., engine, block_trees, block_obs)
    user_tokens: tuple[int, ...]  # per-user versions (aligned with users):
    # the plan's validity token — only a change to one of ITS users'
    # registrations makes it stale (partial invalidation)
    request_users: tuple[str, ...]
    row_counts: tuple[int, ...]
    users: tuple[str, ...]  # first-appearance order == segment ids
    seg_trees: np.ndarray  # (S,) int64 per-user tree counts
    row_slices: tuple[slice, ...]
    n_rows: int
    obs_seg: np.ndarray  # (N,) int32 segment id per row (request order)
    order: np.ndarray  # stable segment-sort permutation
    oseg_s: np.ndarray  # (N,) int32 sorted segment ids
    engine: EngineChoice
    t_pad: int  # tree rows after padding to a block_trees multiple
    n_row_blocks: int  # ceil(N / block_obs) — the kernel grid's row axis

    @property
    def n_users(self) -> int:
        return len(self.users)


def choose_engine(
    store,
    seg_trees: np.ndarray,
    n_rows: int,
    engine: str | None = None,
    block_trees: int | None = None,
    block_obs: int | None = None,
) -> EngineChoice:
    """Resolve the engine for a batch.  ``engine=None`` asks the cost
    model: ``simple`` when the store schema cannot use the fused arena,
    ``sharded`` when >1 device AND the greedy bin-pack predicts at least
    ``MIN_SHARD_SPEEDUP`` over one device, else ``pipelined``.  Explicit
    names are validated but honoured (the escape hatch the legacy string
    kwargs become)."""
    if engine is not None:
        if engine not in ENGINE_BLOCKS:
            raise ValueError(f"unknown serving engine {engine!r}")
        if engine != "simple" and store.arena is None:
            raise ValueError(
                f"engine={engine!r} needs the fused tile arena, which this "
                "store's schema cannot use (packed code word >= 2**24); "
                "use engine='simple'"
            )
        reason = "explicitly requested"
    elif store.arena is None:
        engine = "simple"
        reason = "store schema cannot pack the fused arena layout"
    else:
        import jax

        n_dev = len(jax.devices())
        total_trees = int(np.asarray(seg_trees).sum())
        if n_dev <= 1:
            engine, reason = "pipelined", "single device"
        elif total_trees < MIN_SHARD_TREES:
            engine = "pipelined"
            reason = (
                f"{total_trees} trees below the {MIN_SHARD_TREES}-tree "
                "sharding floor"
            )
        else:
            from ..kernels.tree_predict.ops import estimate_shard_speedup

            speedup = estimate_shard_speedup(seg_trees, n_dev)
            if speedup >= MIN_SHARD_SPEEDUP:
                engine = "sharded"
                reason = (
                    f"{n_dev} devices, predicted {speedup:.2f}x from the "
                    "tree bin-pack"
                )
            else:
                engine = "pipelined"
                reason = (
                    f"shard load imbalance (predicted {speedup:.2f}x < "
                    f"{MIN_SHARD_SPEEDUP}x)"
                )
    bt_default, bo_default = ENGINE_BLOCKS[engine]
    return EngineChoice(
        engine,
        bt_default if block_trees is None else int(block_trees),
        bo_default if block_obs is None else int(block_obs),
        reason,
    )


def build_plan(
    store,
    request_users: Sequence[str],
    row_counts: Sequence[int],
    engine: str | None = None,
    block_trees: int | None = None,
    block_obs: int | None = None,
) -> ServePlan:
    """Compile a batch signature into a ``ServePlan`` (pure host work)."""
    request_users = tuple(request_users)
    row_counts = tuple(int(n) for n in row_counts)
    users, _seg_of, obs_seg, row_slices, order, oseg_s = batch_layout(
        request_users, row_counts
    )
    seg_trees = np.array(
        [store.n_trees(u) for u in users], np.int64
    ) if users else np.zeros(0, np.int64)
    n_rows = int(obs_seg.shape[0])
    choice = choose_engine(
        store, seg_trees, n_rows,
        engine=engine, block_trees=block_trees, block_obs=block_obs,
    )
    t = int(seg_trees.sum())
    t_pad = max(
        -(-t // choice.block_trees) * choice.block_trees, choice.block_trees
    )
    bo = min(choice.block_obs, n_rows) if n_rows else choice.block_obs
    signature = (
        tuple(zip(request_users, row_counts)),
        choice.name, choice.block_trees, choice.block_obs,
    )
    return ServePlan(
        signature=signature,
        user_tokens=tuple(store.user_version(u) for u in users),
        request_users=request_users,
        row_counts=row_counts,
        users=tuple(users),
        seg_trees=seg_trees,
        row_slices=tuple(row_slices),
        n_rows=n_rows,
        obs_seg=obs_seg,
        order=order,
        oseg_s=oseg_s,
        engine=choice,
        t_pad=t_pad,
        n_row_blocks=max(-(-n_rows // bo), 1) if n_rows else 0,
    )

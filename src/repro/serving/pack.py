"""Host-side packing for the serving session API (ISSUE 4).

One canonical home for the request-batch layout machinery that PR 1-3
scattered across ``launch/serve_forest.py`` and ``launch/serve_store.py``:

* ``pad_heap_width`` — THE heap-width padding helper (previously duplicated
  between ``serve_store._pad_heap_width`` and the arena's pad path);
* ``tree_to_heap`` / ``iter_heap_tiles`` — compressed bytes → heap-form
  tree tiles (moved from ``launch.serve_forest``, which re-exports them);
* ``batch_layout`` / ``group_requests`` — mixed-user request batches →
  segment ids, row slices, and the segment-sort permutation;
* ``pack_host_tiles`` — the PR 2 host tile pack kept for the ``simple``
  engine (the differential oracle / baseline).

Everything here is pure host work over numpy arrays — the plan side of the
plan/execute split.  Device gathers live in ``serving.engines``.
"""
from __future__ import annotations

from typing import Iterator, Sequence

import numpy as np

from ..core.forest_codec import CompressedForest
from ..core.tree import Tree

Request = tuple[str, np.ndarray]
Tile = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def pad_heap_width(tile_arr: np.ndarray, h: int) -> np.ndarray:
    """Pad a (t, h_u) heap-form tile to heap width ``h`` with zero columns
    (no copy when the width already matches — the hot fleet path).  The one
    canonical implementation; ``launch.serve_store`` and the device arena
    both route through it."""
    t, h_u = tile_arr.shape
    if h_u == h:
        return tile_arr
    if h_u > h:
        raise ValueError(f"cannot shrink heap width {h_u} -> {h}")
    out = np.zeros((t, h), dtype=tile_arr.dtype)
    out[:, :h_u] = tile_arr
    return out


def tree_to_heap(
    tree: Tree,
    fit_values: np.ndarray | None,
    feature: np.ndarray,
    threshold: np.ndarray,
    fit: np.ndarray,
    is_internal: np.ndarray,
) -> None:
    """Write one preorder compact tree into heap-form rows (node i ->
    children 2i+1 / 2i+2), the layout the Pallas kernel traverses."""
    stack = [(0, 0)]  # (preorder node id, heap slot)
    left, right = tree.children_left, tree.children_right
    feat, thr, nfit = tree.feature, tree.threshold, tree.node_fit
    while stack:
        i, slot = stack.pop()
        if feat[i] >= 0:
            feature[slot] = feat[i]
            threshold[slot] = thr[i]
            is_internal[slot] = True
            stack.append((int(right[i]), 2 * slot + 2))
            stack.append((int(left[i]), 2 * slot + 1))
        elif fit_values is not None:
            fit[slot] = fit_values[int(nfit[i])]
        else:
            fit[slot] = float(nfit[i])


def iter_heap_tiles(
    comp: CompressedForest, block_trees: int
) -> Iterator[Tile]:
    """Stream (feature, threshold, fit, is_internal) heap tiles of up to
    ``block_trees`` trees each, decoded on the fly from the compressed
    bytes — host memory holds one tile, not the forest."""
    from ..core.compressed_predict import iter_trees

    n_heap = (1 << (comp.max_depth + 1)) - 1
    fit_values = (
        comp.fit_values if comp.meta.task == "regression" else None
    )
    buf: list[Tree] = []

    def pack(trees: list[Tree]) -> Tile:
        t = len(trees)
        feature = np.zeros((t, n_heap), np.int32)
        threshold = np.zeros((t, n_heap), np.int32)
        fit = np.zeros((t, n_heap), np.float32)
        is_internal = np.zeros((t, n_heap), bool)
        for k, tree in enumerate(trees):
            tree_to_heap(
                tree, fit_values,
                feature[k], threshold[k], fit[k], is_internal[k],
            )
        return feature, threshold, fit, is_internal

    for tree in iter_trees(comp):
        buf.append(tree)
        if len(buf) == block_trees:
            yield pack(buf)
            buf = []
    if buf:
        yield pack(buf)


def batch_layout(
    request_users: Sequence[str], row_counts: Sequence[int]
):
    """Row bookkeeping for a mixed-user batch, from the batch SIGNATURE
    alone (user ids + per-request row counts — no row data needed, so a
    ``ServePlan`` can be built and cached without touching X).

    Returns ``(users, seg_of, obs_seg, row_slices, order, oseg_s)``:
    users in first-appearance order (their position IS their segment id),
    the per-row segment id array, per-request row slices into the
    concatenated block, the stable segment-sort permutation, and the
    sorted segment ids."""
    users: list[str] = []
    seg_of: dict[str, int] = {}
    for user_id in request_users:
        if user_id not in seg_of:
            seg_of[user_id] = len(users)
            users.append(user_id)
    oseg_parts, row_slices = [], []
    off = 0
    for user_id, n in zip(request_users, row_counts):
        oseg_parts.append(np.full(int(n), seg_of[user_id], np.int32))
        row_slices.append(slice(off, off + int(n)))
        off += int(n)
    obs_seg = (
        np.concatenate(oseg_parts) if oseg_parts else np.zeros(0, np.int32)
    )
    order = np.argsort(obs_seg, kind="stable")
    return users, seg_of, obs_seg, row_slices, order, obs_seg[order]


def group_requests(requests: Sequence[Request]):
    """Legacy-shaped grouping (rows included): users, seg_of, the (N, d)
    int32 row block, per-row segment ids, per-request row slices."""
    users, seg_of, obs_seg, row_slices, _, _ = batch_layout(
        [u for u, _ in requests], [len(x) for _, x in requests]
    )
    xb_parts = [np.ascontiguousarray(x, np.int32) for _, x in requests]
    xb = (
        np.concatenate(xb_parts) if xb_parts else np.zeros((0, 0), np.int32)
    )
    return users, seg_of, xb, obs_seg, row_slices


def concat_rows(X: Sequence[np.ndarray]) -> np.ndarray:
    """Concatenate per-request row blocks into one (N, d) int32 array."""
    parts = [np.ascontiguousarray(x, np.int32) for x in X]
    return np.concatenate(parts) if parts else np.zeros((0, 0), np.int32)


def pack_host_tiles(store, users: Sequence[str], block_trees: int = 32):
    """The PR 2 host tile pack (``engine="simple"``): every requested
    user's decoded heap tiles concatenated at the batch-max heap width.

    Returns ``(tree_pack, max_depth, seg_trees)`` where ``tree_pack`` is
    ``(feature, threshold, fit, is_internal, tree_seg)`` and
    ``seg_trees[s]`` is user s's tree count.  Re-padding only happens for
    users whose heap width differs from the batch maximum
    (``pad_heap_width`` is a no-op otherwise)."""
    max_depth = max(store.max_depth(u) for u in users)
    h = (1 << (max_depth + 1)) - 1
    feats, thrs, fits, inters, tsegs = [], [], [], [], []
    for s, user_id in enumerate(users):
        for feature, threshold, fit, is_internal in store.tiles(
            user_id, block_trees
        ):
            feats.append(pad_heap_width(feature, h))
            thrs.append(pad_heap_width(threshold, h))
            fits.append(pad_heap_width(fit, h))
            inters.append(pad_heap_width(is_internal, h))
            tsegs.append(np.full(feature.shape[0], s, np.int32))
    tree_pack = (
        np.concatenate(feats),
        np.concatenate(thrs),
        np.concatenate(fits),
        np.concatenate(inters),
        np.concatenate(tsegs),
    )
    seg_trees = np.array([store.n_trees(u) for u in users], np.int64)
    return tree_pack, max_depth, seg_trees

"""Engine execution for the serving session (ISSUE 4).

The three engines PR 2/3 grew inside ``launch.serve_store`` now execute a
``ServePlan`` against a row block: each takes ``(store, plan, xb)`` and
returns the raw per-row aggregate (``(N, C)`` vote counts or ``(N,)`` fit
sums) in ORIGINAL request order — the server's finalize step turns that
into per-request predictions.

The pipelined and sharded engines split into ``build_*_pack`` (arena
ensure + device index-gather + chunk ranges — the part ``PlanCache``
memoizes across batches) and ``run_*`` (the kernel launch, paid per
batch).  ``run_simple`` is the PR 2 host-pack path kept verbatim as the
differential oracle and benchmark baseline.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .pack import pack_host_tiles
from .plan import ServePlan


def _n_classes(store) -> int:
    shared = store.shared
    return shared.n_classes if shared.task == "classification" else 0


class PipelinedPack(NamedTuple):
    """Arena-gathered device arrays + chunk ranges for one plan — the
    cross-batch memoizable artifact of the pipelined engine."""

    code: object  # (T_pad, H) f32 device
    fit: object  # (T_pad, H) f32 device
    tree_seg: np.ndarray  # (T_pad,) int32, -1 padding
    counts: np.ndarray  # (S,) int64
    max_depth: int
    chunk_lo: np.ndarray  # (ceil(N / block_obs),) int32
    chunk_hi: np.ndarray
    block_obs: int  # block_obs AFTER the min(N) clamp


class ShardedPack(NamedTuple):
    """Per-device stacked gathers + ranges for the sharded engine."""

    code: object  # (S_dev, T_pad, H) f32 device
    fit: object
    tree_seg: np.ndarray  # (S_dev, T_pad) int32
    chunk_lo: np.ndarray  # (S_dev, G) int32
    chunk_hi: np.ndarray
    max_depth: int
    block_obs: int


# ---------------------------------------------------------------------------
# simple — the PR 2 oracle: host tile pack + one launch per tree chunk
# ---------------------------------------------------------------------------

def run_simple(
    store, plan: ServePlan, xb: np.ndarray, interpret: bool | None = None
) -> np.ndarray:
    """Host pack + one segmented-kernel launch per tree chunk over that
    chunk's row span.  Returns the (N, C) / (N,) aggregate in original
    request order."""
    from ..kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented,
    )

    block_trees = plan.engine.block_trees
    block_obs = plan.engine.block_obs
    tree_pack, max_depth, _seg_trees = pack_host_tiles(
        store, plan.users, block_trees
    )
    feature, threshold, fit, is_internal, tree_seg = tree_pack
    n_classes = _n_classes(store)
    n, c_out = plan.n_rows, max(n_classes, 1)
    t = feature.shape[0]

    # Segments only overlap block-diagonally: sort rows by segment and run
    # each tree chunk against just the row span of the users it contains —
    # work stays ~sum_u T_u * N_u instead of T_total * N_total, while one
    # launch still serves several users' trees (the segment mask sorts out
    # chunk-boundary users).  Spans are padded to block_obs multiples (rows)
    # and block_trees (trees) with non-matching sentinel segments, so the
    # jitted kernel sees a handful of distinct shapes, not one per span.
    xb_s = np.ascontiguousarray(xb[plan.order])
    oseg_s = plan.oseg_s
    n_segs = plan.n_users
    seg_start = np.searchsorted(oseg_s, np.arange(n_segs))
    seg_end = np.searchsorted(oseg_s, np.arange(n_segs), side="right")

    total_sorted = np.zeros(
        (n, c_out) if n_classes > 0 else (n,), np.float64
    )
    parts: list[tuple[int, int, object]] = []
    for lo in range(0, t, block_trees):
        hi = min(lo + block_trees, t)
        r0 = int(seg_start[int(tree_seg[lo])])
        r1 = int(seg_end[int(tree_seg[hi - 1])])
        if r1 <= r0:
            continue
        n_rows = r1 - r0
        n_pad = min(-(-n_rows // block_obs) * block_obs, n)
        r1p = min(r0 + n_pad, n)
        r0p = r1p - n_pad  # slide the window instead of materializing pads
        chunk = [tree_seg[lo:hi], feature[lo:hi], threshold[lo:hi],
                 fit[lo:hi], is_internal[lo:hi]]
        if hi - lo < block_trees:  # pad tail chunk to the common tree shape
            pad_t = block_trees - (hi - lo)
            chunk[0] = np.concatenate(
                [chunk[0], np.full(pad_t, -1, np.int32)]
            )
            for i in range(1, 5):
                chunk[i] = np.concatenate(
                    [chunk[i], np.zeros((pad_t,) + chunk[i].shape[1:],
                                        chunk[i].dtype)]
                )
        tseg_c, feat_c, thr_c, fit_c, inter_c = chunk
        part = forest_predict_agg_segmented(
            xb_s[r0p:r1p],
            oseg_s[r0p:r1p],
            tseg_c,
            feat_c,
            thr_c,
            fit_c,
            inter_c,
            max_depth=max_depth,
            n_classes=n_classes,
            block_trees=block_trees,
            block_obs=block_obs,
            interpret=interpret,
            engine="simple",
        )  # dispatched async; host keeps slicing/submitting
        parts.append((r0p, r1p, part))
    for r0p, r1p, part in parts:
        total_sorted[r0p:r1p] += np.asarray(part, np.float64)
    total = np.empty_like(total_sorted)
    total[plan.order] = total_sorted
    return total


# ---------------------------------------------------------------------------
# pipelined — arena index-gather + ONE double-buffered DMA launch
# ---------------------------------------------------------------------------

def build_pipelined_pack(store, plan: ServePlan) -> PipelinedPack:
    """The gather stage: ensure residency, index-gather the plan's users'
    runs, compute per-row-block chunk ranges.  Memoized by ``PlanCache``
    keyed on the plan signature, validated per user (registry version +
    arena run token)."""
    from ..kernels.tree_predict.tree_predict import segment_chunk_ranges

    bt = plan.engine.block_trees
    code, fit, tree_seg, counts, max_depth = store.arena_pack(
        list(plan.users), bt
    )
    bo = min(plan.engine.block_obs, plan.n_rows)
    chunk_lo, chunk_hi = segment_chunk_ranges(
        plan.oseg_s, tree_seg, bt, bo
    )
    return PipelinedPack(
        code, fit, tree_seg, counts, max_depth, chunk_lo, chunk_hi, bo
    )


def run_pipelined(
    store,
    plan: ServePlan,
    pack: PipelinedPack,
    xb: np.ndarray,
    interpret: bool | None = None,
) -> np.ndarray:
    """The single double-buffered DMA kernel launch over a (possibly
    cached) gathered pack.  Returns the aggregate in request order."""
    from ..kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented_packed,
    )

    xb_s = np.ascontiguousarray(xb[plan.order])
    out = forest_predict_agg_segmented_packed(
        xb_s, plan.oseg_s, pack.code, pack.fit, pack.tree_seg,
        pack.chunk_lo, pack.chunk_hi, pack.max_depth, store.arena.tb2,
        n_classes=_n_classes(store),
        block_trees=plan.engine.block_trees, block_obs=pack.block_obs,
        interpret=interpret,
    )
    out = np.asarray(out, np.float64)
    total = np.empty_like(out)
    total[plan.order] = out
    return total


# ---------------------------------------------------------------------------
# sharded — tree axis partitioned across devices + one psum
# ---------------------------------------------------------------------------

def build_sharded_pack(store, plan: ServePlan) -> ShardedPack:
    """Per-device gathers under one shared width: admit the WHOLE batch
    before any per-shard gather (a later shard's cold admission may grow
    the arena heap width, which would leave earlier shards' gathered
    arrays at a stale narrower width), bin-pack users by tree count, then
    gather each shard with GLOBAL segment ids."""
    import jax
    import jax.numpy as jnp

    from ..kernels.tree_predict.ops import partition_segments_by_load
    from ..kernels.tree_predict.tree_predict import segment_chunk_ranges

    bt = plan.engine.block_trees
    n_dev = len(jax.devices())
    store.arena_ensure(list(plan.users), bt)
    shards = partition_segments_by_load(plan.seg_trees, n_dev)
    # per-shard users ascend by segment id: sorted rows keep ranges tight
    shards = [sorted(s) for s in shards]
    t_pad = max(
        max(
            (-(-int(plan.seg_trees[s].sum()) // bt) * bt
             for s in map(np.asarray, shards) if len(s)),
            default=bt,
        ),
        bt,
    )
    bo = min(plan.engine.block_obs, plan.n_rows)
    codes, fits, tsegs, los, his = [], [], [], [], []
    max_depth = 0
    for shard in shards:
        shard_users = [plan.users[s] for s in shard]
        code, fit, tseg, _, max_depth = store.arena_pack(
            shard_users, bt, pad_to=t_pad, seg_ids=shard
        )
        lo, hi = segment_chunk_ranges(plan.oseg_s, tseg, bt, bo)
        codes.append(code)
        fits.append(fit)
        tsegs.append(tseg)
        los.append(lo)
        his.append(hi)
    return ShardedPack(
        jnp.stack(codes), jnp.stack(fits), np.stack(tsegs),
        np.stack(los), np.stack(his), max_depth, bo,
    )


def run_sharded(
    store,
    plan: ServePlan,
    pack: ShardedPack,
    xb: np.ndarray,
    interpret: bool | None = None,
) -> np.ndarray:
    """Per-device pipelined partials + one psum all-reduce."""
    from ..kernels.tree_predict.ops import (
        forest_predict_agg_segmented_sharded,
    )

    xb_s = np.ascontiguousarray(xb[plan.order])
    out = forest_predict_agg_segmented_sharded(
        xb_s, plan.oseg_s, pack.code, pack.fit, pack.tree_seg,
        pack.chunk_lo, pack.chunk_hi, pack.max_depth, store.arena.tb2,
        n_classes=_n_classes(store),
        block_trees=plan.engine.block_trees, block_obs=pack.block_obs,
        interpret=interpret,
    )
    out = np.asarray(out, np.float64)
    total = np.empty_like(out)
    total[plan.order] = out
    return total

"""Cross-batch plan/pack memoization with PER-USER invalidation (ISSUE 4
tentpole, refined by ISSUE 5's partial invalidation — the ROADMAP
"plan-cache partial invalidation" item).

Two LRU maps, both keyed by the batch's user-run signature
(``ServePlan.signature``) and validated by a TOKEN the serving session
derives from the users the entry covers:

* PLANS — the host-side IR (grouping, sort permutation, engine choice).
  Token: the tuple of the store's PER-USER registration versions for the
  batch's users.  Re-registering (or migrating) one user invalidates only
  plans containing that user.
* PACKS — the arena-gathered device arrays + chunk ranges a plan resolves
  to at execute time.  Token: per user, the pair (store user version,
  arena run-admission token).  A pack survives exactly while every one of
  its users is still resident with unchanged content — so a codebook
  migration or arena eviction touching user A leaves user B's warm packs
  alone, while evicted users' gathered device copies are still swept
  eagerly (``sweep_packs``) so they cannot survive as hidden copies and
  defeat the arena's capacity bound.

A hot repeated batch therefore skips grouping, the argsort, the device
index-gather, and the chunk-range computation — it pays only the row
upload, the kernel, and the finalize.

The cache is THREAD-SAFE (one lock around every map operation): the
scheduler's pipelined executor (ISSUE 7) pre-plans batch *k+1* on the
submit thread while the worker thread plans/executes batch *k*, and both
paths go through this memo.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable

from ..runtime.guards import guarded_by


@guarded_by(
    "_lock",
    "_plans", "_packs",
    "plan_hits", "plan_misses", "pack_hits", "pack_misses",
    "invalidations",
)
class PlanCache:
    """LRU memo of ServePlans and their gathered packs, with per-user
    token invalidation and hit/miss accounting for admission-control
    dashboards."""

    def __init__(self, capacity: int = 64) -> None:
        self.capacity = capacity
        self._lock = threading.Lock()
        # signature -> (token, plan)
        self._plans: OrderedDict[tuple, tuple[tuple, Any]] = OrderedDict()
        # signature -> (users, token, pack)
        self._packs: OrderedDict[
            tuple, tuple[tuple, tuple, Any]
        ] = OrderedDict()
        self.plan_hits = 0
        self.plan_misses = 0
        self.pack_hits = 0
        self.pack_misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._packs)

    # ---------------- plans -----------------------------------------------
    def get_plan(self, key: tuple, token: tuple):
        """The memoized plan under ``key``, provided its per-user token
        still matches; a mismatch drops the entry (counted as an
        invalidation) and misses."""
        with self._lock:
            entry = self._plans.get(key)
            if entry is not None and entry[0] != token:
                del self._plans[key]
                self.invalidations += 1
                entry = None
            if entry is None:
                self.plan_misses += 1
                return None
            self._plans.move_to_end(key)
            self.plan_hits += 1
            return entry[1]

    def put_plan(self, key: tuple, token: tuple, plan) -> None:
        """Memoize ``plan`` under ``key`` with its validity ``token``."""
        with self._lock:
            self._plans[key] = (token, plan)
            self._plans.move_to_end(key)
            while len(self._plans) > self.capacity:
                self._plans.popitem(last=False)

    # ---------------- gathered packs --------------------------------------
    def sweep_packs(
        self, current_token_of: Callable[[tuple], tuple]
    ) -> None:
        """Drop every pack whose users' current token no longer matches
        the one it was stored under.  Sweeping eagerly (not just the
        queried key) keeps evicted users' gathered device arrays from
        surviving as hidden copies, which would defeat the arena's
        capacity bound — but packs whose users are untouched stay put
        (partial invalidation)."""
        with self._lock:
            stale = [
                k for k, (users, token, _) in self._packs.items()
                if current_token_of(users) != token
            ]
            for k in stale:
                del self._packs[k]
            self.invalidations += len(stale)

    def get_pack(self, key: tuple, token: tuple):
        """The memoized gathered pack under ``key``, provided its per-user
        token still matches (callers sweep first; the token check here
        guards the queried entry itself)."""
        with self._lock:
            entry = self._packs.get(key)
            if entry is not None and entry[1] != token:
                del self._packs[key]
                self.invalidations += 1
                entry = None
            if entry is None:
                self.pack_misses += 1
                return None
            self._packs.move_to_end(key)
            self.pack_hits += 1
            return entry[2]

    def put_pack(
        self, key: tuple, users: tuple, token: tuple, pack
    ) -> None:
        """Memoize a gathered ``pack`` for ``users`` under ``key`` with
        its per-user validity ``token``."""
        with self._lock:
            self._packs[key] = (users, token, pack)
            self._packs.move_to_end(key)
            while len(self._packs) > self.capacity:
                self._packs.popitem(last=False)

    # ---------------- maintenance -----------------------------------------
    def clear(self) -> None:
        """Drop every memoized plan and pack."""
        with self._lock:
            self._plans.clear()
            self._packs.clear()

    def stats(self) -> dict:
        """Hit/miss/invalidation counters for dashboards.  Reads under
        the lock: the scheduler's submit thread mutates these counters
        concurrently, and a stats snapshot must be one consistent state,
        not a torn mix of two (the ISSUE 9 lock-discipline fix)."""
        with self._lock:
            plan_total = self.plan_hits + self.plan_misses
            pack_total = self.pack_hits + self.pack_misses
            return {
                "plans": len(self._plans),
                "packs": len(self._packs),
                "plan_hits": self.plan_hits,
                "plan_misses": self.plan_misses,
                "plan_hit_rate": (
                    round(self.plan_hits / plan_total, 4)
                    if plan_total else 0.0
                ),
                "pack_hits": self.pack_hits,
                "pack_misses": self.pack_misses,
                "pack_hit_rate": (
                    round(self.pack_hits / pack_total, 4)
                    if pack_total else 0.0
                ),
                "invalidations": self.invalidations,
            }

"""Cross-batch plan/pack memoization (ISSUE 4 tentpole + the PR 3
"cross-batch gather memoization" ROADMAP item).

Two LRU maps, both keyed by the batch's user-run signature
(``ServePlan.signature``):

* PLANS — the host-side IR (grouping, sort permutation, engine choice).
  Valid while the store registry is unchanged (``store.version``).
* PACKS — the arena-gathered device arrays + chunk ranges a plan resolves
  to at execute time.  Valid while BOTH the registry version and the
  arena ``epoch`` are unchanged: any admission, eviction, compaction, or
  width growth bumps the epoch, so a cached gather can never be served
  stale (and evicted users' tiles don't survive as hidden copies, which
  would defeat the arena's capacity bound).

A hot repeated batch therefore skips grouping, the argsort, the device
index-gather, and the chunk-range computation — it pays only the row
upload, the kernel, and the finalize.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any


class PlanCache:
    """LRU memo of ServePlans and their gathered packs, with version/epoch
    invalidation and hit/miss accounting for admission-control dashboards."""

    def __init__(self, capacity: int = 64) -> None:
        self.capacity = capacity
        # signature -> (store_version, plan)
        self._plans: OrderedDict[tuple, tuple[int, Any]] = OrderedDict()
        # signature -> (store_version, arena_epoch, pack)
        self._packs: OrderedDict[tuple, tuple[int, int, Any]] = OrderedDict()
        self.plan_hits = 0
        self.plan_misses = 0
        self.pack_hits = 0
        self.pack_misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._packs)

    # ---------------- plans -----------------------------------------------
    def get_plan(self, key: tuple, store_version: int):
        entry = self._plans.get(key)
        if entry is not None and entry[0] != store_version:
            del self._plans[key]
            self.invalidations += 1
            entry = None
        if entry is None:
            self.plan_misses += 1
            return None
        self._plans.move_to_end(key)
        self.plan_hits += 1
        return entry[1]

    def put_plan(self, key: tuple, store_version: int, plan) -> None:
        self._plans[key] = (store_version, plan)
        self._plans.move_to_end(key)
        while len(self._plans) > self.capacity:
            self._plans.popitem(last=False)

    # ---------------- gathered packs --------------------------------------
    def _sweep_packs(self, store_version: int, arena_epoch: int) -> None:
        """Drop EVERY pack whose validity token mismatches — all packs
        share the one global (version, epoch) token, so after any arena
        change the whole set is stale at once.  Sweeping eagerly (not just
        the queried key) keeps evicted users' gathered device arrays from
        surviving as hidden copies, which would defeat the arena's
        capacity bound."""
        stale = [
            k for k, (v, e, _) in self._packs.items()
            if v != store_version or e != arena_epoch
        ]
        for k in stale:
            del self._packs[k]
        self.invalidations += len(stale)

    def get_pack(self, key: tuple, store_version: int, arena_epoch: int):
        self._sweep_packs(store_version, arena_epoch)
        entry = self._packs.get(key)
        if entry is None:
            self.pack_misses += 1
            return None
        self._packs.move_to_end(key)
        self.pack_hits += 1
        return entry[2]

    def put_pack(
        self, key: tuple, store_version: int, arena_epoch: int, pack
    ) -> None:
        self._sweep_packs(store_version, arena_epoch)
        self._packs[key] = (store_version, arena_epoch, pack)
        self._packs.move_to_end(key)
        while len(self._packs) > self.capacity:
            self._packs.popitem(last=False)

    # ---------------- maintenance -----------------------------------------
    def clear(self) -> None:
        self._plans.clear()
        self._packs.clear()

    def stats(self) -> dict:
        plan_total = self.plan_hits + self.plan_misses
        pack_total = self.pack_hits + self.pack_misses
        return {
            "plans": len(self._plans),
            "packs": len(self._packs),
            "plan_hits": self.plan_hits,
            "plan_misses": self.plan_misses,
            "plan_hit_rate": (
                round(self.plan_hits / plan_total, 4) if plan_total else 0.0
            ),
            "pack_hits": self.pack_hits,
            "pack_misses": self.pack_misses,
            "pack_hit_rate": (
                round(self.pack_hits / pack_total, 4) if pack_total else 0.0
            ),
            "invalidations": self.invalidations,
        }

"""Conditional empirical-model extraction (Algorithm 1, lines 4-21).

Model keys follow the paper's relaxation (§3.2.2): a node's model depends
only on its DEPTH and its FATHER'S VARIABLE NAME; split-value models
additionally condition on the node's own variable (and are clustered
per-variable, Algorithm 1 line 39).

Key id layout: ``kid = depth * (d + 1) + (father_var + 1)`` with
``father_var = -1`` for roots, so the model space has ``T * (d+1)`` slots
(the paper's d*T up to the root convention).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .tree import Forest, Tree


def key_id(depth: np.ndarray, father_var: np.ndarray, d: int) -> np.ndarray:
    return depth.astype(np.int64) * (d + 1) + (father_var.astype(np.int64) + 1)


@dataclass
class NodeRecords:
    """Flat per-node records over the whole forest, in global preorder
    (tree 0 nodes in preorder, then tree 1, ...) — the canonical symbol
    emission order for every stream."""

    tree_id: np.ndarray
    depth: np.ndarray
    father_var: np.ndarray  # -1 at roots
    var: np.ndarray  # -1 at leaves
    split: np.ndarray  # -1 at leaves
    fit: np.ndarray
    is_leaf: np.ndarray


def extract_records(forest: Forest) -> NodeRecords:
    ts, ds, fs, vs, sp, ft, lf = [], [], [], [], [], [], []
    for ti, tree in enumerate(forest.trees):
        depth = tree.depths()
        parent = tree.parents()
        fvar = np.where(parent >= 0, tree.feature[np.maximum(parent, 0)], -1)
        ts.append(np.full(tree.n_nodes, ti, dtype=np.int32))
        ds.append(depth)
        fs.append(fvar.astype(np.int32))
        vs.append(tree.feature)
        sp.append(tree.threshold)
        ft.append(tree.node_fit)
        lf.append(tree.is_leaf)
    return NodeRecords(
        tree_id=np.concatenate(ts),
        depth=np.concatenate(ds),
        father_var=np.concatenate(fs),
        var=np.concatenate(vs),
        split=np.concatenate(sp),
        fit=np.concatenate(ft),
        is_leaf=np.concatenate(lf),
    )


def var_name_counts(rec: NodeRecords, d: int, t_max: int) -> np.ndarray:
    """(T*(d+1), d+1) counts of P_vn = P(var | depth, father's var).

    Column d is the LEAF symbol: the Zaks sequence already distinguishes
    leaves, so leaves are NOT coded in the vars stream — but internal nodes
    are, with alphabet exactly the d variables. We therefore only count
    internal nodes, over alphabet d.
    """
    mask = ~rec.is_leaf
    kid = key_id(rec.depth[mask], rec.father_var[mask], d)
    sym = rec.var[mask].astype(np.int64)
    counts = np.zeros((t_max * (d + 1), d), dtype=np.int64)
    np.add.at(counts, (kid, sym), 1)
    return counts


def split_counts(rec: NodeRecords, d: int, t_max: int, n_bins: np.ndarray):
    """Per-variable dict: var -> (T*(d+1), B_v) counts of
    P_sv = P(split value | depth, var, father's var)."""
    out = {}
    for v in range(d):
        mask = (~rec.is_leaf) & (rec.var == v)
        if not mask.any():
            continue
        kid = key_id(rec.depth[mask], rec.father_var[mask], d)
        sym = rec.split[mask].astype(np.int64)
        counts = np.zeros((t_max * (d + 1), int(n_bins[v])), dtype=np.int64)
        np.add.at(counts, (kid, sym), 1)
        out[v] = counts
    return out


def fit_counts(rec: NodeRecords, d: int, t_max: int, n_fit_symbols: int):
    """(T*(d+1), n_fit_symbols) counts of P(fit | depth, father's var).
    Every node (internal AND leaf) carries a fit (§3.3)."""
    kid = key_id(rec.depth, rec.father_var, d)
    sym = rec.fit.astype(np.int64)
    counts = np.zeros((t_max * (d + 1), n_fit_symbols), dtype=np.int64)
    np.add.at(counts, (kid, sym), 1)
    return counts


def alpha_vars(d: int) -> float:
    """Paper: alpha = log2(d) + d for variable-name dictionaries."""
    return float(np.log2(max(d, 2)) + d)


def alpha_splits(meta_numeric: bool, n_train: int, c_v: int) -> float:
    """Numeric: log2(n) + C (split is an index into observed values);
    categorical: log2(C) + C."""
    if meta_numeric:
        return float(np.log2(max(n_train, 2)) + c_v)
    return float(np.log2(max(c_v, 2)) + c_v)


def alpha_fits(task: str, n_fit_symbols: int) -> float:
    """Classification: log2(#classes) + #classes.  Regression: each
    dictionary line carries a 64-bit value (paper's orthodox losslessness)
    plus the symbol id."""
    if task == "classification":
        return float(np.log2(max(n_fit_symbols, 2)) + n_fit_symbols)
    return float(64.0 + np.log2(max(n_fit_symbols, 2)))

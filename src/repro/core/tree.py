"""Host-side compact decision-tree containers shared by the codec and the
JAX forest substrate.

Conventions
-----------
* Nodes are stored in **preorder** (root first, then left subtree, then right
  subtree).  The codec relies on this: the Zaks sequence is the preorder
  internal/leaf pattern, and every per-node symbol stream is emitted/consumed
  in the same global preorder.
* Every internal node has exactly two children (CART binary splits).
* ``feature[i] == -1`` marks a leaf.
* ``threshold[i]`` is an integer *split symbol*: the bin index for numerical
  variables (histogram CART; the bin-edge table lives in ForestMeta) or the
  partition id for categorical variables.
* ``node_fit[i]`` is stored for EVERY node, not only leaves — the paper (§3.3)
  notes common implementations keep per-node fits for missing-value handling,
  and that this makes fits a dominant fraction of the forest; we reproduce
  that behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Tree:
    feature: np.ndarray  # (n_nodes,) int32; -1 => leaf
    threshold: np.ndarray  # (n_nodes,) int32 split symbol; -1 at leaves
    children_left: np.ndarray  # (n_nodes,) int32; -1 at leaves
    children_right: np.ndarray  # (n_nodes,) int32; -1 at leaves
    node_fit: np.ndarray  # (n_nodes,) float64 (regression) or int64 (classes)

    def __post_init__(self) -> None:
        self.feature = np.asarray(self.feature, dtype=np.int32)
        self.threshold = np.asarray(self.threshold, dtype=np.int32)
        self.children_left = np.asarray(self.children_left, dtype=np.int32)
        self.children_right = np.asarray(self.children_right, dtype=np.int32)
        self.node_fit = np.asarray(self.node_fit)

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    @property
    def is_leaf(self) -> np.ndarray:
        return self.feature < 0

    def depths(self) -> np.ndarray:
        d = np.zeros(self.n_nodes, dtype=np.int32)
        for i in range(self.n_nodes):
            for c in (self.children_left[i], self.children_right[i]):
                if c >= 0:
                    d[c] = d[i] + 1
        return d

    def parents(self) -> np.ndarray:
        p = np.full(self.n_nodes, -1, dtype=np.int32)
        for i in range(self.n_nodes):
            for c in (self.children_left[i], self.children_right[i]):
                if c >= 0:
                    p[c] = i
        return p

    def predict_one(self, x_binned: np.ndarray) -> float:
        """Reference traversal over binned features (oracle for the kernels)."""
        i = 0
        while self.feature[i] >= 0:
            if x_binned[self.feature[i]] <= self.threshold[i]:
                i = int(self.children_left[i])
            else:
                i = int(self.children_right[i])
        return self.node_fit[i]

    def equals(self, other: "Tree") -> bool:
        return (
            np.array_equal(self.feature, other.feature)
            and np.array_equal(self.threshold, other.threshold)
            and np.array_equal(self.children_left, other.children_left)
            and np.array_equal(self.children_right, other.children_right)
            and np.array_equal(self.node_fit, other.node_fit)
        )


@dataclass
class ForestMeta:
    """Per-forest metadata shared by all trees (stored once; counted in the
    codec's overhead bucket)."""

    n_features: int
    task: str  # "classification" | "regression"
    n_classes: int = 2
    n_bins_per_feature: np.ndarray | None = None  # (d,) alphabet size per var
    bin_edges: np.ndarray | None = None  # (d, max_bins-1) float32 bin uppers
    n_train_obs: int = 0  # the paper's n (numerical split alpha = log2 n + C)
    categorical: np.ndarray | None = None  # (d,) bool

    def __post_init__(self) -> None:
        if self.n_bins_per_feature is None:
            self.n_bins_per_feature = np.full(self.n_features, 256, np.int32)
        if self.categorical is None:
            self.categorical = np.zeros(self.n_features, dtype=bool)


@dataclass
class Forest:
    trees: list[Tree]
    meta: ForestMeta
    fit_values: np.ndarray = field(default_factory=lambda: np.zeros(0))
    # ``fit_values``: for regression, node_fit arrays hold int indices into
    # this table of distinct 64-bit fit values (the paper's "symbol -> 64-bit
    # value" dictionary). For classification it is empty and node_fit holds
    # class ids directly.

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    def equals(self, other: "Forest") -> bool:
        return (
            self.n_trees == other.n_trees
            and all(a.equals(b) for a, b in zip(self.trees, other.trees))
            and np.array_equal(self.fit_values, other.fit_values)
        )

    def max_depth(self) -> int:
        return max((int(t.depths().max()) for t in self.trees), default=0)

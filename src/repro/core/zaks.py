"""Zaks sequences for tree structure (paper §3.1, after Zaks 1980).

Preorder walk; internal node -> 1, leaf -> 0.  For a tree with n internal
nodes the sequence has length 2n+1 and is uniquely decodable.  Validity
(paper conditions i-iii): starts with 1 (unless the tree is a single leaf),
#0 = #1 + 1, and no proper prefix satisfies that property.
"""
from __future__ import annotations

import numpy as np

from .tree import Tree


def zaks_encode(tree: Tree) -> np.ndarray:
    """Preorder 1/0 labels. Assumes ``tree`` is stored in preorder."""
    return (~tree.is_leaf).astype(np.uint8)


def zaks_is_valid(bits: np.ndarray) -> bool:
    bits = np.asarray(bits, dtype=np.int64)
    if len(bits) == 0 or len(bits) % 2 == 0:
        return False
    # running excess of 0s over 1s must first hit +1 exactly at the end
    excess = np.cumsum(1 - 2 * bits)
    return bool(excess[-1] == 1 and (excess[:-1] < 1).all())


def zaks_decode(bits: np.ndarray):
    """Rebuild preorder structure arrays from a Zaks sequence (vectorized).

    Returns ``(children_left, children_right, is_leaf)`` with -1 for absent
    children; node ids are preorder positions (matching :func:`zaks_encode`).

    In preorder, an internal node ``i``'s left child is ``i + 1`` and its
    right child follows the left subtree.  With the running excess
    ``c = cumsum(+1 for leaf, -1 for internal)``, the subtree rooted at ``j``
    ends at the first ``k >= j`` with ``c[k] == c[j-1] + 1`` (the excess walks
    in +-1 steps, so the first time it reaches that level is the subtree
    boundary).  All boundaries are found at once with one lexicographic
    searchsorted over ``(c, position)`` keys — no per-node Python loop.
    """
    bits = np.asarray(bits, dtype=np.uint8)
    n = len(bits)
    if not zaks_is_valid(bits):
        raise ValueError("invalid Zaks sequence")
    left = np.full(n, -1, dtype=np.int32)
    right = np.full(n, -1, dtype=np.int32)
    internal = np.flatnonzero(bits)
    if internal.size:
        c = np.cumsum(1 - 2 * bits.astype(np.int64))
        left[internal] = internal + 1
        shift = n + 2  # make every key component positive
        keys = np.sort((c + shift) * (n + 1) + np.arange(n))
        want = (c[internal] + 1 + shift) * (n + 1) + (internal + 1)
        p = np.searchsorted(keys, want, side="left")
        ends = keys[p] % (n + 1)  # end of each left subtree
        right[internal] = ends + 1
    return left, right, bits == 0


def zaks_decode_reference(bits: np.ndarray):
    """Original stack-based parse (differential oracle for the vectorized
    :func:`zaks_decode`; also the seed-faithful benchmark baseline)."""
    bits = np.asarray(bits, dtype=np.uint8)
    n = len(bits)
    left = np.full(n, -1, dtype=np.int32)
    right = np.full(n, -1, dtype=np.int32)

    # iterative parse (trees can be deep): explicit stack of pending slots
    pos = 0
    stack: list[tuple[int, int]] = []  # (parent id, 0=left pending/1=right)
    root = 0
    first = True
    while pos < n:
        me = pos
        is_internal = bits[pos]
        pos += 1
        if first:
            first = False
            root = me
        else:
            parent, side = stack.pop()
            if side == 0:
                left[parent] = me
            else:
                right[parent] = me
        if is_internal:
            stack.append((me, 1))  # right parsed after the whole left subtree
            stack.append((me, 0))
    if stack or root != 0:
        raise ValueError("invalid Zaks sequence")
    return left, right, bits == 0


def split_concatenated(bits: np.ndarray, lengths: np.ndarray) -> list[np.ndarray]:
    out = []
    off = 0
    for L in lengths:
        out.append(bits[off : off + int(L)])
        off += int(L)
    return out

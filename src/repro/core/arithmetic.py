"""Static integer arithmetic coding (paper §2.2, used for fits per §4).

32-bit range implementation after Witten/Neal/Cleary (as presented in Sayood).
Operates on integer symbols with a fixed cumulative-frequency table; achieves
within ~2 bits of ``n * H(P)`` for the whole sequence, which is why the paper
prefers it over Huffman for skewed binary alphabets (two-class fits).
"""
from __future__ import annotations

import numpy as np

from .bitio import BitReader, BitWriter

_PRECISION = 32
_WHOLE = 1 << _PRECISION
_HALF = _WHOLE >> 1
_QUARTER = _WHOLE >> 2
_MASK = _WHOLE - 1
_MAX_TOTAL = 1 << 24  # keep range arithmetic exact


def _quantize_freqs(freqs: np.ndarray) -> np.ndarray:
    """Integer frequency table with every observed symbol >= 1."""
    freqs = np.asarray(freqs, dtype=np.float64)
    total = freqs.sum()
    if total <= 0:
        raise ValueError("empty frequency table")
    scaled = np.maximum((freqs / total * (_MAX_TOTAL - len(freqs))), 0.0)
    q = np.floor(scaled).astype(np.int64)
    q[freqs > 0] = np.maximum(q[freqs > 0], 1)
    return q


class ArithmeticCode:
    """Static arithmetic coder over symbols 0..B-1 with distribution ``freqs``.

    Symbols with zero frequency cannot be coded (mirrors the Huffman
    codebook-membership rule); cluster centroids always dominate their
    members' supports, so this never triggers in the codec.
    """

    def __init__(self, freqs: np.ndarray) -> None:
        self.freqs = _quantize_freqs(freqs)
        self.cum = np.zeros(len(self.freqs) + 1, dtype=np.int64)
        np.cumsum(self.freqs, out=self.cum[1:])
        self.total = int(self.cum[-1])

    def encode(self, symbols) -> bytes:
        w = BitWriter()
        low, high = 0, _MASK
        pending = 0

        def emit(bit: int) -> None:
            nonlocal pending
            w.write_bit(bit)
            while pending:
                w.write_bit(1 - bit)
                pending -= 1

        for s in symbols:
            s = int(s)
            span = high - low + 1
            if self.freqs[s] == 0:
                raise ValueError(f"symbol {s} has zero probability")
            high = low + span * int(self.cum[s + 1]) // self.total - 1
            low = low + span * int(self.cum[s]) // self.total
            while True:
                if high < _HALF:
                    emit(0)
                elif low >= _HALF:
                    emit(1)
                    low -= _HALF
                    high -= _HALF
                elif low >= _QUARTER and high < 3 * _QUARTER:
                    pending += 1
                    low -= _QUARTER
                    high -= _QUARTER
                else:
                    break
                low <<= 1
                high = (high << 1) | 1
        # flush
        pending += 1
        emit(0 if low < _QUARTER else 1)
        return w.getvalue()

    def decode(self, data: bytes, n_symbols: int) -> np.ndarray:
        # Range decoding is inherently sequential; this loop is tuned for the
        # serving hot path: bits pre-unpacked once, cumulative table as Python
        # ints (bisect/compares beat np.searchsorted by ~10x per call at the
        # tiny alphabet sizes the fits coder sees), and the two-class case —
        # what the paper actually uses arithmetic coding for — gets a branch
        # with a single range split per symbol.  The arithmetic is identical
        # to the original Witten/Neal/Cleary loop: same symbols, bit for bit
        # (tests/test_serve_path.py checks against decode_reference).
        from bisect import bisect_right

        if n_symbols == 0:
            return np.empty(0, dtype=np.int64)
        bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8)).tolist()
        nb = len(bits)
        cum = self.cum.tolist()
        total = self.total
        binary = len(cum) == 3  # alphabet {0, 1}
        c1 = cum[1] if binary else 0
        half, quarter, three_q = _HALF, _QUARTER, 3 * _QUARTER
        low, high = 0, _MASK
        value = 0
        pos = 0
        for _ in range(_PRECISION):
            value = (value << 1) | (bits[pos] if pos < nb else 0)
            pos += 1
        out = []
        append = out.append
        for _ in range(n_symbols):
            span = high - low + 1
            target = ((value - low + 1) * total - 1) // span
            if binary:
                # split = low + span*c1//total is both high(0)+1 and low(1):
                # one multiply-divide decodes AND updates the range.
                split = low + span * c1 // total
                if target < c1:
                    append(0)
                    high = split - 1
                else:
                    append(1)
                    low = split
            else:
                s = bisect_right(cum, target) - 1
                append(s)
                high = low + span * cum[s + 1] // total - 1
                low = low + span * cum[s] // total
            while True:
                if high < half:
                    pass
                elif low >= half:
                    low -= half
                    high -= half
                    value -= half
                elif low >= quarter and high < three_q:
                    low -= quarter
                    high -= quarter
                    value -= quarter
                else:
                    break
                low <<= 1
                high = (high << 1) | 1
                value = (value << 1) | (bits[pos] if pos < nb else 0)
                pos += 1
        return np.array(out, dtype=np.int64)

    def decode_reference(self, data: bytes, n_symbols: int) -> np.ndarray:
        """Original decoder (seed-faithful; differential oracle + benchmark
        baseline)."""
        r = BitReader(data)
        total_bits = len(data) * 8

        def next_bit() -> int:
            return r.read_bit() if r.pos < total_bits else 0

        low, high = 0, _MASK
        value = 0
        for _ in range(_PRECISION):
            value = (value << 1) | next_bit()
        out = np.empty(n_symbols, dtype=np.int64)
        for i in range(n_symbols):
            span = high - low + 1
            target = ((value - low + 1) * self.total - 1) // span
            s = int(np.searchsorted(self.cum, target, side="right") - 1)
            out[i] = s
            high = low + span * int(self.cum[s + 1]) // self.total - 1
            low = low + span * int(self.cum[s]) // self.total
            while True:
                if high < _HALF:
                    pass
                elif low >= _HALF:
                    low -= _HALF
                    high -= _HALF
                    value -= _HALF
                elif low >= _QUARTER and high < 3 * _QUARTER:
                    low -= _QUARTER
                    high -= _QUARTER
                    value -= _QUARTER
                else:
                    break
                low <<= 1
                high = (high << 1) | 1
                value = (value << 1) | next_bit()
        return out

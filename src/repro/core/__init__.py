"""repro.core — the paper's contribution: lossless (and lossy) compression
of random forests via probabilistic modeling + Bregman model clustering +
entropy coding, with prediction from the compressed format."""

from .arithmetic import ArithmeticCode
from .bregman import ClusteringResult, cluster_models, kl_assign, kl_kmeans
from .compressed_predict import iter_trees, predict_compressed
from .forest_codec import CompressedForest, compress_forest, decompress_forest
from .huffman import HuffmanCode, entropy_bits
from .lossy import (
    LossyTheory,
    estimate_sigma2,
    estimate_sigma2_per_obs,
    quantize_fits,
    subsample_trees,
)
from .lz import lzw_decode_bits, lzw_encode_bits
from .tree import Forest, ForestMeta, Tree
from .zaks import zaks_decode, zaks_encode, zaks_is_valid

__all__ = [
    "ArithmeticCode",
    "ClusteringResult",
    "CompressedForest",
    "Forest",
    "ForestMeta",
    "HuffmanCode",
    "LossyTheory",
    "Tree",
    "cluster_models",
    "compress_forest",
    "decompress_forest",
    "entropy_bits",
    "estimate_sigma2",
    "estimate_sigma2_per_obs",
    "iter_trees",
    "kl_assign",
    "kl_kmeans",
    "lzw_decode_bits",
    "lzw_encode_bits",
    "predict_compressed",
    "quantize_fits",
    "subsample_trees",
    "zaks_decode",
    "zaks_encode",
    "zaks_is_valid",
]

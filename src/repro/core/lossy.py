"""Lossy compression with rate-distortion guarantees (paper §7).

Two knobs, each with a closed-form trade-off the experiments verify:

* **tree subsampling** — keep a random |A0| of the |A| trees; the added
  prediction variance is D ≈ sigma^2/|A0| + sigma^2/|A| (eq. 7 with
  |A0| << |A|), while the compressed size shrinks linearly in |A0|/|A|.
* **fit quantization** — uniform b-bit quantization of the numerical fits
  over their range 2^r; distortion 2^{-(b-r)} per value (variance
  (2^{-(b-r)})^2 / 12 under dithered/uniform error), size gain ~ b/64.

Both return ordinary Forest objects, so the LOSSLESS codec is reused
unchanged downstream — "lossy = preprocess, then lossless" exactly as §7.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .tree import Forest


@dataclass(frozen=True)
class LossyConfig:
    """Store-level lossy mode (paper §6/§7): quantize every user's
    regression fit table onto one fleet-wide fixed-rate grid of
    ``2**fit_bits`` levels before (lossless) delta encoding.  Consumed by
    ``repro.store.build_store(lossy=...)``, which reports the measured max
    error next to the closed-form distortion bound."""

    fit_bits: int = 8
    dithered: bool = False
    seed: int = 0


def subsample_trees(forest: Forest, n_keep: int, seed: int = 0) -> Forest:
    rng = np.random.default_rng(seed)
    idx = rng.choice(forest.n_trees, size=min(n_keep, forest.n_trees), replace=False)
    return Forest(
        trees=[forest.trees[int(i)] for i in sorted(idx)],
        meta=forest.meta,
        fit_values=forest.fit_values,
    )


def quantize_fits(
    forest: Forest,
    bits: int,
    dithered: bool = False,
    seed: int = 0,
    value_range: tuple[float, float] | None = None,
) -> tuple[Forest, float]:
    """Uniform b-bit quantization of the regression fit-value dictionary.

    Returns (new forest, max quantization error).  The quantized forest's
    ``fit_values`` table has at most 2^bits distinct values, so the fits
    component's alphabet (and dictionary) shrinks accordingly; node fit
    indices are remapped.

    ``value_range=(lo, hi)`` pins the grid to an EXTERNAL range instead of
    this forest's own min/max — quantizing a whole fleet against one
    shared range makes every user land on the same fixed-rate grid, so
    the store's fleet-union fit table collapses to at most 2^bits entries
    (``repro.store.build_store(lossy=...)``).
    """
    if forest.meta.task != "regression":
        raise ValueError("fit quantization applies to regression forests")
    values = np.asarray(forest.fit_values, dtype=np.float64)
    if value_range is None:
        lo, hi = float(values.min()), float(values.max())
    else:
        lo, hi = float(value_range[0]), float(value_range[1])
    span = max(hi - lo, 1e-30)
    n_levels = 1 << bits
    step = span / n_levels
    rng = np.random.default_rng(seed)
    dither = rng.uniform(-0.5, 0.5, size=values.shape) if dithered else 0.0
    q = np.clip(
        np.floor((values - lo) / step + (dither if dithered else 0.0)),
        0,
        n_levels - 1,
    )
    grid = lo + (q + 0.5) * step  # reconstruction points
    new_values, remap = np.unique(grid, return_inverse=True)
    new_trees = [
        type(t)(
            t.feature,
            t.threshold,
            t.children_left,
            t.children_right,
            remap[t.node_fit.astype(np.int64)].astype(np.int64),
        )
        for t in forest.trees
    ]
    max_err = float(np.abs(grid - values).max())
    return (
        Forest(trees=new_trees, meta=forest.meta, fit_values=new_values),
        max_err,
    )


# --------------------------------------------------------------------------
# §7 theory — used by tests and the lossy benchmarks to overlay predicted
# curves on measured ones.
# --------------------------------------------------------------------------
@dataclass
class LossyTheory:
    sigma2: float  # per-tree prediction-error variance around ensemble mean
    n_trees: int
    fit_range_log2: float  # r: fits span 2^r

    def subsample_distortion(self, n_keep: int) -> float:
        """Eq. 7 (|A0| << |A| approximation)."""
        return self.sigma2 / n_keep + self.sigma2 / self.n_trees

    def quantization_distortion(self, bits: int) -> float:
        """Variance of the uniform quantization error."""
        step = 2.0 ** (self.fit_range_log2 - bits)
        return step**2 / 12.0

    def total_distortion(self, n_keep: int, bits: int) -> float:
        return (
            self.subsample_distortion(n_keep)
            + self.quantization_distortion(bits) / n_keep
        )

    def compression_gain(self, n_keep: int, bits: int) -> float:
        """Predicted size multiplier (fits bucket: b/64; whole forest:
        linear in the sampling ratio)."""
        return (n_keep / self.n_trees) * (bits / 64.0)


def estimate_sigma2(per_tree_preds: np.ndarray) -> float:
    """sigma^2 from a matrix (n_trees, n_obs) of per-tree predictions:
    variance of per-tree mean error around the ensemble mean (paper §7,
    e_t = mean_i(yhat_{t,i} - yhat_i^*))."""
    ens = per_tree_preds.mean(axis=0, keepdims=True)
    e_t = (per_tree_preds - ens).mean(axis=1)
    return float(e_t.var(ddof=1))


def estimate_sigma2_per_obs(per_tree_preds: np.ndarray) -> float:
    """The paper's sigma^2 BOUND: per-observation variance of the tree
    error (sigma_i^2 <= sigma^2, taken as the mean over observations).
    This is the quantity that predicts the per-observation MSE increase
    sigma^2/|A0| when subsampling (var(e_t) of the across-obs MEAN is
    smaller by up to 1/n and underpredicts test MSE)."""
    var_t = per_tree_preds.var(axis=0, ddof=1)  # (n_obs,)
    return float(var_t.mean())

"""Byte-honest framing primitives shared by every serializer in the repo.

One length-prefixed array/bytes wire format shared by every serializer
(dtype-tag + shape + raw bytes): the inline ``CompressedForest`` (RFC1) and
the store formats (RFS1/RFD1/RFT1/RFM1) must never diverge, so both call
here.  The normative byte-level description of every frame built from
these primitives lives in ``docs/format.md``.

Primitives:

* ``write_arr`` / ``read_arr`` — the ARR record: dtype tag + shape + raw
  little-endian bytes;
* ``write_bytes`` / ``read_bytes`` — the BYTES record: u32 length prefix +
  raw bytes;
* ``write_u16`` / ``read_u16``, ``write_u32`` / ``read_u32`` — bare
  little-endian scalars (codebook generations, element counts).

Integrity (ISSUE 6): every read is bounds-checked against the remaining
buffer — a corrupted or truncated length field raises a typed
``TruncatedFrameError`` / ``IntegrityError`` instead of attempting an
unbounded allocation or returning silently-short data — and every
top-level frame writer appends a CRC32 trailer (``with_crc``) that
``check_crc`` verifies and strips on read.  CRC-less frames written
before the trailer existed still parse (``docs/format.md`` §8).
"""
from __future__ import annotations

import io
import os
import struct
import zlib

import numpy as np


class FramingError(ValueError):
    """Base for every typed framing fault (subclasses ``ValueError`` so
    pre-existing ``except ValueError`` callers keep working)."""


class TruncatedFrameError(FramingError):
    """A length field points past the end of the frame, or the frame ends
    mid-record — the payload cannot be read in full."""


class IntegrityError(FramingError):
    """The frame's bytes are internally inconsistent: CRC mismatch, bad
    magic, an impossible dtype tag, or a shape that contradicts the
    element count."""


class UnrepairableError(IntegrityError):
    """Corruption was DETECTED but could not be REPAIRED: more than one
    shard of a parity group is corrupt or missing, so XOR reconstruction
    cannot recover the bytes (``store.durable``).  Subclasses
    ``IntegrityError`` so every quarantine/rejection path that handles
    detected corruption handles the unrepairable case identically —
    never a silent wrong artifact."""


#: CRC trailer layout: this magic + u32 CRC32 of every preceding byte.
CRC_MAGIC = b"CRC1"

#: Upper bound on ARR ndim — anything larger is a corrupted header, not a
#: real tensor (the codec never writes past 2 dimensions).
_MAX_NDIM = 8


def _read_exact(inp: io.BytesIO, n: int, what: str) -> bytes:
    """Read exactly ``n`` bytes or raise ``TruncatedFrameError`` — never
    return a silent short read."""
    b = inp.read(n)
    if len(b) != n:
        raise TruncatedFrameError(
            f"truncated frame: wanted {n} bytes for {what}, got {len(b)}"
        )
    return b


def _remaining(inp: io.BytesIO) -> int | None:
    """Bytes left in the buffer, or ``None`` for non-seekable streams."""
    try:
        return len(inp.getbuffer()) - inp.tell()
    except (AttributeError, io.UnsupportedOperation):
        return None


def _check_length(inp: io.BytesIO, nbytes: int, what: str) -> None:
    """Clamp an untrusted length field against the remaining buffer BEFORE
    allocating — a flipped bit in a u32 length must not turn into a
    multi-gigabyte allocation attempt."""
    rem = _remaining(inp)
    if rem is not None and nbytes > rem:
        raise TruncatedFrameError(
            f"truncated frame: {what} claims {nbytes} bytes but only "
            f"{rem} remain"
        )


def write_u16(out: io.BytesIO, v: int) -> None:
    """Write one little-endian uint16 scalar."""
    out.write(struct.pack("<H", v))


def read_u16(inp: io.BytesIO) -> int:
    """Read one little-endian uint16 scalar."""
    return struct.unpack("<H", _read_exact(inp, 2, "u16"))[0]


def write_u32(out: io.BytesIO, v: int) -> None:
    """Write one little-endian uint32 scalar."""
    out.write(struct.pack("<I", v))


def read_u32(inp: io.BytesIO) -> int:
    """Read one little-endian uint32 scalar."""
    return struct.unpack("<I", _read_exact(inp, 4, "u32"))[0]


def read_struct(inp: io.BytesIO, fmt: str, what: str) -> tuple:
    """Read one packed struct with bounds checking — frame parsers use
    this instead of bare ``struct.unpack(fmt, inp.read(n))`` so a
    truncated header raises ``TruncatedFrameError``, not ``struct.error``."""
    return struct.unpack(fmt, _read_exact(inp, struct.calcsize(fmt), what))


def write_arr(out: io.BytesIO, a: np.ndarray) -> None:
    """Write one ARR record: u8 dtype-tag length, the numpy dtype string
    (e.g. ``<i4``), u8 ndim, u32 total element count, u32 per-axis sizes,
    then the raw little-endian element bytes."""
    a = np.ascontiguousarray(a)
    dt = a.dtype.str.encode()
    out.write(struct.pack("<B", len(dt)))
    out.write(dt)
    out.write(struct.pack("<BI", a.ndim, a.size))
    for s in a.shape:
        out.write(struct.pack("<I", s))
    out.write(a.tobytes())


def read_arr(inp: io.BytesIO) -> np.ndarray:
    """Read one ARR record written by ``write_arr``.

    Every field is validated before use: the dtype tag must parse, ndim
    must be plausible, the per-axis sizes must multiply to the element
    count, and the payload length is clamped against the remaining buffer
    — corrupted headers raise ``IntegrityError`` /
    ``TruncatedFrameError`` instead of allocating from garbage."""
    (dl,) = struct.unpack("<B", _read_exact(inp, 1, "ARR dtype-tag length"))
    tag = _read_exact(inp, dl, "ARR dtype tag")
    try:
        dt = np.dtype(tag.decode("ascii"))
    except (UnicodeDecodeError, TypeError, ValueError) as e:
        raise IntegrityError(f"ARR record has invalid dtype tag {tag!r}") \
            from e
    ndim, size = struct.unpack("<BI", _read_exact(inp, 5, "ARR header"))
    if ndim > _MAX_NDIM:
        raise IntegrityError(f"ARR record claims ndim={ndim} (max {_MAX_NDIM})")
    shape = tuple(
        struct.unpack("<I", _read_exact(inp, 4, "ARR shape"))[0]
        for _ in range(ndim)
    )
    if int(np.prod(shape, dtype=np.int64)) != size:
        raise IntegrityError(
            f"ARR record shape {shape} does not match element count {size}"
        )
    nbytes = size * dt.itemsize
    _check_length(inp, nbytes, "ARR payload")
    raw = _read_exact(inp, nbytes, "ARR payload")
    return np.frombuffer(raw, dtype=dt).reshape(shape)


def write_bytes(out: io.BytesIO, b: bytes) -> None:
    """Write one BYTES record: u32 length prefix + raw bytes."""
    out.write(struct.pack("<I", len(b)))
    out.write(b)


def read_bytes(inp: io.BytesIO) -> bytes:
    """Read one BYTES record written by ``write_bytes``.  The length prefix
    is clamped against the remaining buffer; a short payload raises
    ``TruncatedFrameError`` instead of returning silently-short bytes."""
    (n,) = struct.unpack("<I", _read_exact(inp, 4, "BYTES length"))
    _check_length(inp, n, "BYTES payload")
    return _read_exact(inp, n, "BYTES payload")


# ---------------------------------------------------------------------------
# frame-level integrity: CRC32 trailers + typed magic checks
# ---------------------------------------------------------------------------

def with_crc(payload: bytes) -> bytes:
    """Append the CRC trailer (``CRC1`` magic + u32 CRC32 of ``payload``)
    — what every frame writer emits since ISSUE 6."""
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    return payload + CRC_MAGIC + struct.pack("<I", crc)


def check_crc(data: bytes, what: str = "frame") -> bytes:
    """Verify and strip a frame's CRC trailer, returning the bare payload.

    Backward compatible: frames written before the trailer existed (no
    ``CRC1`` magic at the end) pass through unchanged — but when a trailer
    IS present, a mismatch raises ``IntegrityError`` (the frame was
    corrupted in storage or transit, and decoding it would yield a
    silently wrong artifact)."""
    if len(data) >= 8 and data[-8:-4] == CRC_MAGIC:
        payload = data[:-8]
        (want,) = struct.unpack("<I", data[-4:])
        got = zlib.crc32(payload) & 0xFFFFFFFF
        if got != want:
            raise IntegrityError(
                f"{what}: CRC mismatch (stored 0x{want:08x}, computed "
                f"0x{got:08x}) — the frame is corrupted"
            )
        return payload
    return data


def expect_magic(inp: io.BytesIO, magic: bytes, what: str) -> None:
    """Read and verify a frame's magic; a mismatch is a typed
    ``IntegrityError`` instead of a bare ``AssertionError``."""
    got = _read_exact(inp, len(magic), f"{what} magic")
    if got != magic:
        raise IntegrityError(
            f"{what}: bad magic {got!r} (expected {magic!r})"
        )


# ---------------------------------------------------------------------------
# durable writes: the one atomic-write helper every on-disk frame shares
# ---------------------------------------------------------------------------

def fsync_dir(path: str) -> None:
    """fsync a DIRECTORY so a rename into it survives power loss.  POSIX
    makes the rename itself atomic but not durable: until the directory
    inode is flushed, a crash can forget the new name entirely.  No-op on
    platforms whose directories refuse ``os.open`` (e.g. Windows)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` atomically AND durably: write to a
    same-directory temp file, flush + fsync the file, ``os.replace`` onto
    the final name, then fsync the containing directory.  After a crash at
    any instant the path holds either the complete old bytes or the
    complete new bytes — never a prefix (the durable store's whole
    recovery story rests on this; the migration journal shares it)."""
    directory = os.path.dirname(os.path.abspath(path))
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(directory)

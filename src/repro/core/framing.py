"""Byte-honest framing primitives shared by every serializer in the repo.

One length-prefixed array/bytes wire format shared by every serializer
(dtype-tag + shape + raw bytes): the inline ``CompressedForest`` (RFC1) and
the store formats (RFS1/RFD1/RFT1/RFM1) must never diverge, so both call
here.  The normative byte-level description of every frame built from
these primitives lives in ``docs/format.md``.

Primitives:

* ``write_arr`` / ``read_arr`` — the ARR record: dtype tag + shape + raw
  little-endian bytes;
* ``write_bytes`` / ``read_bytes`` — the BYTES record: u32 length prefix +
  raw bytes;
* ``write_u16`` / ``read_u16``, ``write_u32`` / ``read_u32`` — bare
  little-endian scalars (codebook generations, element counts).
"""
from __future__ import annotations

import io
import struct

import numpy as np


def write_u16(out: io.BytesIO, v: int) -> None:
    """Write one little-endian uint16 scalar."""
    out.write(struct.pack("<H", v))


def read_u16(inp: io.BytesIO) -> int:
    """Read one little-endian uint16 scalar."""
    return struct.unpack("<H", inp.read(2))[0]


def write_u32(out: io.BytesIO, v: int) -> None:
    """Write one little-endian uint32 scalar."""
    out.write(struct.pack("<I", v))


def read_u32(inp: io.BytesIO) -> int:
    """Read one little-endian uint32 scalar."""
    return struct.unpack("<I", inp.read(4))[0]


def write_arr(out: io.BytesIO, a: np.ndarray) -> None:
    """Write one ARR record: u8 dtype-tag length, the numpy dtype string
    (e.g. ``<i4``), u8 ndim, u32 total element count, u32 per-axis sizes,
    then the raw little-endian element bytes."""
    a = np.ascontiguousarray(a)
    dt = a.dtype.str.encode()
    out.write(struct.pack("<B", len(dt)))
    out.write(dt)
    out.write(struct.pack("<BI", a.ndim, a.size))
    for s in a.shape:
        out.write(struct.pack("<I", s))
    out.write(a.tobytes())


def read_arr(inp: io.BytesIO) -> np.ndarray:
    """Read one ARR record written by ``write_arr``."""
    (dl,) = struct.unpack("<B", inp.read(1))
    dt = np.dtype(inp.read(dl).decode())
    ndim, size = struct.unpack("<BI", inp.read(5))
    shape = tuple(struct.unpack("<I", inp.read(4))[0] for _ in range(ndim))
    return np.frombuffer(inp.read(size * dt.itemsize), dtype=dt).reshape(shape)


def write_bytes(out: io.BytesIO, b: bytes) -> None:
    """Write one BYTES record: u32 length prefix + raw bytes."""
    out.write(struct.pack("<I", len(b)))
    out.write(b)


def read_bytes(inp: io.BytesIO) -> bytes:
    """Read one BYTES record written by ``write_bytes``."""
    (n,) = struct.unpack("<I", inp.read(4))
    return inp.read(n)

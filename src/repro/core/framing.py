"""Byte-honest framing primitives shared by every serializer in the repo.

One length-prefixed array/bytes wire format shared by every serializer
(dtype-tag + shape + raw bytes): the inline ``CompressedForest`` (RFC1) and
the store formats (RFS1/RFD1/RFT1) must never diverge, so both call here.
"""
from __future__ import annotations

import io
import struct

import numpy as np


def write_arr(out: io.BytesIO, a: np.ndarray) -> None:
    a = np.ascontiguousarray(a)
    dt = a.dtype.str.encode()
    out.write(struct.pack("<B", len(dt)))
    out.write(dt)
    out.write(struct.pack("<BI", a.ndim, a.size))
    for s in a.shape:
        out.write(struct.pack("<I", s))
    out.write(a.tobytes())


def read_arr(inp: io.BytesIO) -> np.ndarray:
    (dl,) = struct.unpack("<B", inp.read(1))
    dt = np.dtype(inp.read(dl).decode())
    ndim, size = struct.unpack("<BI", inp.read(5))
    shape = tuple(struct.unpack("<I", inp.read(4))[0] for _ in range(ndim))
    return np.frombuffer(inp.read(size * dt.itemsize), dtype=dt).reshape(shape)


def write_bytes(out: io.BytesIO, b: bytes) -> None:
    out.write(struct.pack("<I", len(b)))
    out.write(b)


def read_bytes(inp: io.BytesIO) -> bytes:
    (n,) = struct.unpack("<I", inp.read(4))
    return inp.read(n)

"""LZW coding of the concatenated Zaks bitstream (paper §3.1).

The paper compresses all trees' Zaks sequences as ONE concatenated sequence
with "an LZ-based encoder", exploiting cross-tree structural redundancy
without paying any dictionary overhead.  We implement LZW over the binary
alphabet {0,1} with growing code width — dictionary-free on the wire, exactly
the property §2.2 highlights for the LZ family.
"""
from __future__ import annotations

import numpy as np

from .bitio import BitReader, BitWriter


def lzw_encode_bits(bits: np.ndarray) -> bytes:
    """LZW-encode a 0/1 numpy array. Returns the code stream (the symbol count
    travels in the codec header, not here)."""
    bits = np.asarray(bits, dtype=np.uint8)
    dictionary: dict[bytes, int] = {b"\x00": 0, b"\x01": 1}
    w = BitWriter()
    if len(bits) == 0:
        return w.getvalue()
    data = bits.tobytes()  # one byte per bit; fine for dictionary keys
    cur = data[0:1]
    for i in range(1, len(data)):
        nxt = cur + data[i : i + 1]
        if nxt in dictionary:
            cur = nxt
            continue
        width = max(1, (len(dictionary) - 1).bit_length())
        w.write_bits(dictionary[cur], width)
        dictionary[nxt] = len(dictionary)
        cur = data[i : i + 1]
    width = max(1, (len(dictionary) - 1).bit_length())
    w.write_bits(dictionary[cur], width)
    return w.getvalue()


def _extract_codes(payload: bytes, n_bits: int) -> np.ndarray:
    """Vectorized extraction of every LZW code in ``payload``.

    The dictionary grows by exactly one entry per decoded code, so the code
    widths are a deterministic sequence — ``1`` for the first code, then
    ``(2 + j).bit_length()`` for loop iteration ``j`` — and every code
    boundary is known before decoding starts.  Codes are pulled out with one
    windowed gather per distinct width (<= ~30 groups), no per-bit loop.
    """
    bits = np.unpackbits(np.frombuffer(payload, dtype=np.uint8)).astype(np.int64)
    total = bits.size
    # widths: enough codes to certainly cover the payload (each code >= 1 bit)
    j = np.arange(total, dtype=np.int64)
    widths = np.concatenate(
        [[1], (np.floor(np.log2(j + 2)).astype(np.int64) + 1)]
    )
    ends = np.cumsum(widths)
    k = int(np.searchsorted(ends, total, side="right"))  # codes fully inside
    widths = widths[:k]
    starts = ends[:k] - widths
    codes = np.zeros(k, dtype=np.int64)
    lo = 0
    while lo < k:
        w = int(widths[lo])
        hi = int(np.searchsorted(widths, w, side="right"))
        s = starts[lo:hi]
        window = bits[s[:, None] + np.arange(w)[None, :]]
        codes[lo:hi] = window @ (1 << np.arange(w - 1, -1, -1, dtype=np.int64))
        lo = hi
    return codes


def lzw_decode_bits(payload: bytes, n_bits: int) -> np.ndarray:
    """Inverse of :func:`lzw_encode_bits`; returns exactly ``n_bits`` bits."""
    if n_bits == 0:
        return np.empty(0, dtype=np.uint8)
    codes = _extract_codes(payload, n_bits)
    if len(codes) == 0:
        raise ValueError("corrupt LZW stream")
    entries = [b"\x00", b"\x01"]
    prev = entries[int(codes[0])]
    parts = [prev]
    pos = len(prev)
    n_entries = 2
    for i in range(1, len(codes)):
        if pos >= n_bits:
            break
        code = int(codes[i])
        if code < n_entries:
            entry = entries[code]
        elif code == n_entries:  # KwKwK corner case
            entry = prev + prev[0:1]
        else:
            raise ValueError("corrupt LZW stream")
        entries.append(prev + entry[0:1])
        n_entries += 1
        parts.append(entry)
        pos += len(entry)
        prev = entry
    if pos < n_bits:
        raise ValueError("corrupt LZW stream")
    buf = b"".join(parts)
    return np.frombuffer(buf, dtype=np.uint8)[:n_bits].copy()


def lzw_decode_bits_reference(payload: bytes, n_bits: int) -> np.ndarray:
    """Original bit-at-a-time decoder (differential oracle for the
    vectorized path; also the seed-faithful baseline in benchmarks)."""
    out = np.empty(n_bits, dtype=np.uint8)
    if n_bits == 0:
        return out
    dictionary: dict[int, bytes] = {0: b"\x00", 1: b"\x01"}
    r = BitReader(payload)

    # The encoder's dictionary grows BEFORE it emits the next code, so the
    # decoder mirrors that: after reading code k, it knows entry
    # len(dictionary) will be prev + first-byte-of(entry(k)).
    width = max(1, (len(dictionary) - 1).bit_length())
    code = r.read_bits(width)
    prev = dictionary[code]
    pos = 0
    out[pos : pos + len(prev)] = np.frombuffer(prev, dtype=np.uint8)
    pos += len(prev)
    while pos < n_bits:
        width = max(1, len(dictionary).bit_length())
        code = r.read_bits(width)
        if code in dictionary:
            entry = dictionary[code]
        elif code == len(dictionary):  # KwKwK corner case
            entry = prev + prev[0:1]
        else:
            raise ValueError("corrupt LZW stream")
        dictionary[len(dictionary)] = prev + entry[0:1]
        out[pos : pos + len(entry)] = np.frombuffer(entry, dtype=np.uint8)
        pos += len(entry)
        prev = entry
    return out

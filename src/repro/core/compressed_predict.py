"""Prediction straight from the compressed format (paper §5).

The serving path is a streamed decode→predict pipeline: every per-cluster
Huffman stream is decoded wholesale with the table-driven vectorized decoder
(``vechuff.VectorHuffman.decode``: width-12 LUT over every bit offset +
prefix-doubling chain extraction, no per-bit Python loop), and ``iter_trees``
then reassembles trees one at a time by advancing plain integer cursors
through the pre-decoded symbol arrays in global preorder.  The working set is
O(#symbols) decoded ints plus ONE tree's structure — storage still holds only
the compressed bytes, which is the paper's subscriber-device scenario; the
Pallas serving driver (``repro.launch.serve_forest``) keeps the *device*
working set at O(single tree-tile) by streaming heap-form tiles.

Note on laziness: routing through a node requires its variable name, and the
variable name determines which split-value stream every descendant uses — so
variable names of preorder-preceding nodes must be decoded even off-path.
The paper's claim is the memory bound and the direct-from-bytes operation,
which is exactly what this module delivers; tests assert bit-exact agreement
with the uncompressed forest.

``engine="bitwise"`` preserves the original bit-at-a-time dict-lookup decoder
as a differential oracle and as the benchmark baseline
(``benchmarks/serve_forest.py`` reports before/after numbers against it).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from .bitio import BitReader
from .forest_codec import ClusteredComponent, CompressedForest
from .lz import lzw_decode_bits
from .tree import Tree
from .zaks import zaks_decode


def _component_symbol_lists(c: ClusteredComponent) -> list[list[int]]:
    """Decode every cluster stream of one component up front.

    Huffman clusters go through the vectorized table-driven decoder;
    arithmetic clusters (two-class fits) are whole-sequence by construction.
    Returns Python lists: cursor consumption in ``iter_trees`` is a hot
    per-node loop and list indexing is ~3x cheaper than numpy scalars.
    """
    return [
        dec.decode(s, n).tolist() if n else []
        for dec, s, n in zip(c.decoders(), c.streams, c.n_symbols)
    ]


def iter_trees(comp: CompressedForest, engine: str = "table") -> Iterator[Tree]:
    """Stream trees one at a time from the compressed bytes.

    engine="table" (default): array-at-a-time — all cluster streams are
    decoded vectorized, then trees are assembled with integer cursors.
    engine="bitwise": the original per-bit decoder (differential oracle).
    """
    if engine == "bitwise":
        yield from _iter_trees_bitwise(comp)
        return
    if engine != "table":
        raise ValueError(f"unknown decode engine: {engine!r}")

    meta = comp.meta
    d = meta.n_features
    zaks_all = lzw_decode_bits(comp.zaks_payload, comp.zaks_total_bits)

    vars_seqs = _component_symbol_lists(comp.vars_comp)
    split_seqs = {
        v: _component_symbol_lists(c) for v, c in comp.splits_comp.items()
    }
    fits_seqs = _component_symbol_lists(comp.fits_comp)
    vars_cur = [0] * len(vars_seqs)
    split_cur = {v: [0] * len(s) for v, s in split_seqs.items()}
    fits_cur = [0] * len(fits_seqs)

    v_map = comp.vars_comp.kid_to_cluster.tolist()
    s_map = {v: c.kid_to_cluster.tolist() for v, c in comp.splits_comp.items()}
    f_map = comp.fits_comp.kid_to_cluster.tolist()

    off = 0
    for tlen in comp.zaks_lengths:
        bits = zaks_all[off : off + int(tlen)]
        off += int(tlen)
        left, right, is_leaf = zaks_decode(bits)
        n = len(bits)
        leftl = left.tolist()
        rightl = right.tolist()
        leafl = is_leaf.tolist()
        feature = [-1] * n
        threshold = [-1] * n
        fit = [0] * n
        depth = [0] * n
        fvar = [-1] * n
        for i in range(n):
            kid = depth[i] * (d + 1) + fvar[i] + 1
            if not leafl[i]:
                c = v_map[kid]
                k = vars_cur[c]
                vars_cur[c] = k + 1
                v = vars_seqs[c][k]
                feature[i] = v
                sc = s_map[v][kid]
                cur = split_cur[v]
                k = cur[sc]
                cur[sc] = k + 1
                threshold[i] = split_seqs[v][sc][k]
                dd = depth[i] + 1
                lc, rc = leftl[i], rightl[i]
                depth[lc] = dd
                fvar[lc] = v
                depth[rc] = dd
                fvar[rc] = v
            fc = f_map[kid]
            k = fits_cur[fc]
            fits_cur[fc] = k + 1
            fit[i] = fits_seqs[fc][k]
        yield Tree(
            np.array(feature, dtype=np.int32),
            np.array(threshold, dtype=np.int32),
            left,
            right,
            np.array(fit, dtype=np.int64),
        )


def _iter_trees_bitwise(comp: CompressedForest) -> Iterator[Tree]:
    """Original node-at-a-time decoder: one dict lookup per BIT, reference
    LZW/Zaks/arithmetic implementations throughout (kept as the differential
    oracle and the seed-faithful benchmark 'before' baseline)."""
    from .lz import lzw_decode_bits_reference
    from .zaks import zaks_decode_reference

    meta = comp.meta
    d = meta.n_features
    zaks_all = lzw_decode_bits_reference(comp.zaks_payload, comp.zaks_total_bits)

    vars_dec = comp.vars_comp.decoders()
    vars_readers = [BitReader(s) for s in comp.vars_comp.streams]
    split_dec = {v: c.decoders() for v, c in comp.splits_comp.items()}
    split_readers = {
        v: [BitReader(s) for s in c.streams]
        for v, c in comp.splits_comp.items()
    }
    fits_dec = comp.fits_comp.decoders()
    if comp.fits_comp.coder == "arithmetic":
        # range decoding is whole-sequence per cluster; decode once, then
        # stream with cursors (still O(#fits) ints, not O(forest) trees).
        fits_seqs = [
            dec.decode_reference(s, n) if n else np.zeros(0, np.int64)
            for dec, s, n in zip(
                fits_dec, comp.fits_comp.streams, comp.fits_comp.n_symbols
            )
        ]
        fits_readers = None
    else:
        fits_seqs = None
        fits_readers = [BitReader(s) for s in comp.fits_comp.streams]
    fits_cursor = [0] * max(
        len(comp.fits_comp.codebook_lengths), len(comp.fits_comp.centroid_freqs)
    )

    off = 0
    for tlen in comp.zaks_lengths:
        bits = zaks_all[off : off + int(tlen)]
        off += int(tlen)
        left, right, is_leaf = zaks_decode_reference(bits)
        n = len(bits)
        feature = np.full(n, -1, dtype=np.int32)
        threshold = np.full(n, -1, dtype=np.int32)
        fit = np.zeros(n, dtype=np.int64)
        depth = np.zeros(n, dtype=np.int32)
        fvar = np.full(n, -1, dtype=np.int32)
        for i in range(n):
            kid = int(depth[i]) * (d + 1) + int(fvar[i]) + 1
            if not is_leaf[i]:
                c = int(comp.vars_comp.kid_to_cluster[kid])
                v = vars_dec[c].decode_symbol_bitwise(vars_readers[c])
                feature[i] = v
                sc = int(comp.splits_comp[v].kid_to_cluster[kid])
                threshold[i] = split_dec[v][sc].decode_symbol_bitwise(
                    split_readers[v][sc]
                )
                for ch in (left[i], right[i]):
                    depth[ch] = depth[i] + 1
                    fvar[ch] = v
            fc = int(comp.fits_comp.kid_to_cluster[kid])
            if fits_seqs is not None:
                fit[i] = fits_seqs[fc][fits_cursor[fc]]
            else:
                fit[i] = fits_dec[fc].decode_symbol_bitwise(fits_readers[fc])
            fits_cursor[fc] += 1
        yield Tree(feature, threshold, left, right, fit)


class StackedForest:
    """Decoded forest as padded (T, max_nodes) arrays ready for the batched
    traversal.  Leaves self-loop (children point at the leaf itself), so a
    fixed ``max_depth`` level loop needs no active mask; ``feature`` and
    ``threshold`` are clamped to >= 0 (their value at a self-looping leaf is
    irrelevant to routing)."""

    __slots__ = ("feature", "threshold", "left", "right", "fit", "max_depth")

    def __init__(self, trees: list[Tree], max_depth: int):
        t = len(trees)
        m = max(tr.n_nodes for tr in trees)
        self.max_depth = max_depth
        self.feature = np.zeros((t, m), dtype=np.int32)
        self.threshold = np.zeros((t, m), dtype=np.int32)
        self.left = np.zeros((t, m), dtype=np.int32)
        self.right = np.zeros((t, m), dtype=np.int32)
        self.fit = np.zeros((t, m), dtype=np.int32)
        for k, tr in enumerate(trees):
            nn = tr.n_nodes
            leaf = tr.feature < 0
            ids = np.arange(nn, dtype=np.int32)
            self.feature[k, :nn] = np.maximum(tr.feature, 0)
            self.threshold[k, :nn] = np.maximum(tr.threshold, 0)
            self.left[k, :nn] = np.where(leaf, ids, tr.children_left)
            self.right[k, :nn] = np.where(leaf, ids, tr.children_right)
            self.fit[k, :nn] = tr.node_fit


def stacked_forest(comp: CompressedForest) -> StackedForest:
    """Decode + stack, memoized on the CompressedForest instance: a serving
    process decodes once and predicts many batches against the same bytes."""
    cached = getattr(comp, "_stacked_cache", None)
    if cached is None:
        cached = StackedForest(list(iter_trees(comp)), comp.max_depth)
        comp._stacked_cache = cached
    return cached


_jax_traverse = None  # resolved lazily; False => jax unavailable


def _get_jax_traverse():
    global _jax_traverse
    if _jax_traverse is None:
        try:
            import functools

            import jax
            import jax.numpy as jnp

            @functools.partial(jax.jit, static_argnames=("depth",))
            def traverse(feat, thr, lft, rgt, fit, xb, depth):
                nn = xb.shape[0]
                xb_t = xb.T
                cols = jnp.arange(nn)[None, :]
                idx = jnp.zeros((feat.shape[0], nn), jnp.int32)

                def level(_, idx):
                    fe = jnp.take_along_axis(feat, idx, axis=1)
                    xv = xb_t[fe, cols]
                    go_left = xv <= jnp.take_along_axis(thr, idx, axis=1)
                    return jnp.where(
                        go_left,
                        jnp.take_along_axis(lft, idx, axis=1),
                        jnp.take_along_axis(rgt, idx, axis=1),
                    )

                idx = jax.lax.fori_loop(0, depth, level, idx)
                return jnp.take_along_axis(fit, idx, axis=1)

            _jax_traverse = traverse
        except Exception:  # pragma: no cover - jax is a baked-in dependency
            _jax_traverse = False
    return _jax_traverse or None


def _batched_leaf_fits(sf: StackedForest, x_binned: np.ndarray) -> np.ndarray:
    """(T, N) leaf ``node_fit`` per (tree, observation): one traversal over
    ALL trees at once — the level loop runs max-depth times, not
    n_trees * depth times.  Routing is all-integer, so the result is
    bit-exact regardless of backend (jitted XLA when jax is importable,
    numpy gathers otherwise)."""
    x_binned = np.ascontiguousarray(x_binned, dtype=np.int32)
    traverse = _get_jax_traverse()
    if traverse is not None:
        out = traverse(
            sf.feature, sf.threshold, sf.left, sf.right, sf.fit,
            x_binned, depth=sf.max_depth,
        )
        return np.asarray(out)
    xb_t = np.ascontiguousarray(x_binned.T)
    cols = np.arange(x_binned.shape[0])[None, :]
    idx = np.zeros((sf.feature.shape[0], x_binned.shape[0]), dtype=np.int32)
    for _ in range(sf.max_depth):
        fe = np.take_along_axis(sf.feature, idx, axis=1)
        go_left = xb_t[fe, cols] <= np.take_along_axis(sf.threshold, idx, axis=1)
        idx = np.where(
            go_left,
            np.take_along_axis(sf.left, idx, axis=1),
            np.take_along_axis(sf.right, idx, axis=1),
        )
    return np.take_along_axis(sf.fit, idx, axis=1)


def predict_compressed(
    comp: CompressedForest, x_binned: np.ndarray, engine: str = "table"
) -> np.ndarray:
    """Ensemble prediction for binned observations ``x_binned`` (n, d),
    decoding directly from the compressed representation.

    Returns (n,) float predictions: mean of fit values (regression) or
    majority vote (classification).  Integer traversal and per-tree
    accumulation order are identical to the original node-at-a-time
    implementation, so outputs are bit-exact across engines."""
    meta = comp.meta
    n = x_binned.shape[0]
    if engine == "table":
        leaf_fits = _batched_leaf_fits(stacked_forest(comp), x_binned)
        if meta.task == "classification":
            bc = np.bincount(
                ((np.arange(n) * meta.n_classes)[None, :] + leaf_fits).ravel(),
                minlength=n * meta.n_classes,
            )
            votes = bc.reshape(n, meta.n_classes)
            return votes.argmax(axis=1).astype(np.float64)
        acc = np.zeros(n, dtype=np.float64)
        vals = comp.fit_values[leaf_fits]  # (T, N) float64
        for row in vals:  # sequential per-tree adds: seed accumulation order
            acc += row
        return acc / max(len(vals), 1)

    rows = np.arange(n)
    if meta.task == "classification":
        votes = np.zeros((n, meta.n_classes), dtype=np.int64)
    else:
        acc = np.zeros(n, dtype=np.float64)
    n_trees = 0
    for tree in iter_trees(comp, engine=engine):
        idx = np.zeros(n, dtype=np.int64)
        # vectorized traversal: all observations step down together
        while True:
            feat = tree.feature[idx]
            active = feat >= 0
            if not active.any():
                break
            f = np.maximum(feat, 0)
            go_left = x_binned[rows, f] <= tree.threshold[idx]
            nxt = np.where(go_left, tree.children_left[idx], tree.children_right[idx])
            idx = np.where(active, nxt, idx)
        leaf_fit = tree.node_fit[idx]
        if meta.task == "classification":
            votes[rows, leaf_fit.astype(np.int64)] += 1
        else:
            acc += comp.fit_values[leaf_fit.astype(np.int64)]
        n_trees += 1
    if meta.task == "classification":
        return votes.argmax(axis=1).astype(np.float64)
    return acc / max(n_trees, 1)

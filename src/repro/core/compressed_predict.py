"""Prediction straight from the compressed format (paper §5).

The Huffman prefix property lets us decode symbol-by-symbol; combined with
the preorder emission discipline of forest_codec, the whole forest never
needs to be materialized: we hold ONE tree's Zaks bits (2n+1 bits) plus the
per-cluster stream cursors in RAM, decode a tree, predict with it, drop it,
and move on.  This is the paper's subscriber-device scenario: storage holds
only the compressed bytes; working memory is O(single tree).

Note on laziness: routing through a node requires its variable name, and the
variable name determines which split-value stream every descendant uses — so
variable names of preorder-preceding nodes must be decoded even off-path
(decode-and-discard, no materialization).  The paper's claim is the memory
bound and the direct-from-bytes operation, which is exactly what this module
delivers; tests assert bit-exact agreement with the uncompressed forest.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from .bitio import BitReader
from .forest_codec import CompressedForest
from .lz import lzw_decode_bits
from .tree import Tree
from .zaks import zaks_decode


def iter_trees(comp: CompressedForest) -> Iterator[Tree]:
    """Stream trees one at a time from the compressed bytes."""
    meta = comp.meta
    d = meta.n_features
    zaks_all = lzw_decode_bits(comp.zaks_payload, comp.zaks_total_bits)

    vars_dec = comp.vars_comp.decoders()
    vars_readers = [BitReader(s) for s in comp.vars_comp.streams]
    split_dec = {v: c.decoders() for v, c in comp.splits_comp.items()}
    split_readers = {
        v: [BitReader(s) for s in c.streams]
        for v, c in comp.splits_comp.items()
    }
    fits_dec = comp.fits_comp.decoders()
    if comp.fits_comp.coder == "arithmetic":
        # range decoding is whole-sequence per cluster; decode once, then
        # stream with cursors (still O(#fits) ints, not O(forest) trees).
        fits_seqs = [
            dec.decode(s, n) if n else np.zeros(0, np.int64)
            for dec, s, n in zip(
                fits_dec, comp.fits_comp.streams, comp.fits_comp.n_symbols
            )
        ]
        fits_readers = None
    else:
        fits_seqs = None
        fits_readers = [BitReader(s) for s in comp.fits_comp.streams]
    fits_cursor = [0] * max(
        len(comp.fits_comp.codebook_lengths), len(comp.fits_comp.centroid_freqs)
    )

    off = 0
    for tlen in comp.zaks_lengths:
        bits = zaks_all[off : off + int(tlen)]
        off += int(tlen)
        left, right, is_leaf = zaks_decode(bits)
        n = len(bits)
        feature = np.full(n, -1, dtype=np.int32)
        threshold = np.full(n, -1, dtype=np.int32)
        fit = np.zeros(n, dtype=np.int64)
        depth = np.zeros(n, dtype=np.int32)
        fvar = np.full(n, -1, dtype=np.int32)
        for i in range(n):
            kid = int(depth[i]) * (d + 1) + int(fvar[i]) + 1
            if not is_leaf[i]:
                c = int(comp.vars_comp.kid_to_cluster[kid])
                v = vars_dec[c].decode_symbol(vars_readers[c])
                feature[i] = v
                sc = int(comp.splits_comp[v].kid_to_cluster[kid])
                threshold[i] = split_dec[v][sc].decode_symbol(
                    split_readers[v][sc]
                )
                for ch in (left[i], right[i]):
                    depth[ch] = depth[i] + 1
                    fvar[ch] = v
            fc = int(comp.fits_comp.kid_to_cluster[kid])
            if fits_seqs is not None:
                fit[i] = fits_seqs[fc][fits_cursor[fc]]
            else:
                fit[i] = fits_dec[fc].decode_symbol(fits_readers[fc])
            fits_cursor[fc] += 1
        yield Tree(feature, threshold, left, right, fit)


def predict_compressed(comp: CompressedForest, x_binned: np.ndarray) -> np.ndarray:
    """Ensemble prediction for binned observations ``x_binned`` (n, d),
    decoding directly from the compressed representation.

    Returns (n,) float predictions: mean of fit values (regression) or
    majority vote (classification)."""
    meta = comp.meta
    n = x_binned.shape[0]
    if meta.task == "classification":
        votes = np.zeros((n, meta.n_classes), dtype=np.int64)
    else:
        acc = np.zeros(n, dtype=np.float64)
    n_trees = 0
    for tree in iter_trees(comp):
        idx = np.zeros(n, dtype=np.int64)
        # vectorized traversal: all observations step down together
        while True:
            feat = tree.feature[idx]
            active = feat >= 0
            if not active.any():
                break
            f = np.maximum(feat, 0)
            go_left = (
                x_binned[np.arange(n), f] <= tree.threshold[idx]
            )
            nxt = np.where(go_left, tree.children_left[idx], tree.children_right[idx])
            idx = np.where(active, nxt, idx)
        leaf_fit = tree.node_fit[idx]
        if meta.task == "classification":
            votes[np.arange(n), leaf_fit.astype(np.int64)] += 1
        else:
            acc += comp.fit_values[leaf_fit.astype(np.int64)]
        n_trees += 1
    if meta.task == "classification":
        return votes.argmax(axis=1).astype(np.float64)
    return acc / max(n_trees, 1)

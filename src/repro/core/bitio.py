"""Bit-level IO used by every entropy coder in repro.core.

Host-side (numpy / pure python): entropy coding is inherently sequential,
variable-length work and lives on the coordinator CPU in production; the TPU
handles the dense statistics extraction (see repro.forest / repro.kernels).
"""
from __future__ import annotations

import numpy as np


class BitWriter:
    """Append-only MSB-first bit buffer."""

    __slots__ = ("_bytes", "_cur", "_nbits")

    def __init__(self) -> None:
        self._bytes = bytearray()
        self._cur = 0  # partial byte accumulator
        self._nbits = 0  # bits in accumulator (0..7)

    def write_bit(self, bit: int) -> None:
        self._cur = (self._cur << 1) | (bit & 1)
        self._nbits += 1
        if self._nbits == 8:
            self._bytes.append(self._cur)
            self._cur = 0
            self._nbits = 0

    def write_bits(self, value: int, width: int) -> None:
        """Write ``width`` bits of ``value``, MSB first."""
        for shift in range(width - 1, -1, -1):
            self.write_bit((value >> shift) & 1)

    def write_bitstring(self, bits) -> None:
        for b in bits:
            self.write_bit(int(b))

    def __len__(self) -> int:  # total bits written
        return len(self._bytes) * 8 + self._nbits

    def getvalue(self) -> bytes:
        """Byte-aligned payload; trailing bits padded with zeros."""
        out = bytearray(self._bytes)
        if self._nbits:
            out.append(self._cur << (8 - self._nbits))
        return bytes(out)


class BitReader:
    """MSB-first reader over a bytes payload."""

    __slots__ = ("_data", "_pos")

    def __init__(self, data: bytes, start_bit: int = 0) -> None:
        self._data = data
        self._pos = start_bit

    @property
    def pos(self) -> int:
        return self._pos

    def read_bit(self) -> int:
        byte = self._data[self._pos >> 3]
        bit = (byte >> (7 - (self._pos & 7))) & 1
        self._pos += 1
        return bit

    def read_bits(self, width: int) -> int:
        v = 0
        for _ in range(width):
            v = (v << 1) | self.read_bit()
        return v

    def peek_bits(self, width: int) -> int:
        """Next ``width`` bits MSB-first WITHOUT advancing; positions past the
        end of the payload read as 0 (callers bound the real consumption)."""
        v = 0
        data = self._data
        n_bits = len(data) * 8
        for p in range(self._pos, self._pos + width):
            v <<= 1
            if p < n_bits:
                v |= (data[p >> 3] >> (7 - (p & 7))) & 1
        return v

    def skip(self, n_bits: int) -> None:
        self._pos += n_bits

    def remaining(self) -> int:
        return len(self._data) * 8 - self._pos


def pack_bits(bits: np.ndarray) -> bytes:
    """Vectorized MSB-first packing of a 0/1 array."""
    bits = np.asarray(bits, dtype=np.uint8)
    return np.packbits(bits).tobytes()


def unpack_bits(data: bytes, n_bits: int) -> np.ndarray:
    arr = np.frombuffer(data, dtype=np.uint8)
    return np.unpackbits(arr)[:n_bits]

"""Entropy-coded checkpoint tensors — the paper's scheme transplanted to
LM state (BEYOND-PAPER, reported separately; see DESIGN.md §3).

The paper's premise is that i.i.d. sub-models (trees) share empirical
distributions, so their codebooks can be CLUSTERED (eq. 6) instead of
stored per-model.  Transformer checkpoints have the same structure: the
per-layer weight tensors are near-i.i.d. across depth (and experts across
the expert axis), so their value histograms cluster tightly.

Two modes:
  * LOSSLESS (bf16/fp16): split each tensor into high bytes
    (sign+exponent, heavily skewed -> entropy-codable) and low bytes
    (mantissa tail, ~uniform -> stored raw).  Perfect reconstruction.
  * QUANTIZED (b-bit): §7's uniform quantizer per tensor; distortion
    bounded by step/2 = range/2^{b+1}, the paper's closed-form knob.

Pipeline (mirrors Algorithm 1): histogram per tensor chunk -> KL k-means
clustering of histograms (core.bregman, eq. 6 objective with alpha =
dictionary-line cost) -> one canonical Huffman codebook per cluster ->
vectorized encode (core.vechuff).  Each tensor chunk is an independent
stream, so a restore can decode just the layers it needs — the checkpoint
analogue of predicting from the compressed forest (§5).
"""
from __future__ import annotations

import io
import pickle
from dataclasses import dataclass, field

import numpy as np

from .bregman import cluster_models
from .vechuff import VectorHuffman

_CHUNK = 1 << 16  # symbols per stream: decode parallelism vs overhead


def _split_float(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """float array -> (top-byte symbols [sign+exponent: heavily skewed],
    remaining bytes raw).  Works for any itemsize >= 2."""
    size = arr.dtype.itemsize
    raw = arr.ravel().view(np.uint8).reshape(-1, size)
    # numpy is little-endian here: the top byte is the LAST byte
    hi = raw[:, size - 1].copy()
    rest = raw[:, : size - 1].copy()
    return hi, rest.ravel()


def _join_float(hi: np.ndarray, rest: np.ndarray, dtype, shape) -> np.ndarray:
    size = np.dtype(dtype).itemsize
    n = hi.size
    raw = np.empty((n, size), np.uint8)
    raw[:, : size - 1] = rest.reshape(n, size - 1)
    raw[:, size - 1] = hi
    return raw.ravel().view(dtype).reshape(shape)


def _quantize(arr: np.ndarray, bits: int):
    """§7 uniform quantizer: returns (codes uint16, lo, step)."""
    flat = arr.astype(np.float64).ravel()
    lo, hi = float(flat.min()), float(flat.max())
    n_levels = 1 << bits
    step = max((hi - lo) / n_levels, 1e-300)
    q = np.clip(np.floor((flat - lo) / step), 0, n_levels - 1)
    return q.astype(np.uint16), lo, step


@dataclass
class CompressedTensors:
    """Self-contained compressed checkpoint payload."""

    mode: str  # "lossless" | "quantized"
    bits: int  # alphabet log-size (8 for lossless high bytes)
    tensors: dict  # name -> metadata dict
    cluster_lengths: list  # per-cluster Huffman code lengths
    streams: dict  # name -> list[(blob, n_symbols)]
    raw: dict  # name -> bytes (low bytes / unquantized passthrough)
    n_clusters: int = 0
    stats: dict = field(default_factory=dict)

    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        pickle.dump(self, buf, protocol=4)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, data: bytes) -> "CompressedTensors":
        obj = pickle.loads(data)
        assert isinstance(obj, cls)
        return obj

    @property
    def nbytes(self) -> int:
        total = 0
        for st in self.streams.values():
            total += sum(len(b) for b, _n, _k in st)
        for r in self.raw.values():
            total += len(r)
        for ln in self.cluster_lengths:
            total += len(ln)  # dictionary: one length byte per line
        for meta in self.tensors.values():
            total += 64  # shape/dtype/scale bookkeeping
        return total


def _histograms(symbol_chunks: list[np.ndarray], alphabet: int) -> np.ndarray:
    return np.stack(
        [np.bincount(c, minlength=alphabet) for c in symbol_chunks]
    )


def compress_tensors(
    tree: dict[str, np.ndarray],
    *,
    bits: int | None = None,
    k_max: int = 8,
    seed: int = 0,
) -> CompressedTensors:
    """tree: flat {name: array}. bits=None -> lossless bf16 split mode."""
    if bits is not None and not (1 <= bits <= 12):
        raise ValueError("quantized mode supports 1..12 bits (FSM decoder)")
    mode = "lossless" if bits is None else "quantized"
    alphabet = 256 if bits is None else (1 << bits)

    names: list[str] = []
    chunk_syms: list[np.ndarray] = []
    chunk_owner: list[int] = []
    tensors: dict[str, dict] = {}
    raw: dict[str, bytes] = {}

    for name, arr in tree.items():
        arr = np.asarray(arr)
        meta: dict = {"shape": arr.shape, "dtype": str(arr.dtype)}
        if mode == "lossless":
            if arr.dtype.kind != "f" or arr.dtype.itemsize < 2 \
                    or arr.size == 0:
                raw[name] = arr.tobytes()  # ints/scalars pass through
                meta["passthrough"] = True
                tensors[name] = meta
                continue
            hi, lo = _split_float(arr)
            raw[name] = lo.tobytes()
            syms = hi
        else:
            codes, lo_v, step = _quantize(arr, bits)
            meta["scale"] = (lo_v, step)
            syms = codes
        ti = len(names)
        names.append(name)
        tensors[name] = meta
        for off in range(0, len(syms), _CHUNK):
            chunk_syms.append(syms[off : off + _CHUNK])
            chunk_owner.append(ti)

    if not chunk_syms:
        return CompressedTensors(mode, bits or 8, tensors, [], {}, raw)

    hists = _histograms(chunk_syms, alphabet)
    # alpha: one dictionary line = symbol id + code length byte
    alpha_bits = 8 + np.log2(alphabet)
    res = cluster_models(hists, alpha_bits=alpha_bits, k_max=k_max, seed=seed)

    # build one codebook per cluster from the SUMMED member counts (the
    # centroid may assign zero mass to a symbol a member uses; sums can't)
    books: list[VectorHuffman] = []
    lengths_out = []
    for k in range(res.k):
        members = np.flatnonzero(res.assignments == k)
        counts = hists[members].sum(0) if len(members) else np.ones(alphabet)
        vh = VectorHuffman(_lengths_from_counts(counts))
        books.append(vh)
        lengths_out.append(vh.lengths.astype(np.uint8).tobytes())

    streams: dict[str, list] = {n: [] for n in names}
    for ci, syms in enumerate(chunk_syms):
        k = int(res.assignments[ci])
        blob, _bits = books[k].encode(syms)
        streams[names[chunk_owner[ci]]].append((blob, len(syms), k))

    comp = CompressedTensors(
        mode, bits or 8, tensors, lengths_out, streams, raw, res.k
    )
    comp.stats = {
        "k": res.k,
        "objective_bits": res.objective_bits,
        "coding_loss_bits": res.coding_loss_bits,
        "n_chunks": len(chunk_syms),
    }
    return comp


def _lengths_from_counts(counts: np.ndarray) -> np.ndarray:
    from .huffman import code_lengths

    return code_lengths(counts)


def decompress_tensors(
    comp: CompressedTensors, names: list[str] | None = None
) -> dict[str, np.ndarray]:
    """Decode all tensors (or just ``names`` — layer-on-demand restore)."""
    books = [
        VectorHuffman(np.frombuffer(ln, dtype=np.uint8).astype(np.int64))
        for ln in comp.cluster_lengths
    ]
    want = set(names) if names is not None else set(comp.tensors)
    out: dict[str, np.ndarray] = {}

    # group chunks by codebook so each decode_streams call is big
    jobs: dict[int, list] = {}
    for name, chunks in comp.streams.items():
        if name not in want:
            continue
        for pos, (blob, n, k) in enumerate(chunks):
            jobs.setdefault(k, []).append((name, pos, blob, n))
    decoded: dict[tuple[str, int], np.ndarray] = {}
    for k, items in jobs.items():
        blobs = [b for _, _, b, _ in items]
        ns = np.array([n for _, _, _, n in items])
        res = books[k].decode_streams(blobs, ns)
        for (name, pos, _, _), syms in zip(items, res):
            decoded[(name, pos)] = syms

    for name, meta in comp.tensors.items():
        if name not in want:
            continue
        shape, dtype = meta["shape"], np.dtype(meta["dtype"])
        if meta.get("passthrough"):
            out[name] = np.frombuffer(comp.raw[name], dtype=dtype).reshape(shape)
            continue
        chunks = comp.streams[name]
        syms = np.concatenate(
            [decoded[(name, i)] for i in range(len(chunks))]
        ) if chunks else np.zeros(0, np.int64)
        if comp.mode == "lossless":
            lo = np.frombuffer(comp.raw[name], dtype=np.uint8)
            out[name] = _join_float(syms.astype(np.uint8), lo, dtype, shape)
        else:
            lo_v, step = meta["scale"]
            vals = lo_v + (syms.astype(np.float64) + 0.5) * step
            out[name] = vals.astype(dtype).reshape(shape)
    return out


def flatten_pytree(tree, prefix="") -> dict[str, np.ndarray]:
    """dict-pytree -> flat {path: np.ndarray} (jax arrays converted)."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(flatten_pytree(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def unflatten_pytree(flat: dict[str, np.ndarray]):
    root: dict = {}
    for path, arr in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root

"""Lossless forest compression — the paper's Algorithm 1.

Pipeline
--------
1. Structure: per-tree Zaks sequences, concatenated, LZW-coded (§3.1).
2. Variable names: empirical models P(var | depth, father's var), clustered
   with KL K-means under objective (6); one canonical-Huffman codebook per
   cluster (§3.2).
3. Split values: per-variable models P(split | depth, var, father's var),
   clustered per variable (Algorithm 1 line 39).
4. Fits: P(fit | depth, father's var); Huffman, or arithmetic coding for
   two-class problems (Algorithm 1 line 40 / §4).

Symbols are emitted in GLOBAL PREORDER (tree by tree, preorder within a
tree) into one bitstream per cluster.  The decoder reproduces the exact
same order from the decoded structure + already-decoded parents, so the
streams need no per-node framing.  (Algorithm 1 groups per-model sequences
inside each cluster; interleaving by preorder is rate-identical under the
same codebook and enables streaming prediction — see compressed_predict.)

Everything here is byte-honest: ``CompressedForest.to_bytes()`` is a real
serialization, and the size report in ``size_report()`` is measured from
those bytes, bucketed as in the paper's Table 1.

Codebook ownership is pluggable: the preorder stream emission
(``emit_streams``) is driven by ``ComponentCodec`` objects — a kid→cluster
map plus one symbol coder per cluster — and does not care where the
codebooks live.  ``compress_forest`` builds them inline per forest (the
paper's single-subscriber format); the multi-tenant store
(``repro.store``) builds them against fleet-level shared codebooks and
stores only per-user residual streams.
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass, field

import numpy as np

from .arithmetic import ArithmeticCode
from .bitio import BitReader, BitWriter
from .bregman import ClusteringResult, cluster_models
from .framing import (
    check_crc,
    expect_magic,
    read_arr,
    read_bytes,
    read_struct,
    with_crc,
    write_arr,
    write_bytes,
)
from .huffman import HuffmanCode
from .lz import lzw_decode_bits, lzw_encode_bits
from .stats import (
    alpha_fits,
    alpha_splits,
    alpha_vars,
    extract_records,
    fit_counts,
    key_id,
    split_counts,
    var_name_counts,
)
from .tree import Forest, ForestMeta, Tree
from .zaks import zaks_decode, zaks_encode


# --------------------------------------------------------------------------
# component containers
# --------------------------------------------------------------------------
@dataclass
class ClusteredComponent:
    """One compressed component: cluster map + per-cluster codebooks+streams."""

    kid_to_cluster: np.ndarray  # (n_keys,) int16; -1 for unused keys
    codebook_lengths: list[np.ndarray]  # per cluster: (B,) Huffman lengths
    streams: list[bytes]  # per cluster: coded payload
    n_symbols: list[int]  # per cluster: symbol count
    coder: str = "huffman"  # or "arithmetic"
    centroid_freqs: list[np.ndarray] = field(default_factory=list)  # arithmetic

    def decoders(self):
        if self.coder == "huffman":
            return [HuffmanCode(l) for l in self.codebook_lengths]
        return [ArithmeticCode(f) for f in self.centroid_freqs]


@dataclass
class ComponentCodec:
    """A component's resolved coding state with pluggable codebook ownership:
    the kid→cluster map plus one symbol coder per cluster id.  ``coders``
    entries may be None for clusters the map never references (external
    store codebooks the forest at hand does not use)."""

    kid_to_cluster: np.ndarray
    coders: list

    @classmethod
    def of_component(cls, c: ClusteredComponent) -> "ComponentCodec":
        return cls(c.kid_to_cluster, c.decoders())

    @property
    def n_clusters(self) -> int:
        return len(self.coders)


def emit_streams(
    rec,
    d: int,
    vars_codec: ComponentCodec,
    split_codecs: dict[int, ComponentCodec],
    fits_codec: ComponentCodec,
    fit_syms_global: np.ndarray,
):
    """Encode every per-node symbol in GLOBAL PREORDER into per-cluster
    streams, against whatever codebooks the ``ComponentCodec``s resolve to
    (inline per-forest, or fleet-shared plus user-local).

    Vars/splits are Huffman symbol-at-a-time; fits are gathered per cluster
    and whole-sequence coded (required by the arithmetic coder, harmless for
    Huffman).  Returns ``(vars_streams, vars_n, split_streams, split_n,
    fits_streams, fits_n)`` where the split entries are per-variable dicts.
    """
    kid_all = key_id(rec.depth, rec.father_var, d)

    vars_writers = [BitWriter() for _ in vars_codec.coders]
    vars_n = [0] * vars_codec.n_clusters
    split_writers = {
        v: [BitWriter() for _ in c.coders] for v, c in split_codecs.items()
    }
    split_n = {v: [0] * c.n_clusters for v, c in split_codecs.items()}
    fits_seq_per_cluster: list[list[int]] = [
        [] for _ in range(fits_codec.n_clusters)
    ]

    internal = ~rec.is_leaf
    for i in range(len(rec.depth)):
        kid = int(kid_all[i])
        if internal[i]:
            c = int(vars_codec.kid_to_cluster[kid])
            vars_codec.coders[c].encode_symbol(vars_writers[c], int(rec.var[i]))
            vars_n[c] += 1
            v = int(rec.var[i])
            sc = int(split_codecs[v].kid_to_cluster[kid])
            split_codecs[v].coders[sc].encode_symbol(
                split_writers[v][sc], int(rec.split[i])
            )
            split_n[v][sc] += 1
        fc = int(fits_codec.kid_to_cluster[kid])
        fits_seq_per_cluster[fc].append(int(fit_syms_global[i]))

    vars_streams = [w.getvalue() for w in vars_writers]
    split_streams = {
        v: [w.getvalue() for w in ws] for v, ws in split_writers.items()
    }
    fits_streams = [
        fits_codec.coders[c].encode(seq) if len(seq) else b""
        for c, seq in enumerate(fits_seq_per_cluster)
    ]
    fits_n = [len(s) for s in fits_seq_per_cluster]
    return vars_streams, vars_n, split_streams, split_n, fits_streams, fits_n


#: magic of the inline single-forest frame (legacy format; docs/format.md §7)
_RFC_MAGIC = b"RFC1"


def _write_rfc_component(out: io.BytesIO, c: ClusteredComponent) -> None:
    """Write one RFC1 COMPONENT record (mirror of ``_read_rfc_component``):
    u8 coder flag, ARR cluster map, u16 cluster count, then per cluster an
    ARR codebook table, u32 symbol count, and a BYTES stream."""
    out.write(struct.pack("<B", 1 if c.coder == "arithmetic" else 0))
    write_arr(out, c.kid_to_cluster.astype(np.int16))
    out.write(struct.pack("<H", len(c.streams)))
    for k in range(len(c.streams)):
        if c.coder == "huffman":
            write_arr(out, c.codebook_lengths[k].astype(np.uint8))
        else:
            write_arr(out, c.centroid_freqs[k].astype(np.uint32))
        out.write(struct.pack("<I", c.n_symbols[k]))
        write_bytes(out, c.streams[k])


def _read_rfc_component(inp: io.BytesIO) -> ClusteredComponent:
    """Read one RFC1 COMPONENT record written by ``_write_rfc_component``."""
    (is_arith,) = read_struct(inp, "<B", "RFC1 component coder flag")
    kid_to_cluster = read_arr(inp).astype(np.int16)
    (nk,) = read_struct(inp, "<H", "RFC1 component cluster count")
    lengths, freqs, streams, n_symbols = [], [], [], []
    for _ in range(nk):
        tab = read_arr(inp)
        if is_arith:
            freqs.append(tab.astype(np.int64))
            lengths.append(np.zeros(0, np.int32))
        else:
            lengths.append(tab.astype(np.int32))
        (ns,) = read_struct(inp, "<I", "RFC1 component symbol count")
        n_symbols.append(ns)
        streams.append(read_bytes(inp))
    return ClusteredComponent(
        kid_to_cluster, lengths, streams, n_symbols,
        "arithmetic" if is_arith else "huffman", freqs,
    )


@dataclass
class CompressedForest:
    meta: ForestMeta
    n_trees: int
    zaks_payload: bytes
    zaks_total_bits: int
    zaks_lengths: np.ndarray  # (n_trees,) int32 — bits per tree
    vars_comp: ClusteredComponent
    splits_comp: dict[int, ClusteredComponent]  # per variable
    fits_comp: ClusteredComponent
    fit_values: np.ndarray  # regression: distinct 64-bit fit values
    max_depth: int

    # ---------------- size accounting (paper Table 1 buckets) -------------
    def size_report(self) -> dict[str, float]:
        def comp_stream_bytes(c: ClusteredComponent) -> int:
            return sum(len(s) for s in c.streams)

        def comp_dict_bytes(c: ClusteredComponent) -> int:
            b = len(c.kid_to_cluster) * 2  # cluster map, int16/line
            for lengths in c.codebook_lengths:
                b += int((np.asarray(lengths) > 0).sum()) * 2  # (sym,len) lines
            for f in c.centroid_freqs:
                b += int((np.asarray(f) > 0).sum()) * 4
            return b

        structure = len(self.zaks_payload) + len(self.zaks_lengths) * 4
        names = comp_stream_bytes(self.vars_comp)
        splits = sum(comp_stream_bytes(c) for c in self.splits_comp.values())
        fits = comp_stream_bytes(self.fits_comp)
        dicts = (
            comp_dict_bytes(self.vars_comp)
            + sum(comp_dict_bytes(c) for c in self.splits_comp.values())
            + comp_dict_bytes(self.fits_comp)
            + self.fit_values.size * 8  # 64-bit fit-value dictionary
        )
        total = structure + names + splits + fits + dicts
        return {
            "structure": structure,
            "var_names": names,
            "split_values": splits,
            "fits": fits,
            "dictionaries": dicts,
            "total": total,
            "total_serialized": len(self.to_bytes()),
        }

    # ---------------- serialization ---------------------------------------
    def to_bytes(self) -> bytes:
        m = self.meta
        out = io.BytesIO()
        out.write(_RFC_MAGIC)
        out.write(
            struct.pack(
                "<IIHIB", self.n_trees, m.n_features, m.n_classes,
                m.n_train_obs, 1 if m.task == "regression" else 0,
            )
        )
        out.write(struct.pack("<HI", self.max_depth, self.zaks_total_bits))
        write_arr(out, m.n_bins_per_feature.astype(np.int32))
        write_arr(out, m.categorical.astype(np.uint8))
        write_arr(out, self.zaks_lengths.astype(np.int32))
        write_bytes(out, self.zaks_payload)
        _write_rfc_component(out, self.vars_comp)
        out.write(struct.pack("<H", len(self.splits_comp)))
        for v, c in sorted(self.splits_comp.items()):
            out.write(struct.pack("<H", v))
            _write_rfc_component(out, c)
        _write_rfc_component(out, self.fits_comp)
        write_arr(out, self.fit_values.astype(np.float64))
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "CompressedForest":
        """Parse one RFC1 frame.  The CRC32 trailer is verified when
        present (pre-ISSUE-9 frames without one still parse); truncated
        or corrupted frames raise a typed ``core.framing.FramingError``
        instead of ``struct.error`` / ``AssertionError``."""
        inp = io.BytesIO(check_crc(data, "RFC1 compressed forest"))
        expect_magic(inp, _RFC_MAGIC, "RFC1 compressed forest")
        n_trees, d, n_classes, n_obs, is_reg = read_struct(
            inp, "<IIHIB", "RFC1 header"
        )
        max_depth, zaks_total_bits = read_struct(
            inp, "<HI", "RFC1 structure header"
        )
        n_bins = read_arr(inp).astype(np.int32)
        categorical = read_arr(inp).astype(bool)
        meta = ForestMeta(
            n_features=d,
            task="regression" if is_reg else "classification",
            n_classes=n_classes,
            n_bins_per_feature=n_bins,
            n_train_obs=n_obs,
            categorical=categorical,
        )
        zaks_lengths = read_arr(inp).astype(np.int32)
        zaks_payload = read_bytes(inp)
        vars_comp = _read_rfc_component(inp)
        (nsplit,) = read_struct(inp, "<H", "RFC1 split-component count")
        splits_comp = {}
        for _ in range(nsplit):
            (v,) = read_struct(inp, "<H", "RFC1 split variable id")
            splits_comp[v] = _read_rfc_component(inp)
        fits_comp = _read_rfc_component(inp)
        fit_values = read_arr(inp).astype(np.float64)
        return cls(
            meta, n_trees, zaks_payload, zaks_total_bits, zaks_lengths,
            vars_comp, splits_comp, fits_comp, fit_values, max_depth,
        )


# --------------------------------------------------------------------------
# encoder
# --------------------------------------------------------------------------
def _build_component(
    counts: np.ndarray,
    alpha_bits: float,
    coder: str,
    k_max: int,
    seed: int,
) -> tuple[ClusteredComponent, ClusteringResult]:
    """Cluster the models and build per-cluster codebooks.

    Codebooks are built from the SUM OF MEMBER COUNTS (the empirical
    distribution the cluster actually codes) — this is the Huffman code "for
    Q_k" and guarantees every coded symbol has a codeword (paper §5)."""
    used = np.flatnonzero(counts.sum(-1) > 0)
    full_map = np.full(counts.shape[0], -1, dtype=np.int16)
    if len(used) == 0:
        comp = ClusteredComponent(full_map, [], [], [], coder, [])
        return comp, ClusteringResult(np.zeros(0, int), np.zeros((0, 0)), 0, 0, 0, 0)
    res = cluster_models(counts[used], alpha_bits, k_max=k_max, seed=seed)
    # compact cluster ids to 0..K-1
    uniq, compact = np.unique(res.assignments, return_inverse=True)
    full_map[used] = compact.astype(np.int16)
    k = len(uniq)
    codebooks, cfreqs = [], []
    for c in range(k):
        member_counts = counts[used][compact == c].sum(0)
        if coder == "huffman":
            codebooks.append(HuffmanCode.from_freqs(member_counts).lengths)
            cfreqs.append(np.zeros(0, np.int64))
        else:
            codebooks.append(np.zeros(0, np.int32))
            cfreqs.append(member_counts.astype(np.int64))
    comp = ClusteredComponent(full_map, codebooks, [], [], coder, cfreqs)
    return comp, res


def compress_forest(
    forest: Forest, k_max: int = 12, seed: int = 0
) -> CompressedForest:
    meta = forest.meta
    d = meta.n_features
    rec = extract_records(forest)
    t_max = int(rec.depth.max()) + 1 if len(rec.depth) else 1

    # ---- 1. structure ----------------------------------------------------
    zaks_list = [zaks_encode(t) for t in forest.trees]
    zaks_lengths = np.array([len(z) for z in zaks_list], dtype=np.int32)
    zaks_all = (
        np.concatenate(zaks_list) if zaks_list else np.zeros(0, np.uint8)
    )
    zaks_payload = lzw_encode_bits(zaks_all)

    # ---- 2. variable names -----------------------------------------------
    v_counts = var_name_counts(rec, d, t_max)
    vars_comp, _ = _build_component(
        v_counts, alpha_vars(d), "huffman", k_max, seed
    )

    # ---- 3. split values (per variable) ----------------------------------
    s_counts = split_counts(rec, d, t_max, meta.n_bins_per_feature)
    splits_comp: dict[int, ClusteredComponent] = {}
    for v, cnts in s_counts.items():
        a = alpha_splits(
            not bool(meta.categorical[v]),
            meta.n_train_obs,
            int(meta.n_bins_per_feature[v]),
        )
        splits_comp[v], _ = _build_component(cnts, a, "huffman", k_max, seed)

    # ---- 4. fits -----------------------------------------------------------
    if meta.task == "classification":
        n_fit_syms = meta.n_classes
        fit_values = np.zeros(0, np.float64)
        fit_syms_global = rec.fit.astype(np.int64)
        fits_coder = "arithmetic" if meta.n_classes == 2 else "huffman"
    else:
        # regression: node fits are already indices into forest.fit_values
        fit_values = np.asarray(forest.fit_values, dtype=np.float64)
        n_fit_syms = len(fit_values)
        fit_syms_global = rec.fit.astype(np.int64)
        fits_coder = "huffman"
    f_counts = fit_counts(rec, d, t_max, n_fit_syms)
    fits_comp, _ = _build_component(
        f_counts, alpha_fits(meta.task, n_fit_syms), fits_coder, k_max, seed
    )

    # ---- 5. emit streams in global preorder --------------------------------
    vs, vn, ss, sn, fs, fn = emit_streams(
        rec, d,
        ComponentCodec.of_component(vars_comp),
        {v: ComponentCodec.of_component(c) for v, c in splits_comp.items()},
        ComponentCodec.of_component(fits_comp),
        fit_syms_global,
    )
    vars_comp.streams = vs
    vars_comp.n_symbols = vn
    for v, c in splits_comp.items():
        c.streams = ss[v]
        c.n_symbols = sn[v]
    fits_comp.streams = fs
    fits_comp.n_symbols = fn

    return CompressedForest(
        meta=meta,
        n_trees=forest.n_trees,
        zaks_payload=zaks_payload,
        zaks_total_bits=int(zaks_lengths.sum()),
        zaks_lengths=zaks_lengths,
        vars_comp=vars_comp,
        splits_comp=splits_comp,
        fits_comp=fits_comp,
        fit_values=fit_values,
        max_depth=t_max - 1,
    )


# --------------------------------------------------------------------------
# decoder (full reconstruction; streaming prediction lives in
# compressed_predict.py)
# --------------------------------------------------------------------------
def decompress_forest(comp: CompressedForest) -> Forest:
    meta = comp.meta
    d = meta.n_features

    zaks_all = lzw_decode_bits(comp.zaks_payload, comp.zaks_total_bits)
    vars_dec = comp.vars_comp.decoders()
    vars_readers = [BitReader(s) for s in comp.vars_comp.streams]
    split_dec = {v: c.decoders() for v, c in comp.splits_comp.items()}
    split_readers = {
        v: [BitReader(s) for s in c.streams]
        for v, c in comp.splits_comp.items()
    }
    # arithmetic/huffman fits: decode each cluster's full symbol sequence up
    # front, then consume in preorder.
    fits_dec = comp.fits_comp.decoders()
    fits_seqs = [
        dec.decode(s, n) if n else np.zeros(0, np.int64)
        for dec, s, n in zip(
            fits_dec, comp.fits_comp.streams, comp.fits_comp.n_symbols
        )
    ]
    fits_cursor = [0] * len(fits_seqs)

    trees = []
    off = 0
    for tlen in comp.zaks_lengths:
        bits = zaks_all[off : off + int(tlen)]
        off += int(tlen)
        left, right, is_leaf = zaks_decode(bits)
        n = len(bits)
        feature = np.full(n, -1, dtype=np.int32)
        threshold = np.full(n, -1, dtype=np.int32)
        fit = np.zeros(n, dtype=np.int64)
        depth = np.zeros(n, dtype=np.int32)
        fvar = np.full(n, -1, dtype=np.int32)
        for i in range(n):  # preorder; parents precede children
            kid = int(depth[i]) * (d + 1) + int(fvar[i]) + 1
            if not is_leaf[i]:
                c = int(comp.vars_comp.kid_to_cluster[kid])
                v = vars_dec[c].decode_symbol(vars_readers[c])
                feature[i] = v
                sc = int(comp.splits_comp[v].kid_to_cluster[kid])
                threshold[i] = split_dec[v][sc].decode_symbol(
                    split_readers[v][sc]
                )
                for ch in (left[i], right[i]):
                    depth[ch] = depth[i] + 1
                    fvar[ch] = v
            fc = int(comp.fits_comp.kid_to_cluster[kid])
            fit[i] = fits_seqs[fc][fits_cursor[fc]]
            fits_cursor[fc] += 1
        trees.append(Tree(feature, threshold, left, right, fit))
    return Forest(trees=trees, meta=meta, fit_values=comp.fit_values)

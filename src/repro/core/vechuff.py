"""Vectorized canonical Huffman codec over MANY independent streams.

The paper's per-tree Huffman coding is fine in pure Python; checkpoint
tensors have 1e8 symbols, so the tensor codec (tensor_codec.py) needs a
numpy-vectorized path:

  * ENCODE: per-symbol (code, length) lookup, then one flat bit-scatter +
    np.packbits — O(total bits) without a Python per-symbol loop.
  * DECODE: canonical decoding advanced bit-synchronously across all
    streams at once (the classic first_code/offset-per-length tables);
    the Python loop is over BITS-PER-STREAM, not total symbols, so
    decoding N streams of length L costs O(L * max_len) vector steps.

Streams are independent (one per tensor chunk) — which is also what lets
a restore path decode only the layers it needs (the paper's
predict-from-compressed property, §5).
"""
from __future__ import annotations

import numpy as np

from .huffman import DecodeTables, build_decode_tables


def _byte_windows(blob: bytes) -> np.ndarray:
    """(n_bytes + 1,) uint64 array: ``u[q]`` is the big-endian 64-bit value of
    payload bytes q..q+7 (zero-padded past the end).  The window of any bit
    position ``p`` is then one shift of ``u[p >> 3]`` — no per-bit unpacking."""
    raw = np.frombuffer(blob, dtype=np.uint8)
    padded = np.concatenate([raw, np.zeros(8, np.uint8)])
    u = np.zeros(raw.size + 1, dtype=np.uint64)
    for k in range(8):
        u = (u << np.uint64(8)) | padded[k : k + u.size].astype(np.uint64)
    return u


def decode_stream(t: DecodeTables, blob: bytes, n: int) -> np.ndarray:
    """Table-driven whole-stream canonical Huffman decode.

    Two strategies share the same tables, picked by symbol density:

    * dense streams (short codes): speculatively decode a symbol at EVERY
      bit offset — codes of length <= lut_bits resolve with one LUT gather;
      longer codes get their length from one searchsorted over the
      left-aligned canonical range ``ends`` and their symbol from rank
      arithmetic — then follow the true decode chain 0 -> +len(sym_0) -> ...
      through the precomputed successor list (all per-bit work is numpy; the
      only Python loop is the O(n_symbols) chain walk over plain lists);
    * sparse streams (avg code length > ~8 bits, e.g. regression fit
      alphabets with 1e4+ symbols): the all-positions pass would waste most
      of its work, so walk the chain directly, resolving each symbol with
      one LUT probe into the 64-bit byte-window table.
    """
    n = int(n)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    if t.max_len == 0:
        raise ValueError("corrupt Huffman stream")
    if t.max_len > 57:  # 64-bit windows can't hold offset+code; rare/corrupt
        return _decode_stream_bitwise(t, blob, n)
    nbytes = len(blob)
    L = nbytes * 8
    if L == 0:
        raise ValueError("truncated Huffman stream")
    u = _byte_windows(blob)
    if L > 8 * n:  # sparse: per-symbol LUT chase beats the all-positions pass
        return _decode_chase(t, u, L, n)
    p = np.arange(L, dtype=np.int64)
    uq = u[p >> 3]
    off = (p & 7).astype(np.uint64)
    w = t.lut_bits
    win = ((uq >> (np.uint64(64 - w) - off)) & np.uint64((1 << w) - 1)).astype(
        np.int64
    )
    sym_at = t.lut_sym[win]
    len_at = t.lut_len[win]
    if t.max_len > w:
        hard = np.flatnonzero(sym_at < 0)
        if hard.size:
            ml = t.max_len
            vmax = (
                (u[hard >> 3] >> (np.uint64(64 - ml) - (hard & 7).astype(np.uint64)))
                & np.uint64((1 << ml) - 1)
            ).astype(np.int64)
            li = np.searchsorted(t.ends, vmax, side="right")
            ok = li < len(t.ends)
            length = np.minimum(li, len(t.ends) - 1) + 1
            offv = (vmax >> (ml - length)) - t.first_code[length]
            rank = t.rank_base[length] + offv
            ok &= (offv >= 0) & (offv < t.count_at[length])
            rank = np.clip(rank, 0, max(len(t.sym_by_rank) - 1, 0))
            sym_at[hard] = np.where(ok, t.sym_by_rank[rank], -1)
            len_at[hard] = np.where(ok, length, 0)
    # successor list; a symbol is only real if its code fits in the payload
    complete = (len_at > 0) & (p + len_at <= L)
    nxt = np.where(complete, p + len_at, L).tolist()
    syms = np.where(complete, sym_at, -1).tolist()
    out = []
    append = out.append
    pos = 0
    for _ in range(n):
        if pos >= L:
            raise ValueError("truncated Huffman stream")
        s = syms[pos]
        if s < 0:
            raise ValueError("corrupt Huffman stream")
        append(s)
        pos = nxt[pos]
    return np.array(out, dtype=np.int64)


def _decode_chase(t: DecodeTables, u: np.ndarray, L: int, n: int) -> np.ndarray:
    """Per-symbol chain walk: one 64-bit window shift + LUT probe per symbol,
    canonical ``ends``-bisect fallback for codes longer than the LUT."""
    from bisect import bisect_right

    u_l = u.tolist()
    lut_sym = t.lut_sym.tolist()
    lut_len = t.lut_len.tolist()
    ends = t.ends.tolist()
    first_code = t.first_code.tolist()
    count_at = t.count_at.tolist()
    rank_base = t.rank_base.tolist()
    sym_by_rank = t.sym_by_rank.tolist()
    w = t.lut_bits
    ml = t.max_len
    wmask = (1 << w) - 1
    mmask = (1 << ml) - 1
    out = []
    append = out.append
    pos = 0
    for _ in range(n):
        q = u_l[pos >> 3]
        r = pos & 7
        win = (q >> (64 - w - r)) & wmask
        s = lut_sym[win]
        if s >= 0:
            length = lut_len[win]
        else:
            v = (q >> (64 - ml - r)) & mmask
            li = bisect_right(ends, v)
            if li >= ml:
                raise ValueError("corrupt Huffman stream")
            length = li + 1
            off = (v >> (ml - length)) - first_code[length]
            if not 0 <= off < count_at[length]:
                raise ValueError("corrupt Huffman stream")
            s = sym_by_rank[rank_base[length] + off]
        pos += length
        if pos > L:
            raise ValueError("truncated Huffman stream")
        append(s)
    return np.array(out, dtype=np.int64)


def _decode_stream_bitwise(t: DecodeTables, blob: bytes, n: int) -> np.ndarray:
    """Per-symbol canonical decode (fallback for > 57-bit codes)."""
    bits = np.unpackbits(np.frombuffer(blob, dtype=np.uint8)).tolist()
    L = len(bits)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    for i in range(n):
        code = 0
        length = 0
        while True:
            if pos >= L:
                raise ValueError("truncated Huffman stream")
            code = (code << 1) | bits[pos]
            pos += 1
            length += 1
            if length > t.max_len:
                raise ValueError("corrupt Huffman stream")
            offv = code - int(t.first_code[length])
            if 0 <= offv < int(t.count_at[length]):
                out[i] = int(t.sym_by_rank[int(t.rank_base[length]) + offv])
                break
    return out


class VectorHuffman:
    """Canonical Huffman codec with vectorized encode/decode.

    lengths: (B,) int array of code lengths (0 = absent symbol).
    """

    def __init__(self, lengths: np.ndarray):
        self.lengths = np.asarray(lengths, dtype=np.int64)
        # shared table-driven canonical decode state (per-length first_code /
        # rank_base + width-min(max_len, 12) LUT) — see huffman.DecodeTables.
        t = build_decode_tables(self.lengths)
        self.tables = t
        self.max_len = t.max_len
        self.sym_by_rank = t.sym_by_rank
        self.first_code = t.first_code
        self.count_at = t.count_at
        self.rank_base = t.rank_base
        # per-symbol canonical codes from rank arithmetic (encode side)
        self.code_of = np.zeros(len(self.lengths), dtype=np.uint64)
        if t.sym_by_rank.size:
            lens_sorted = self.lengths[t.sym_by_rank]
            ranks = np.arange(t.sym_by_rank.size, dtype=np.int64)
            codes = t.first_code[lens_sorted] + (ranks - t.rank_base[lens_sorted])
            self.code_of[t.sym_by_rank] = codes.astype(np.uint64)

    # -- encode ------------------------------------------------------------
    def encode(self, symbols: np.ndarray) -> tuple[bytes, int]:
        """symbols (N,) -> (packed bytes, total bits)."""
        symbols = np.asarray(symbols).ravel()
        lens = self.lengths[symbols]
        codes = self.code_of[symbols].astype(np.uint64)
        total = int(lens.sum())
        if total == 0:
            return b"", 0
        ends = np.cumsum(lens)
        starts = ends - lens
        # flat index of every bit: for symbol i, bits land at starts[i]..ends[i)
        within = np.arange(total, dtype=np.int64) - np.repeat(starts, lens)
        rep_codes = np.repeat(codes, lens)
        rep_lens = np.repeat(lens, lens)
        shift = (rep_lens - 1 - within).astype(np.uint64)
        bits = ((rep_codes >> shift) & np.uint64(1)).astype(np.uint8)
        return np.packbits(bits).tobytes(), total

    # -- decode ------------------------------------------------------------
    # Byte-level finite state machine: states are the internal nodes of the
    # code tree; one transition per INPUT BYTE emits 0..8 symbols.  The
    # Python loop is over stream BYTES (vectorized across streams), so
    # decoding cost is O(compressed bytes / n_streams) iterations.
    _MAX_FSM_ALPHABET = 4096  # table build is O(states * 2048)

    def _build_fsm(self):
        if getattr(self, "_fsm", None) is not None:
            return
        if int((self.lengths > 0).sum()) > self._MAX_FSM_ALPHABET:
            raise ValueError(
                "alphabet too large for the byte-FSM decoder; "
                "use <= 12-bit quantization"
            )
        # rebuild the code tree: children[node] = [left, right]; negative
        # entries encode leaves as -(symbol+1)
        children: list[list[int]] = [[0, 0]]
        for sym in self.sym_by_rank:
            code = int(self.code_of[sym])
            length = int(self.lengths[sym])
            node = 0
            for i in range(length - 1, -1, -1):
                bit = (code >> i) & 1
                if i == 0:
                    children[node][bit] = -(int(sym) + 1)
                else:
                    nxt = children[node][bit]
                    if nxt <= 0:
                        children.append([0, 0])
                        nxt = len(children) - 1
                        children[node][bit] = nxt
                    node = nxt
        n_states = len(children)
        # a byte may finish one pending code AND start/finish floor(8/min)
        # fresh codes
        max_emit = 8 // max(self._min_len(), 1) + 1
        trans = np.zeros((n_states, 256), dtype=np.int32)
        emit_count = np.zeros((n_states, 256), dtype=np.int8)
        emit_syms = np.zeros((n_states, 256, max_emit), dtype=np.int64)
        for s in range(n_states):
            for byte in range(256):
                node = s
                cnt = 0
                for i in range(7, -1, -1):
                    nxt = children[node][(byte >> i) & 1]
                    if nxt <= 0:
                        emit_syms[s, byte, cnt] = -nxt - 1
                        cnt += 1
                        node = 0
                    else:
                        node = nxt
                trans[s, byte] = node
                emit_count[s, byte] = cnt
        self._fsm = (trans, emit_count, emit_syms, max_emit)

    def _min_len(self) -> int:
        nz = self.lengths[self.lengths > 0]
        return int(nz.min()) if nz.size else 1

    def decode_streams(
        self, blobs: list[bytes], n_symbols: np.ndarray
    ) -> list[np.ndarray]:
        """Decode many independent streams with one shared FSM."""
        n_streams = len(blobs)
        if n_streams == 0:
            return []
        self._build_fsm()
        trans, emit_count, emit_syms, max_emit = self._fsm
        n_symbols = np.asarray(n_symbols, dtype=np.int64)
        byte_arrays = [np.frombuffer(b, dtype=np.uint8) for b in blobs]
        max_bytes = max((a.size for a in byte_arrays), default=0)
        data = np.zeros((n_streams, max_bytes), dtype=np.uint8)
        for i, a in enumerate(byte_arrays):
            data[i, : a.size] = a
        max_syms = int(n_symbols.max(initial=0))
        # one scratch slot at the end absorbs post-quota emissions (zero
        # padding of short streams keeps the FSM running; writes past a
        # stream's quota are clamped there and never read back)
        cap = max_syms + 1
        out = np.zeros((n_streams, cap + 1), np.int64)

        state = np.zeros(n_streams, dtype=np.int32)
        pos = np.zeros(n_streams, dtype=np.int64)
        rows = np.arange(n_streams)
        for j in range(max_bytes):
            byte = data[:, j]
            cnt = emit_count[state, byte].astype(np.int64)
            syms = emit_syms[state, byte]  # (n_streams, max_emit)
            for e in range(max_emit):
                w = e < cnt
                idx = np.minimum(pos[w] + e, cap)
                out[rows[w], idx] = syms[w, e]
            pos = np.minimum(pos + cnt, cap)
            state = trans[state, byte]
        if (pos < n_symbols).any():
            raise ValueError("truncated Huffman stream")
        return [out[i, : n_symbols[i]] for i in range(n_streams)]

    # -- single-stream vectorized decode ----------------------------------
    def decode(self, blob: bytes, n: int) -> np.ndarray:
        """Table-driven whole-stream decode (see :func:`decode_stream`)."""
        return decode_stream(self.tables, blob, n)

"""Vectorized canonical Huffman codec over MANY independent streams.

The paper's per-tree Huffman coding is fine in pure Python; checkpoint
tensors have 1e8 symbols, so the tensor codec (tensor_codec.py) needs a
numpy-vectorized path:

  * ENCODE: per-symbol (code, length) lookup, then one flat bit-scatter +
    np.packbits — O(total bits) without a Python per-symbol loop.
  * DECODE: canonical decoding advanced bit-synchronously across all
    streams at once (the classic first_code/offset-per-length tables);
    the Python loop is over BITS-PER-STREAM, not total symbols, so
    decoding N streams of length L costs O(L * max_len) vector steps.

Streams are independent (one per tensor chunk) — which is also what lets
a restore path decode only the layers it needs (the paper's
predict-from-compressed property, §5).
"""
from __future__ import annotations

import numpy as np

from .huffman import canonical_codes


class VectorHuffman:
    """Canonical Huffman codec with vectorized encode/decode.

    lengths: (B,) int array of code lengths (0 = absent symbol).
    """

    def __init__(self, lengths: np.ndarray):
        self.lengths = np.asarray(lengths, dtype=np.int64)
        codes = canonical_codes(self.lengths)
        b = len(self.lengths)
        self.code_of = np.zeros(b, dtype=np.uint64)
        for s, (c, _l) in codes.items():
            self.code_of[s] = c
        self.max_len = int(self.lengths.max(initial=0))
        # canonical decode tables: for each length l, the first canonical
        # code of that length, the number of codes, and the symbol list
        # sorted by (length, symbol).
        order = sorted((int(l), int(s)) for s, l in enumerate(self.lengths) if l)
        self.sym_by_rank = np.array([s for _, s in order], dtype=np.int64)
        self.first_code = np.zeros(self.max_len + 2, dtype=np.int64)
        self.count_at = np.zeros(self.max_len + 2, dtype=np.int64)
        self.rank_base = np.zeros(self.max_len + 2, dtype=np.int64)
        code = 0
        prev_len = 0
        rank = 0
        for length, _s in order:
            code <<= length - prev_len
            if self.count_at[length] == 0:
                self.first_code[length] = code
                self.rank_base[length] = rank
            self.count_at[length] += 1
            code += 1
            rank += 1
            prev_len = length

    # -- encode ------------------------------------------------------------
    def encode(self, symbols: np.ndarray) -> tuple[bytes, int]:
        """symbols (N,) -> (packed bytes, total bits)."""
        symbols = np.asarray(symbols).ravel()
        lens = self.lengths[symbols]
        codes = self.code_of[symbols].astype(np.uint64)
        total = int(lens.sum())
        if total == 0:
            return b"", 0
        ends = np.cumsum(lens)
        starts = ends - lens
        # flat index of every bit: for symbol i, bits land at starts[i]..ends[i)
        within = np.arange(total, dtype=np.int64) - np.repeat(starts, lens)
        rep_codes = np.repeat(codes, lens)
        rep_lens = np.repeat(lens, lens)
        shift = (rep_lens - 1 - within).astype(np.uint64)
        bits = ((rep_codes >> shift) & np.uint64(1)).astype(np.uint8)
        return np.packbits(bits).tobytes(), total

    # -- decode ------------------------------------------------------------
    # Byte-level finite state machine: states are the internal nodes of the
    # code tree; one transition per INPUT BYTE emits 0..8 symbols.  The
    # Python loop is over stream BYTES (vectorized across streams), so
    # decoding cost is O(compressed bytes / n_streams) iterations.
    _MAX_FSM_ALPHABET = 4096  # table build is O(states * 2048)

    def _build_fsm(self):
        if getattr(self, "_fsm", None) is not None:
            return
        if int((self.lengths > 0).sum()) > self._MAX_FSM_ALPHABET:
            raise ValueError(
                "alphabet too large for the byte-FSM decoder; "
                "use <= 12-bit quantization"
            )
        # rebuild the code tree: children[node] = [left, right]; negative
        # entries encode leaves as -(symbol+1)
        children: list[list[int]] = [[0, 0]]
        for sym in self.sym_by_rank:
            code = int(self.code_of[sym])
            length = int(self.lengths[sym])
            node = 0
            for i in range(length - 1, -1, -1):
                bit = (code >> i) & 1
                if i == 0:
                    children[node][bit] = -(int(sym) + 1)
                else:
                    nxt = children[node][bit]
                    if nxt <= 0:
                        children.append([0, 0])
                        nxt = len(children) - 1
                        children[node][bit] = nxt
                    node = nxt
        n_states = len(children)
        # a byte may finish one pending code AND start/finish floor(8/min)
        # fresh codes
        max_emit = 8 // max(self._min_len(), 1) + 1
        trans = np.zeros((n_states, 256), dtype=np.int32)
        emit_count = np.zeros((n_states, 256), dtype=np.int8)
        emit_syms = np.zeros((n_states, 256, max_emit), dtype=np.int64)
        for s in range(n_states):
            for byte in range(256):
                node = s
                cnt = 0
                for i in range(7, -1, -1):
                    nxt = children[node][(byte >> i) & 1]
                    if nxt <= 0:
                        emit_syms[s, byte, cnt] = -nxt - 1
                        cnt += 1
                        node = 0
                    else:
                        node = nxt
                trans[s, byte] = node
                emit_count[s, byte] = cnt
        self._fsm = (trans, emit_count, emit_syms, max_emit)

    def _min_len(self) -> int:
        nz = self.lengths[self.lengths > 0]
        return int(nz.min()) if nz.size else 1

    def decode_streams(
        self, blobs: list[bytes], n_symbols: np.ndarray
    ) -> list[np.ndarray]:
        """Decode many independent streams with one shared FSM."""
        n_streams = len(blobs)
        if n_streams == 0:
            return []
        self._build_fsm()
        trans, emit_count, emit_syms, max_emit = self._fsm
        n_symbols = np.asarray(n_symbols, dtype=np.int64)
        byte_arrays = [np.frombuffer(b, dtype=np.uint8) for b in blobs]
        max_bytes = max((a.size for a in byte_arrays), default=0)
        data = np.zeros((n_streams, max_bytes), dtype=np.uint8)
        for i, a in enumerate(byte_arrays):
            data[i, : a.size] = a
        max_syms = int(n_symbols.max(initial=0))
        # one scratch slot at the end absorbs post-quota emissions (zero
        # padding of short streams keeps the FSM running; writes past a
        # stream's quota are clamped there and never read back)
        cap = max_syms + 1
        out = np.zeros((n_streams, cap + 1), np.int64)

        state = np.zeros(n_streams, dtype=np.int32)
        pos = np.zeros(n_streams, dtype=np.int64)
        rows = np.arange(n_streams)
        for j in range(max_bytes):
            byte = data[:, j]
            cnt = emit_count[state, byte].astype(np.int64)
            syms = emit_syms[state, byte]  # (n_streams, max_emit)
            for e in range(max_emit):
                w = e < cnt
                idx = np.minimum(pos[w] + e, cap)
                out[rows[w], idx] = syms[w, e]
            pos = np.minimum(pos + cnt, cap)
            state = trans[state, byte]
        if (pos < n_symbols).any():
            raise ValueError("truncated Huffman stream")
        return [out[i, : n_symbols[i]] for i in range(n_streams)]

    def decode(self, blob: bytes, n: int) -> np.ndarray:
        return self.decode_streams([blob], np.array([n]))[0]

"""Canonical Huffman coding (paper §2.2, §3.2).

The codec operates on integer symbol ids ``0..B-1``.  Codes are *canonical*:
the dictionary only needs the code length of each symbol, which is what we
charge as overhead (the paper's ``alpha`` per dictionary line).

Guarantees (tested):
  * prefix-free, uniquely decodable,
  * average length within [H, H+1) of the empirical entropy,
  * lossless even when coding with a mismatched (cluster) distribution Q,
    provided Q gives every coded symbol nonzero mass (paper §5).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from .bitio import BitReader, BitWriter

_MAX_CODE_LEN = 58  # fits comfortably in python ints; depth bound for sanity


def code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol. Zero-frequency symbols get length 0
    (they are not in the codebook and must never be coded)."""
    freqs = np.asarray(freqs, dtype=np.float64)
    alive = np.flatnonzero(freqs > 0)
    lengths = np.zeros(len(freqs), dtype=np.int32)
    if len(alive) == 0:
        return lengths
    if len(alive) == 1:
        lengths[alive[0]] = 1  # degenerate alphabet still needs 1 bit/symbol
        return lengths
    # classic heap construction over (freq, tiebreak, payload-of-symbols)
    heap = [(float(freqs[s]), int(s), [int(s)]) for s in alive]
    heapq.heapify(heap)
    tie = len(freqs)
    while len(heap) > 1:
        fa, _, syms_a = heapq.heappop(heap)
        fb, _, syms_b = heapq.heappop(heap)
        for s in syms_a:
            lengths[s] += 1
        for s in syms_b:
            lengths[s] += 1
        heapq.heappush(heap, (fa + fb, tie, syms_a + syms_b))
        tie += 1
    return lengths


def canonical_codes(lengths: np.ndarray) -> dict[int, tuple[int, int]]:
    """symbol -> (code, length), canonical ordering (length, then symbol id)."""
    order = sorted((int(l), int(s)) for s, l in enumerate(lengths) if l > 0)
    codes: dict[int, tuple[int, int]] = {}
    code = 0
    prev_len = 0
    for length, sym in order:
        code <<= length - prev_len
        codes[sym] = (code, length)
        code += 1
        prev_len = length
    return codes


_LUT_BITS_CAP = 12  # LUT width: min(max_len, 12) — table is <= 4096 entries


@dataclass
class DecodeTables:
    """Table-driven canonical decoding state.

    ``lut_sym``/``lut_len`` resolve every code of length <= ``lut_bits`` with a
    single ``lut_bits``-wide window lookup; longer codes fall through to the
    per-length ``first_code``/``rank_base`` comparisons (the classic canonical
    decoder: a length-``l`` window ``c`` is a valid code iff
    ``0 <= c - first_code[l] < count_at[l]``, and its symbol is
    ``sym_by_rank[rank_base[l] + c - first_code[l]]``).
    """

    max_len: int
    lut_bits: int
    lut_sym: np.ndarray  # (1 << lut_bits,) int64; -1 => code longer than LUT
    lut_len: np.ndarray  # (1 << lut_bits,) int64; 0 where lut_sym == -1
    first_code: np.ndarray  # (max_len + 2,) int64
    count_at: np.ndarray  # (max_len + 2,) int64
    rank_base: np.ndarray  # (max_len + 2,) int64; #codes shorter than l
    sym_by_rank: np.ndarray  # (n_codes,) int64, sorted by (length, symbol)
    ends: np.ndarray  # (max_len,) left-aligned exclusive end of length-l codes


def build_decode_tables(
    lengths: np.ndarray, lut_bits_cap: int | None = None
) -> DecodeTables:
    lengths = np.asarray(lengths, dtype=np.int64)
    nz = np.flatnonzero(lengths > 0)
    max_len = int(lengths[nz].max()) if nz.size else 0
    if lut_bits_cap is None:
        # large alphabets (regression fit dictionaries reach 1e4+ symbols)
        # get a wider LUT so typical codes still resolve in one probe
        lut_bits_cap = _LUT_BITS_CAP
        if nz.size > (1 << _LUT_BITS_CAP):
            lut_bits_cap = min(16, int(np.ceil(np.log2(nz.size))) + 1)
    lut_bits = max(1, min(max_len, lut_bits_cap))
    lut_sym = np.full(1 << lut_bits, -1, dtype=np.int64)
    lut_len = np.zeros(1 << lut_bits, dtype=np.int64)
    first_code = np.zeros(max_len + 2, dtype=np.int64)
    count_at = np.zeros(max_len + 2, dtype=np.int64)
    rank_base = np.zeros(max_len + 2, dtype=np.int64)
    ends = np.zeros(max(max_len, 1), dtype=np.int64)
    if nz.size == 0:
        return DecodeTables(
            max_len, lut_bits, lut_sym, lut_len,
            first_code, count_at, rank_base, nz.astype(np.int64), ends,
        )
    sym_by_rank = nz[np.lexsort((nz, lengths[nz]))]  # by (length, symbol)
    cnt = np.bincount(lengths[sym_by_rank], minlength=max_len + 2)
    count_at[: len(cnt)] = cnt[: max_len + 2]
    rank_base[1:] = np.cumsum(count_at)[:-1]  # rank_base[l] = #codes len < l
    # canonical code assignment: fc[l] = (fc[l-1] + count[l-1]) << 1, and the
    # left-aligned (max_len-bit) code ranges of successive lengths tile
    # [0, 2^max_len) in increasing order — that is what lets the decoder find
    # a window's code length with one searchsorted over ``ends``.
    fc = 0
    for length in range(1, max_len + 1):
        first_code[length] = fc
        ends[length - 1] = (fc + int(count_at[length])) << (max_len - length)
        fc = (fc + int(count_at[length])) << 1
    for length in range(1, lut_bits + 1):  # LUT: one segment per length
        c = int(count_at[length])
        if c == 0:
            continue
        span = 1 << (lut_bits - length)
        base = int(first_code[length]) << (lut_bits - length)
        seg = sym_by_rank[int(rank_base[length]) : int(rank_base[length]) + c]
        lut_sym[base : base + c * span] = np.repeat(seg, span)
        lut_len[base : base + c * span] = length
    return DecodeTables(
        max_len, lut_bits, lut_sym, lut_len,
        first_code, count_at, rank_base, sym_by_rank, ends,
    )


@dataclass
class HuffmanCode:
    """A canonical Huffman codebook over symbols 0..B-1."""

    lengths: np.ndarray  # (B,) int32; 0 => symbol absent from codebook

    def __post_init__(self) -> None:
        self.lengths = np.asarray(self.lengths, dtype=np.int32)
        nzl = self.lengths[self.lengths > 0]
        self._min_len = int(nzl.min()) if nzl.size else 0
        self._max_len = int(nzl.max()) if nzl.size else 0
        # (code, length) dicts and decode tables are built lazily: encoders
        # touch _codes, bitwise decoding touches _decode, the table-driven
        # serving path touches tables() — none should pay for the others
        # (fit alphabets reach 1e4+ symbols).
        self._codes_map: dict[int, tuple[int, int]] | None = None
        self._decode_map: dict[tuple[int, int], int] | None = None
        self._tables: DecodeTables | None = None

    @property
    def _codes(self) -> dict[int, tuple[int, int]]:
        if self._codes_map is None:
            self._codes_map = canonical_codes(self.lengths)
        return self._codes_map

    @property
    def _decode(self) -> dict[tuple[int, int], int]:
        if self._decode_map is None:
            self._decode_map = {
                (l, c): s for s, (c, l) in self._codes.items()
            }
        return self._decode_map

    def tables(self) -> DecodeTables:
        if self._tables is None:
            self._tables = build_decode_tables(self.lengths)
        return self._tables

    @classmethod
    def from_freqs(cls, freqs: np.ndarray) -> "HuffmanCode":
        return cls(code_lengths(freqs))

    @property
    def alphabet_size(self) -> int:
        return len(self.lengths)

    def encode_symbol(self, w: BitWriter, sym: int) -> None:
        code, length = self._codes[int(sym)]
        w.write_bits(code, length)

    def decode_symbol_bitwise(self, r: BitReader) -> int:
        """Reference bit-at-a-time decoder (kept as the differential oracle
        for the table-driven paths; see tests/test_serve_path.py)."""
        code = 0
        length = 0
        while True:
            code = (code << 1) | r.read_bit()
            length += 1
            sym = self._decode.get((length, code))
            if sym is not None:
                return sym
            if length > _MAX_CODE_LEN:
                raise ValueError("corrupt Huffman stream")

    def decode_symbol(self, r: BitReader) -> int:
        """Table-driven decode: one LUT probe resolves codes of length
        <= min(max_len, 12); longer codes use per-length canonical compares.
        peek_bits speculates with zero padding past the payload, but a code
        is only consumed if it fits inside the remaining real bits."""
        t = self.tables()
        if t.max_len == 0:
            raise ValueError("corrupt Huffman stream")
        win = r.peek_bits(t.lut_bits)
        sym = int(t.lut_sym[win])
        if sym >= 0:
            length = int(t.lut_len[win])
            if r.remaining() < length:
                raise ValueError("truncated Huffman stream")
            r.skip(length)
            return sym
        code = r.peek_bits(t.max_len)
        for length in range(t.lut_bits + 1, t.max_len + 1):
            c = code >> (t.max_len - length)
            off = c - int(t.first_code[length])
            if 0 <= off < int(t.count_at[length]):
                if r.remaining() < length:
                    raise ValueError("truncated Huffman stream")
                r.skip(length)
                return int(t.sym_by_rank[int(t.rank_base[length]) + off])
        raise ValueError("corrupt Huffman stream")

    def encode(self, symbols) -> bytes:
        w = BitWriter()
        n = 0
        for s in symbols:
            self.encode_symbol(w, s)
            n += 1
        return w.getvalue()

    def decode(self, data: bytes, n_symbols: int) -> np.ndarray:
        """Whole-stream decode via the vectorized table-driven path."""
        if n_symbols == 0:
            return np.zeros(0, dtype=np.int64)
        from .vechuff import decode_stream  # deferred: vechuff imports us

        return decode_stream(self.tables(), data, n_symbols)

    def decode_bitwise(self, data: bytes, n_symbols: int) -> np.ndarray:
        r = BitReader(data)
        return np.array(
            [self.decode_symbol_bitwise(r) for _ in range(n_symbols)],
            dtype=np.int64,
        )

    def encoded_bits(self, counts: np.ndarray) -> int:
        """Exact bit cost of coding ``counts[s]`` occurrences of each symbol."""
        counts = np.asarray(counts)
        return int((counts * self.lengths).sum())

    def dictionary_bits(self, alpha_bits: float) -> float:
        """Paper's dictionary overhead: alpha bits per dictionary line."""
        return float((self.lengths > 0).sum()) * alpha_bits


def entropy_bits(counts: np.ndarray) -> float:
    """n * empirical entropy, in bits."""
    counts = np.asarray(counts, dtype=np.float64)
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts[counts > 0] / n
    return float(-n * (p * np.log2(p)).sum())

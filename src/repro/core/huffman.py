"""Canonical Huffman coding (paper §2.2, §3.2).

The codec operates on integer symbol ids ``0..B-1``.  Codes are *canonical*:
the dictionary only needs the code length of each symbol, which is what we
charge as overhead (the paper's ``alpha`` per dictionary line).

Guarantees (tested):
  * prefix-free, uniquely decodable,
  * average length within [H, H+1) of the empirical entropy,
  * lossless even when coding with a mismatched (cluster) distribution Q,
    provided Q gives every coded symbol nonzero mass (paper §5).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from .bitio import BitReader, BitWriter

_MAX_CODE_LEN = 58  # fits comfortably in python ints; depth bound for sanity


def code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol. Zero-frequency symbols get length 0
    (they are not in the codebook and must never be coded)."""
    freqs = np.asarray(freqs, dtype=np.float64)
    alive = np.flatnonzero(freqs > 0)
    lengths = np.zeros(len(freqs), dtype=np.int32)
    if len(alive) == 0:
        return lengths
    if len(alive) == 1:
        lengths[alive[0]] = 1  # degenerate alphabet still needs 1 bit/symbol
        return lengths
    # classic heap construction over (freq, tiebreak, payload-of-symbols)
    heap = [(float(freqs[s]), int(s), [int(s)]) for s in alive]
    heapq.heapify(heap)
    tie = len(freqs)
    while len(heap) > 1:
        fa, _, syms_a = heapq.heappop(heap)
        fb, _, syms_b = heapq.heappop(heap)
        for s in syms_a:
            lengths[s] += 1
        for s in syms_b:
            lengths[s] += 1
        heapq.heappush(heap, (fa + fb, tie, syms_a + syms_b))
        tie += 1
    return lengths


def canonical_codes(lengths: np.ndarray) -> dict[int, tuple[int, int]]:
    """symbol -> (code, length), canonical ordering (length, then symbol id)."""
    order = sorted((int(l), int(s)) for s, l in enumerate(lengths) if l > 0)
    codes: dict[int, tuple[int, int]] = {}
    code = 0
    prev_len = 0
    for length, sym in order:
        code <<= length - prev_len
        codes[sym] = (code, length)
        code += 1
        prev_len = length
    return codes


@dataclass
class HuffmanCode:
    """A canonical Huffman codebook over symbols 0..B-1."""

    lengths: np.ndarray  # (B,) int32; 0 => symbol absent from codebook

    def __post_init__(self) -> None:
        self.lengths = np.asarray(self.lengths, dtype=np.int32)
        self._codes = canonical_codes(self.lengths)
        # decode table: (length, code) -> symbol
        self._decode = {(l, c): s for s, (c, l) in self._codes.items()}
        self._min_len = min((l for l in self.lengths if l > 0), default=0)
        self._max_len = int(self.lengths.max(initial=0))

    @classmethod
    def from_freqs(cls, freqs: np.ndarray) -> "HuffmanCode":
        return cls(code_lengths(freqs))

    @property
    def alphabet_size(self) -> int:
        return len(self.lengths)

    def encode_symbol(self, w: BitWriter, sym: int) -> None:
        code, length = self._codes[int(sym)]
        w.write_bits(code, length)

    def decode_symbol(self, r: BitReader) -> int:
        code = 0
        length = 0
        while True:
            code = (code << 1) | r.read_bit()
            length += 1
            sym = self._decode.get((length, code))
            if sym is not None:
                return sym
            if length > _MAX_CODE_LEN:
                raise ValueError("corrupt Huffman stream")

    def encode(self, symbols) -> bytes:
        w = BitWriter()
        n = 0
        for s in symbols:
            self.encode_symbol(w, s)
            n += 1
        return w.getvalue()

    def decode(self, data: bytes, n_symbols: int) -> np.ndarray:
        r = BitReader(data)
        return np.array(
            [self.decode_symbol(r) for _ in range(n_symbols)], dtype=np.int64
        )

    def encoded_bits(self, counts: np.ndarray) -> int:
        """Exact bit cost of coding ``counts[s]`` occurrences of each symbol."""
        counts = np.asarray(counts)
        return int((counts * self.lengths).sum())

    def dictionary_bits(self, alpha_bits: float) -> float:
        """Paper's dictionary overhead: alpha bits per dictionary line."""
        return float((self.lengths > 0).sum()) * alpha_bits


def entropy_bits(counts: np.ndarray) -> float:
    """n * empirical entropy, in bits."""
    counts = np.asarray(counts, dtype=np.float64)
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts[counts > 0] / n
    return float(-n * (p * np.log2(p)).sum())

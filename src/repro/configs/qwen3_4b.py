"""Config for --arch qwen3-4b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch qwen3-4b` resolves)."""
from .registry import get_config

CONFIG = get_config("qwen3-4b")
SMOKE = CONFIG.smoke()

"""Config for --arch starcoder2-3b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch starcoder2-3b` resolves)."""
from .registry import get_config

CONFIG = get_config("starcoder2-3b")
SMOKE = CONFIG.smoke()

"""Config for --arch internvl2-76b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch internvl2-76b` resolves)."""
from .registry import get_config

CONFIG = get_config("internvl2-76b")
SMOKE = CONFIG.smoke()

"""Config for --arch qwen2.5-3b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch qwen2.5-3b` resolves)."""
from .registry import get_config

CONFIG = get_config("qwen2.5-3b")
SMOKE = CONFIG.smoke()

"""Architecture registry — the 10 assigned architectures, exact published
configs (sources in brackets; see DESIGN.md for modality-stub notes)."""
from __future__ import annotations

from .base import ModelConfig

# — LM-family transformers —————————————————————————————————————————————

INTERNVL2_76B = ModelConfig(
    # InternViT frontend is a stub; this is the InternLM2-76B backbone
    # [arXiv:2404.16821]
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, attn_type="gqa", rope_theta=1e6,
    frontend="patch", n_frontend_tokens=256,
)

DEEPSEEK_7B = ModelConfig(
    # llama-arch dense [arXiv:2401.02954]
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=102400, attn_type="gqa", rope_theta=10000.0,
)

QWEN3_4B = ModelConfig(
    # qk_norm, GQA [hf:Qwen/Qwen3-8B family]
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab_size=151936, head_dim=128, attn_type="gqa", qk_norm=True,
    rope_theta=1e6,
)

STARCODER2_3B = ModelConfig(
    # GQA, RoPE [arXiv:2402.19173]
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, attn_type="gqa", qkv_bias=True, mlp_bias=True,
    rope_theta=1e5,
)

QWEN2_5_3B = ModelConfig(
    # GQA, QKV bias [hf:Qwen/Qwen2.5 family]
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab_size=151936, attn_type="gqa", qkv_bias=True, rope_theta=1e6,
)

DEEPSEEK_V3_671B = ModelConfig(
    # MLA, 1 shared + 256 routed top-8, 3 leading dense layers, MTP
    # [arXiv:2412.19437]
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280, attn_type="mla", mlp_type="moe",
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    n_dense_layers=3,
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, mtp_depth=1, rope_theta=10000.0,
)

GRANITE_MOE_3B = ModelConfig(
    # 40 experts top-8 [hf:ibm-granite/granite-3.0 family]
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, attn_type="gqa", mlp_type="moe",
    n_experts=40, top_k=8, moe_d_ff=512, rope_theta=10000.0,
)

RWKV6_1_6B = ModelConfig(
    # Finch: data-dependent decay, attention-free [arXiv:2404.05892]
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
    vocab_size=65536, head_dim=64, attn_type="rwkv6",
)

HYMBA_1_5B = ModelConfig(
    # parallel attn+mamba heads, ssm_state=16 [arXiv:2411.13676]
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64, attn_type="hymba", ssm_state=16,
    d_inner=3200, sliding_window=2048, rope_theta=10000.0,
)

MUSICGEN_LARGE = ModelConfig(
    # decoder-only over EnCodec tokens; frame frontend stubbed
    # [arXiv:2306.05284]
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, attn_type="gqa", frontend="frame",
    n_frontend_tokens=0, rope_theta=10000.0,
)

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        INTERNVL2_76B, DEEPSEEK_7B, QWEN3_4B, STARCODER2_3B, QWEN2_5_3B,
        DEEPSEEK_V3_671B, GRANITE_MOE_3B, RWKV6_1_6B, HYMBA_1_5B,
        MUSICGEN_LARGE,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        )
    return ARCHITECTURES[name]

"""Model / run configuration system.

One frozen dataclass describes every assigned architecture; configs/<id>.py
instantiates it with the published numbers.  ``smoke()`` derives the reduced
same-family config used by CPU smoke tests (small widths, few layers/experts,
tiny vocab) — the full config is exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads

    # block wiring
    attn_type: str = "gqa"  # gqa | mla | rwkv6 | hymba
    mlp_type: str = "dense"  # dense | moe
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    # MoE (deepseek-v3 / granite)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden size
    n_dense_layers: int = 0  # leading dense layers (deepseek-v3: 3)
    capacity_factor: float = 1.25

    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid (rwkv6, hymba)
    ssm_state: int = 0
    d_inner: int = 0  # mamba inner width (hymba)
    sliding_window: int = 0  # hymba attention window (0 => full causal)

    # modality frontend stub (vlm / audio): embeddings for the first
    # n_frontend_tokens positions arrive precomputed from input_specs()
    frontend: str | None = None  # None | "patch" | "frame"
    n_frontend_tokens: int = 0

    # multi-token prediction (deepseek-v3 optional head)
    mtp_depth: int = 0

    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with O(1)/O(window) state?"""
        return self.attn_type in ("rwkv6", "hymba")

    def n_params(self) -> int:
        """Total parameter count (embeddings included, analytic)."""
        d, h = self.d_model, self.head_dim_
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.attn_type == "gqa":
            attn = d * h * self.n_heads + 2 * d * h * self.n_kv_heads + self.n_heads * h * d
        elif self.attn_type == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        elif self.attn_type == "rwkv6":
            attn = 4 * d * d + 2 * d * 64  # r,k,v,g,o + decay lora
        else:  # hymba: attention + mamba branches
            attn = (
                d * h * self.n_heads
                + 2 * d * h * self.n_kv_heads
                + self.n_heads * h * d
                + 2 * d * self.d_inner_  # in/ gate proj
                + self.d_inner_ * d  # out proj
                + self.d_inner_ * 3 * self.ssm_state  # B, C, dt
            )
        if self.mlp_type == "dense":
            mlp = 3 * d * self.d_ff
        else:
            mlp = (
                self.n_experts * 3 * d * self.moe_d_ff
                + self.n_shared_experts * 3 * d * self.moe_d_ff
                + d * self.n_experts  # router
            )
            mlp_dense = 3 * d * self.d_ff
            return (
                emb
                + self.n_dense_layers * (attn + mlp_dense)
                + (self.n_layers - self.n_dense_layers) * (attn + mlp)
            )
        return emb + self.n_layers * (attn + mlp)

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: only routed top-k + shared)."""
        if self.mlp_type != "moe":
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        inactive = (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff * (
            self.n_layers - self.n_dense_layers
        )
        return full - inactive

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    # ---- reduced smoke config ------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Same-family reduced config for CPU smoke tests."""
        heads = min(self.n_heads, 4)
        kv = max(1, min(self.n_kv_heads, heads))
        # preserve the GQA group structure when possible
        if self.n_kv_heads < self.n_heads:
            kv = max(1, heads // max(1, self.n_heads // self.n_kv_heads))
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 + self.n_dense_layers),
            d_model=128,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.mlp_type == "moe" else 0,
            n_dense_layers=min(self.n_dense_layers, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            d_inner=128 if self.attn_type == "hymba" else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

"""Config for --arch deepseek-7b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch deepseek-7b` resolves)."""
from .registry import get_config

CONFIG = get_config("deepseek-7b")
SMOKE = CONFIG.smoke()

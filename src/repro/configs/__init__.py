"""repro.configs — model + shape registry."""

from .base import SHAPES, ModelConfig, ShapeConfig
from .registry import ARCHITECTURES, get_config

__all__ = ["ARCHITECTURES", "SHAPES", "ModelConfig", "ShapeConfig", "get_config"]

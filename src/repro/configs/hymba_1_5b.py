"""Config for --arch hymba-1.5b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch hymba-1.5b` resolves)."""
from .registry import get_config

CONFIG = get_config("hymba-1.5b")
SMOKE = CONFIG.smoke()

"""Config for --arch musicgen-large (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch musicgen-large` resolves)."""
from .registry import get_config

CONFIG = get_config("musicgen-large")
SMOKE = CONFIG.smoke()

"""Config for --arch granite-moe-3b-a800m (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch granite-moe-3b-a800m` resolves)."""
from .registry import get_config

CONFIG = get_config("granite-moe-3b-a800m")
SMOKE = CONFIG.smoke()

"""Config for --arch rwkv6-1.6b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch rwkv6-1.6b` resolves)."""
from .registry import get_config

CONFIG = get_config("rwkv6-1.6b")
SMOKE = CONFIG.smoke()

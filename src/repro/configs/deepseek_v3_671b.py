"""Config for --arch deepseek-v3-671b (exact published numbers live in
configs/registry.py; this module is the per-arch entry point the spec
asks for and is what `--arch deepseek-v3-671b` resolves)."""
from .registry import get_config

CONFIG = get_config("deepseek-v3-671b")
SMOKE = CONFIG.smoke()

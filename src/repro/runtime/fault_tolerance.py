"""Fault-tolerant training runtime.

Production story (and what the CI-scale tests exercise on CPU):
  * CHECKPOINT/RESTART — TrainLoop periodically saves through
    CheckpointManager; on (simulated or real) preemption the loop restarts
    from the latest committed step.  The data pipeline is stateless in
    (seed, step) (data.tokens), so a restart reproduces the exact same
    batch stream: training is bit-deterministic across failures.
  * STRAGGLER MITIGATION — per-step deadline derived from a running
    latency percentile; hosts whose data fetch misses the deadline are
    skipped for that step and the batch is re-scaled (gradient math uses
    whatever microbatches arrived; deterministic replay still holds
    because skips are logged in the step record).  At dry-run scale this
    manifests as the deadline/skip bookkeeping tested in
    tests/test_runtime.py.
  * ELASTIC SCALING — checkpoints carry logical (unsharded) arrays;
    loading onto a different mesh re-shards via device_put (see
    checkpoint.manager.load_checkpoint(shardings=...)).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from ..checkpoint.manager import CheckpointManager


class Preemption(Exception):
    """Raised mid-training to simulate a node loss / maintenance event."""


@dataclass
class PreemptionSchedule:
    """Deterministic preemption injector for tests: fail at given steps."""

    fail_at: tuple[int, ...] = ()
    _seen: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self._seen:
            self._seen.add(step)
            raise Preemption(f"simulated preemption at step {step}")


class StragglerMonitor:
    """Tracks per-host step latencies; flags hosts exceeding a multiple of
    the running median as stragglers to skip."""

    def __init__(self, window: int = 32, threshold: float = 3.0):
        self.window = window
        self.threshold = threshold
        self._lat: dict[int, deque] = {}
        self.skipped: list[tuple[int, int]] = []  # (step, host)

    def observe(self, host: int, seconds: float):
        self._lat.setdefault(host, deque(maxlen=self.window)).append(seconds)

    def deadline(self) -> float:
        all_lat = sorted(
            x for dq in self._lat.values() for x in dq
        )
        if not all_lat:
            return float("inf")
        median = all_lat[len(all_lat) // 2]
        return self.threshold * median

    def should_skip(self, step: int, host: int, seconds: float) -> bool:
        dl = self.deadline()
        self.observe(host, min(seconds, dl))  # clamp so one spike
        # doesn't poison the window
        if seconds > dl:
            self.skipped.append((step, host))
            return True
        return False


class TrainLoop:
    """Restartable training loop.

    step_fn(state, step) -> (state, metrics); state is any pytree.
    run() survives Preemption by restoring the latest checkpoint and
    continuing — the test asserts the final state matches an uninterrupted
    run bit-for-bit.
    """

    def __init__(
        self,
        step_fn: Callable[[Any, int], tuple[Any, dict]],
        manager: CheckpointManager,
        *,
        save_every: int = 10,
        preemption: PreemptionSchedule | None = None,
        max_restarts: int = 16,
    ):
        self.step_fn = step_fn
        self.manager = manager
        self.save_every = save_every
        self.preemption = preemption
        self.max_restarts = max_restarts
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def run(self, init_state, n_steps: int, shardings=None):
        state, start = self.manager.restore_or_none(shardings)
        if state is None:
            state, start = init_state, 0
            self.manager.save(0, state)
        while True:
            try:
                return self._run_from(state, start, n_steps)
            except Preemption:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, start = self.manager.restore_or_none(shardings)
                assert state is not None, "preempted before first commit"

    def _run_from(self, state, start: int, n_steps: int):
        for step in range(start, n_steps):
            if self.preemption is not None:
                self.preemption.check(step)
            t0 = time.time()
            state, metrics = self.step_fn(state, step)
            metrics = dict(metrics, step=step, wall_s=time.time() - t0)
            self.metrics_log.append(metrics)
            next_step = step + 1
            if next_step % self.save_every == 0 or next_step == n_steps:
                self.manager.save(next_step, state)
        return state

"""Deterministic fault-injection harness (ISSUE 6 tentpole piece 4).

Everything the fault-tolerance tests and ``benchmarks/chaos_bench.py``
need to break the system ON PURPOSE, reproducibly:

* **Corruption** — seeded bit flips (``flip_bit`` / ``flip_bits``) and
  truncations (``truncate``) of serialized RFS1/RFD1/RFT1/RFM1 frames,
  for exercising the integrity-checked framing (``core.framing``);
* **Crashes** — ``CrashSchedule``, modeled on the training runtime's
  ``PreemptionSchedule``: a step hook the recluster journal calls at
  every named step, raising ``InjectedCrash`` at a chosen step name or
  index.  Run once with an empty schedule to RECORD the step list, then
  replay crashing at each recorded step in turn ("crash at every journal
  step");
* **Transient faults** — ``TransientFaults``, a callable that fails its
  first N invocations with ``TransientError`` (what the serving session's
  bounded retry-with-backoff is tested against, standing in for arena
  admission failures under memory pressure).

Everything here is seed-deterministic: the same seed produces the same
flipped bits, the same truncation points, the same crash steps — so every
chaos test is replayable bit-for-bit.
"""
from __future__ import annotations

import errno
import os
from dataclasses import dataclass, field

import numpy as np


class InjectedCrash(Exception):
    """Raised by ``CrashSchedule`` to simulate a process crash mid-step."""


class TransientError(Exception):
    """A retryable fault (simulated memory pressure, a busy device): the
    serving session's bounded retry-with-backoff handles these; anything
    else propagates."""


# ---------------------------------------------------------------------------
# corruption
# ---------------------------------------------------------------------------

def flip_bit(data: bytes, bit: int) -> bytes:
    """Return a copy of ``data`` with one bit flipped (``bit`` indexes the
    whole payload, LSB-first within each byte)."""
    if not 0 <= bit < 8 * len(data):
        raise ValueError(f"bit {bit} out of range for {len(data)} bytes")
    buf = bytearray(data)
    buf[bit // 8] ^= 1 << (bit % 8)
    return bytes(buf)


def flip_bits(data: bytes, seed: int, n: int = 1) -> tuple[bytes, list[int]]:
    """Flip ``n`` distinct seeded-random bits; returns the corrupted copy
    and the flipped bit positions (for reproduction in failure reports)."""
    rng = np.random.default_rng(seed)
    total = 8 * len(data)
    if total == 0:
        return data, []
    n = min(n, total)
    positions = sorted(
        int(p) for p in rng.choice(total, size=n, replace=False)
    )
    out = data
    for p in positions:
        out = flip_bit(out, p)
    return out, positions


def truncate(data: bytes, keep: int) -> bytes:
    """Return the first ``keep`` bytes of ``data`` (a torn write / partial
    download)."""
    if not 0 <= keep <= len(data):
        raise ValueError(f"keep={keep} out of range for {len(data)} bytes")
    return data[:keep]


def seeded_truncation(data: bytes, seed: int) -> tuple[bytes, int]:
    """Truncate at a seeded-random point strictly inside the payload."""
    rng = np.random.default_rng(seed)
    keep = int(rng.integers(0, max(len(data), 1)))
    return truncate(data, keep), keep


class PoisonedDelta:
    """Stand-in for a user delta whose bytes fail integrity checks at
    decode time: every attribute access beyond the generation stamp
    raises ``core.framing.IntegrityError``, so any decode path
    (``hydrate`` / ``tiles`` / ``reconstruct``) faults exactly where a
    CRC-failing delta loaded lazily from storage would."""

    def __init__(self, generation: int, reason: str) -> None:
        self.codebook_generation = generation
        self._reason = reason

    def __getattr__(self, name: str):
        from ..core.framing import IntegrityError

        raise IntegrityError(self._reason)


def poison_user(
    store, user_id: str, reason: str = "injected delta corruption"
) -> None:
    """Deterministically corrupt one user in a ``ForestStore``: their
    delta is replaced with a ``PoisonedDelta`` and every cached decode
    artifact for them is dropped, so the next decode attempt raises a
    typed ``IntegrityError`` — the fault ``ForestServer.serve_safe``
    quarantines."""
    if user_id not in store._deltas:
        raise KeyError(f"unknown user {user_id!r}")
    gen = store._deltas[user_id].codebook_generation
    store._deltas[user_id] = PoisonedDelta(gen, reason)
    store._hydrated.pop(user_id, None)
    store._tile_counts = {
        k: v for k, v in store._tile_counts.items() if k[0] != user_id
    }
    store.cache.invalidate_user(user_id)
    if store.arena is not None:
        store.arena.invalidate(user_id)
    store.version += 1
    store._user_versions[user_id] = store.version


# ---------------------------------------------------------------------------
# disk faults (ISSUE 8): what the durable shard store is tested against
# ---------------------------------------------------------------------------

@dataclass
class DiskFaults:
    """Seeded disk-fault injector for the durable shard store
    (``store.durable``).

    Two kinds of surface, both deterministic under ``seed``:

    * **File mutators** — corrupt on-disk state directly, the way a dying
      disk or torn write would: ``torn_write`` truncates a file at a byte
      offset, ``bit_rot_file`` flips seeded bits in place, ``missing``
      deletes a file, and ``corrupt_region`` zeroes a byte range (a
      trashed sector inside a slab).  Each returns/records where it
      struck so failures replay bit-for-bit.
    * **I/O hooks** — install ``on_read`` as ``DurableStore.read_fault``
      to bit-rot the shards named in ``rot_shards`` as they are read
      (latent corruption surfacing at access time), and ``on_write`` as
      ``DurableStore.write_fault`` to raise ``OSError(ENOSPC)`` once
      ``enospc_after`` writes have succeeded (a full disk mid-commit).
    """

    seed: int = 0
    rot_shards: tuple = ()
    enospc_after: int | None = None
    reads: int = 0
    writes: int = 0
    rotted: list = field(default_factory=list)

    # -- I/O hooks ----------------------------------------------------------

    def on_read(self, shard_id: int, data: bytes) -> bytes:
        """``DurableStore.read_fault`` hook: flip one seeded bit in the
        shards listed in ``rot_shards`` (every read, so repair-then-reread
        still sees clean bytes only from the healed file, not this hook —
        remove the shard from ``rot_shards`` to model a one-shot rot)."""
        self.reads += 1
        if shard_id in self.rot_shards and data:
            rng = np.random.default_rng(self.seed + shard_id)
            bit = int(rng.integers(0, 8 * len(data)))
            self.rotted.append((shard_id, bit))
            return flip_bit(data, bit)
        return data

    def on_write(self, path: str, nbytes: int) -> None:
        """``DurableStore.write_fault`` hook: allow ``enospc_after``
        writes, then fail every subsequent one with ``ENOSPC``."""
        self.writes += 1
        if self.enospc_after is not None and self.writes > self.enospc_after:
            raise OSError(
                errno.ENOSPC,
                f"injected ENOSPC on write {self.writes} ({nbytes} bytes)",
                path,
            )

    # -- file mutators ------------------------------------------------------

    def torn_write(self, path: str, offset: int | None = None) -> int:
        """Truncate ``path`` at ``offset`` (seeded-random strictly inside
        the file when omitted) — the on-disk shape of a write that died
        partway.  Returns the offset used."""
        size = os.path.getsize(path)
        if offset is None:
            rng = np.random.default_rng(self.seed ^ len(path))
            offset = int(rng.integers(0, size)) if size else 0
        os.truncate(path, offset)
        return offset

    def bit_rot_file(self, path: str, n: int = 1) -> list[int]:
        """Flip ``n`` seeded bits of ``path`` in place (deliberately NOT
        an atomic write — this IS the corruption).  Returns bit positions."""
        with open(path, "rb") as f:
            data = f.read()
        out, positions = flip_bits(data, self.seed ^ (len(path) << 8), n)
        with open(path, "wb") as f:
            f.write(out)
        return positions

    def corrupt_region(self, path: str, offset: int, length: int) -> None:
        """Zero ``length`` bytes of ``path`` at ``offset`` — a trashed
        sector; how the tests corrupt ONE shard inside a multi-shard slab
        without touching its siblings."""
        with open(path, "r+b") as f:
            f.seek(offset)
            f.write(b"\x00" * length)

    def missing(self, path: str) -> None:
        """Delete a file (a lost shard / parity file)."""
        os.remove(path)


# ---------------------------------------------------------------------------
# crash injection
# ---------------------------------------------------------------------------

@dataclass
class CrashSchedule:
    """Deterministic crash injector for journaled operations.

    Pass an instance as the ``on_step`` hook of ``lifecycle.recluster`` /
    ``resume_recluster``; each call records the step name in ``steps`` and
    raises ``InjectedCrash`` when the step's NAME or 0-based INDEX appears
    in ``fail_at`` (each trigger fires once, so a resumed run sails past
    the crash point it already took)."""

    fail_at: tuple = ()
    steps: list = field(default_factory=list)
    _fired: set = field(default_factory=set)

    def __call__(self, name: str) -> None:
        idx = len(self.steps)
        self.steps.append(name)
        for key in (name, idx):
            if key in self.fail_at and key not in self._fired:
                self._fired.add(key)
                raise InjectedCrash(
                    f"injected crash at step {idx} ({name})"
                )


def record_steps(run) -> list[str]:
    """Run ``run(on_step)`` with a no-crash schedule and return the step
    names it took — the crash points a crash-at-every-step sweep replays."""
    sched = CrashSchedule()
    run(sched)
    return list(sched.steps)


# ---------------------------------------------------------------------------
# transient faults
# ---------------------------------------------------------------------------

@dataclass
class TransientFaults:
    """Callable that raises ``TransientError`` on its first ``fail_first``
    invocations, then succeeds forever — install as
    ``TileArena.admission_fault`` to simulate admission failures under
    memory pressure and exercise the serving session's bounded
    retry-with-backoff."""

    fail_first: int = 1
    calls: int = 0

    def __call__(self, *_args) -> None:
        self.calls += 1
        if self.calls <= self.fail_first:
            raise TransientError(
                f"injected transient fault ({self.calls}/{self.fail_first})"
            )


@dataclass
class BatchFaults:
    """Scheduler-level fault injector (ISSUE 7): install as the
    ``fault_hook`` of ``sched.PipelinedExecutor`` / ``sched.Scheduler``
    and it raises on the micro-batches whose ``seq`` appears in
    ``fail_batches`` — exercising the executor's batch-level isolation
    (the poisoned batch resolves ``status="failed"``, the scheduler loop
    keeps serving).  ``transient=True`` raises ``TransientError`` (a
    retryable device fault) instead of ``InjectedCrash``."""

    fail_batches: tuple = ()
    transient: bool = False
    calls: int = 0
    seen: list = field(default_factory=list)

    def __call__(self, batch) -> None:
        self.calls += 1
        self.seen.append(batch.seq)
        if batch.seq in self.fail_batches:
            exc = TransientError if self.transient else InjectedCrash
            raise exc(f"injected batch fault at micro-batch {batch.seq}")

"""Lock-discipline annotations (ISSUE 9).

``guarded_by`` is a zero-cost class decorator that DECLARES which lock
protects which attributes of a concurrent class.  It does nothing at
runtime beyond recording the mapping on the class — the enforcement is
static: the ``lock-discipline`` pass of ``tools/analysis/repro_lint.py``
reads the decorator from the AST and verifies that every access to a
guarded attribute (outside ``__init__``) is lexically inside a
``with self.<lock>:`` block of the matching lock.

Usage::

    @guarded_by("_lock", "_plans", "_packs", "hits")
    class PlanCache:
        def __init__(self):
            self._lock = threading.Lock()
            ...

``holds`` names methods that REQUIRE the lock to already be held by
their caller (private helpers called from inside a locked region).  The
pass skips enforcement inside those methods but instead verifies that
every call site of such a method within the class is itself under the
lock::

    @guarded_by("_lock", "_items", "_cursor", holds=("_scan",))
    class Scrubber: ...

A ``threading.Condition`` counts as a lock (``with self._cond:``
acquires its underlying lock), so executor-style classes annotate their
condition variable as the guard.

The mapping is also available at runtime as ``cls.__guarded_by__``
(attr -> lock name) and ``cls.__guard_holds__`` (lock name -> methods
that assume it held) for introspection and tests.
"""
from __future__ import annotations

from typing import Callable, Iterable, TypeVar

T = TypeVar("T", bound=type)


def guarded_by(
    lock_attr: str, *attrs: str, holds: Iterable[str] = ()
) -> Callable[[T], T]:
    """Declare that ``attrs`` of the decorated class are protected by
    ``self.<lock_attr>``.  Stack multiple decorators to declare several
    locks on one class.  Purely declarative — see module docstring."""
    if not attrs:
        raise ValueError("guarded_by needs at least one guarded attribute")

    def deco(cls: T) -> T:
        mapping = dict(getattr(cls, "__guarded_by__", {}))
        for a in attrs:
            mapping[a] = lock_attr
        cls.__guarded_by__ = mapping
        hold_map = dict(getattr(cls, "__guard_holds__", {}))
        hold_map[lock_attr] = tuple(
            sorted(set(hold_map.get(lock_attr, ())) | set(holds))
        )
        cls.__guard_holds__ = hold_map
        return cls

    return deco

"""repro.runtime — fault tolerance: restart, preemption, stragglers."""

from .fault_tolerance import (
    Preemption,
    PreemptionSchedule,
    StragglerMonitor,
    TrainLoop,
)

__all__ = [
    "Preemption",
    "PreemptionSchedule",
    "StragglerMonitor",
    "TrainLoop",
]

"""repro.runtime — fault tolerance: restart, preemption, stragglers,
plus the deterministic fault-injection harness (``runtime.chaos``)."""

from .chaos import (
    BatchFaults,
    CrashSchedule,
    InjectedCrash,
    TransientError,
    TransientFaults,
    flip_bit,
    flip_bits,
    truncate,
)
from .fault_tolerance import (
    Preemption,
    PreemptionSchedule,
    StragglerMonitor,
    TrainLoop,
)

__all__ = [
    "BatchFaults",
    "CrashSchedule",
    "InjectedCrash",
    "Preemption",
    "PreemptionSchedule",
    "StragglerMonitor",
    "TrainLoop",
    "TransientError",
    "TransientFaults",
    "flip_bit",
    "flip_bits",
    "truncate",
]

"""repro.runtime — fault tolerance: restart, preemption, stragglers,
plus the deterministic fault-injection harness (``runtime.chaos``) and
the lock-discipline annotation (``runtime.guards``)."""

from .chaos import (
    BatchFaults,
    CrashSchedule,
    InjectedCrash,
    TransientError,
    TransientFaults,
    flip_bit,
    flip_bits,
    truncate,
)
from .fault_tolerance import (
    Preemption,
    PreemptionSchedule,
    StragglerMonitor,
    TrainLoop,
)
from .guards import guarded_by

__all__ = [
    "BatchFaults",
    "CrashSchedule",
    "InjectedCrash",
    "Preemption",
    "PreemptionSchedule",
    "StragglerMonitor",
    "TrainLoop",
    "TransientError",
    "TransientFaults",
    "flip_bit",
    "flip_bits",
    "guarded_by",
    "truncate",
]

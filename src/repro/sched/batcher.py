"""Micro-batch formation under a dual trigger (ISSUE 7 tentpole piece 2).

``MicroBatcher`` turns the per-tenant queue into a stream of
micro-batches for the executor.  A batch forms when EITHER trigger
fires, whichever comes first:

* **rows** — the queue holds at least ``max_rows`` pending rows: enough
  work to fill the kernel, no reason to wait;
* **deadline** — the earliest servable deadline is within
  ``plan_headroom_s`` of now: waiting any longer would blow the SLO
  (the headroom covers plan + execute for one batch).

Selection is tenant-coherent and deterministic: tenants are visited in
urgency order (earliest head deadline first) and each selected tenant
contributes its WHOLE FIFO run while the row budget lasts — grouping a
user's requests into one batch means the plan folds them into one
segment and the arena pack is gathered once.  The chosen requests are
then ordered canonically by ``(user_id, seq)``, so recurring workloads
produce recurring plan signatures and hit the serving session's
cross-batch ``PlanCache``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .queue import RequestQueue, SchedRequest


@dataclass
class MicroBatch:
    """One formed micro-batch: the requests it serves, which trigger
    fired (``"rows"`` | ``"deadline"`` | ``"flush"``), and when."""

    seq: int
    requests: list[SchedRequest] = field(default_factory=list)
    trigger: str = ""
    formed_t: float = 0.0

    @property
    def n_rows(self) -> int:
        return sum(r.n_rows for r in self.requests)

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def users(self) -> list[str]:
        """Distinct users, in batch order."""
        return list(dict.fromkeys(r.user_id for r in self.requests))


class MicroBatcher:
    """Coalesces queued requests into micro-batches under the dual
    trigger (max-rows budget / SLO deadline headroom)."""

    def __init__(
        self, max_rows: int = 1024, plan_headroom_s: float = 0.05
    ) -> None:
        if max_rows < 1:
            raise ValueError(f"max_rows must be positive, got {max_rows}")
        self.max_rows = int(max_rows)
        self.plan_headroom_s = float(plan_headroom_s)
        self._next_seq = 0
        self.n_batches = 0
        self.trigger_counts: dict[str, int] = {}

    def due(self, queue: RequestQueue, now: float) -> str | None:
        """Which trigger (if any) fires at ``now``: ``"rows"`` when the
        pending-row budget is met, else ``"deadline"`` when the earliest
        servable deadline is within the plan headroom."""
        if queue.n_pending == 0:
            return None
        if queue.pending_rows >= self.max_rows:
            return "rows"
        oldest = queue.oldest_head_deadline()
        if oldest is not None and now >= oldest - self.plan_headroom_s:
            return "deadline"
        return None

    def form(
        self, queue: RequestQueue, now: float, flush: bool = False
    ) -> MicroBatch | None:
        """Form one micro-batch if a trigger is due (or unconditionally
        with ``flush=True``, for drains); ``None`` otherwise.

        The first (most urgent) request is always taken even when it
        alone exceeds the row budget — an oversized request must not
        starve behind the budget it can never fit."""
        trigger = self.due(queue, now)
        if trigger is None:
            if not flush or queue.n_pending == 0:
                return None
            trigger = "flush"
        heads = queue.head_deadlines()
        order = sorted(heads, key=lambda u: (heads[u], u))
        taken: list[SchedRequest] = []
        rows = 0
        for user in order:
            while True:
                req = queue.peek(user)
                if req is None:
                    break
                if taken and rows + req.n_rows > self.max_rows:
                    break  # tenant's tail stays queued; try next tenant
                taken.append(queue.pop(user))
                rows += taken[-1].n_rows
            if rows >= self.max_rows:
                break
        # canonical order: same-user requests adjacent, recurring
        # workloads -> recurring plan signatures (PlanCache hits)
        taken.sort(key=lambda r: (r.user_id, r.seq))
        batch = MicroBatch(
            seq=self._next_seq, requests=taken, trigger=trigger,
            formed_t=now,
        )
        self._next_seq += 1
        self.n_batches += 1
        self.trigger_counts[trigger] = self.trigger_counts.get(trigger, 0) + 1
        for r in taken:
            r.batch_seq = batch.seq
        return batch

    def stats(self) -> dict:
        """Batch-formation counters (dual-trigger mix)."""
        return {
            "n_batches": self.n_batches,
            "trigger_counts": dict(self.trigger_counts),
            "max_rows": self.max_rows,
            "plan_headroom_s": self.plan_headroom_s,
        }

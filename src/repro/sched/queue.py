"""Per-tenant request queue with admission bounds (ISSUE 7 tentpole
piece 1).

``RequestQueue`` holds one FIFO per tenant of ``SchedRequest`` tickets —
``(user_id, rows)`` plus arrival time, deadline, and a result slot the
executor fills.  Admission is BOUNDED three ways (global requests,
global rows, per-tenant requests); a full queue rejects with a typed
``AdmissionError`` instead of buffering unboundedly, which is what keeps
the latency SLO meaningful under overload (queueing delay is capped by
construction).

The queue itself never looks at a clock: callers stamp ``now`` into
``submit``, so the same code runs under the wall clock and the
deterministic virtual clock.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from threading import Event, Lock

import numpy as np

from ..runtime.guards import guarded_by


class AdmissionError(RuntimeError):
    """Raised by ``RequestQueue.submit`` when an admission bound is hit —
    the caller should shed or retry later, not buffer."""


@dataclass(slots=True)
class SchedRequest:
    """One queued prediction request: the ticket the scheduler hands back
    at ``submit`` time and fills in when its micro-batch completes.

    ``status`` moves ``"pending"`` -> ``"ok"`` | ``"quarantined"`` |
    ``"failed"`` (the latter two mirror ``ForestServer.serve_safe``
    semantics plus batch-level fault isolation).  ``deadline`` is the
    absolute completion target (arrival + SLO); ``latency_s`` is valid
    once ``done``."""

    seq: int
    user_id: str
    rows: np.ndarray
    arrival_t: float
    deadline: float
    status: str = "pending"
    prediction: np.ndarray | None = None
    detail: str = ""
    degraded: bool = False
    completed_t: float | None = None
    batch_seq: int | None = None
    # resolution signalling: a bare flag on the hot path, with the Event
    # materialized lazily only when somebody actually wait()s.  The
    # flag-then-event / event-then-flag ordering below makes the
    # handshake race-free under the GIL (each side publishes its write
    # before reading the other's).
    _done_flag: bool = field(default=False, repr=False, compare=False)
    _event: Event | None = field(default=None, repr=False, compare=False)

    @property
    def done(self) -> bool:
        """True once the executor resolved this request (any status)."""
        return self._done_flag

    def _resolve(self) -> None:
        """Executor side: publish resolution, then wake any waiter."""
        self._done_flag = True
        ev = self._event
        if ev is not None:
            ev.set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until resolved (overlapped executor); immediate under the
        inline executor.  Returns ``done``."""
        if self._done_flag:
            return True
        ev = self._event
        if ev is None:
            ev = self._event = Event()
            if self._done_flag:  # resolver may have missed the new event
                return True
        return ev.wait(timeout)

    @property
    def n_rows(self) -> int:
        return int(len(self.rows))

    @property
    def latency_s(self) -> float:
        """Arrival-to-completion latency; raises if not yet resolved."""
        if self.completed_t is None:
            raise ValueError(f"request {self.seq} is not resolved yet")
        return self.completed_t - self.arrival_t

    @property
    def deadline_excess_s(self) -> float:
        """Seconds past the deadline this request completed (<= 0 means
        it made the SLO)."""
        if self.completed_t is None:
            raise ValueError(f"request {self.seq} is not resolved yet")
        return self.completed_t - self.deadline


@guarded_by(
    "_lock",
    "_tenants", "_n_pending", "_pending_rows", "_next_seq",
    "n_admitted", "n_rejected", "rows_admitted",
)
class RequestQueue:
    """Per-tenant FIFO of pending requests with admission bounds.

    ``slo_s`` is the default latency SLO: a request submitted at ``now``
    gets ``deadline = now + slo_s`` unless the caller passes an explicit
    ``deadline_s``.  Service is FIFO per tenant, so the batcher's
    deadline trigger looks at TENANT-HEAD deadlines (``head_deadlines``):
    a request behind another of the same tenant cannot be served before
    it, so the head deadline is the earliest *servable* one.

    Thread-safe (ISSUE 9 lock-discipline fix): client threads ``submit``
    while the scheduler's pump thread peeks/pops, so every access to the
    tenant map, occupancy totals, and admission counters holds ``_lock``
    — previously the admission check-then-append could interleave and
    overshoot the bounds, and ``stats`` could read torn totals.
    """

    def __init__(
        self,
        slo_s: float = 0.25,
        max_pending_requests: int = 4096,
        max_pending_rows: int = 1 << 20,
        max_pending_per_tenant: int = 512,
    ) -> None:
        self.slo_s = float(slo_s)
        self.max_pending_requests = int(max_pending_requests)
        self.max_pending_rows = int(max_pending_rows)
        self.max_pending_per_tenant = int(max_pending_per_tenant)
        self._lock = Lock()
        self._tenants: OrderedDict[str, deque[SchedRequest]] = OrderedDict()
        self._n_pending = 0
        self._pending_rows = 0
        self._next_seq = 0
        self.n_admitted = 0
        self.n_rejected = 0
        self.rows_admitted = 0

    # ---------------- admission -------------------------------------------
    def submit(
        self,
        user_id: str,
        rows: np.ndarray,
        now: float,
        deadline_s: float | None = None,
    ) -> SchedRequest:
        """Admit one ``(user_id, rows)`` request at time ``now`` and
        return its ticket.  Raises ``AdmissionError`` when any bound
        (global requests, global rows, per-tenant requests) is full."""
        rows = np.ascontiguousarray(rows, np.int32)
        if rows.ndim != 2:
            raise ValueError(
                f"rows must be a (n, d) block, got shape {rows.shape}"
            )
        # admission is one atomic check-then-append: concurrent submits
        # racing the bounds check could otherwise both pass and overshoot
        with self._lock:
            fifo = self._tenants.get(user_id)
            if self._n_pending >= self.max_pending_requests:
                self.n_rejected += 1
                raise AdmissionError(
                    f"queue full: {self._n_pending} pending requests "
                    f"(bound {self.max_pending_requests})"
                )
            if self._pending_rows + len(rows) > self.max_pending_rows:
                self.n_rejected += 1
                raise AdmissionError(
                    f"queue full: {self._pending_rows} pending rows + "
                    f"{len(rows)} would exceed the "
                    f"{self.max_pending_rows}-row bound"
                )
            if (fifo is not None
                    and len(fifo) >= self.max_pending_per_tenant):
                self.n_rejected += 1
                raise AdmissionError(
                    f"tenant {user_id!r} has {len(fifo)} pending requests "
                    f"(bound {self.max_pending_per_tenant})"
                )
            slo = self.slo_s if deadline_s is None else float(deadline_s)
            req = SchedRequest(
                seq=self._next_seq,
                user_id=user_id,
                rows=rows,
                arrival_t=now,
                deadline=now + slo,
            )
            self._next_seq += 1
            if fifo is None:
                fifo = self._tenants[user_id] = deque()
            fifo.append(req)
            self._n_pending += 1
            self._pending_rows += len(rows)
            self.n_admitted += 1
            self.rows_admitted += len(rows)
            return req

    # ---------------- state the batcher reads -----------------------------
    @property
    def n_pending(self) -> int:
        with self._lock:
            return self._n_pending

    @property
    def pending_rows(self) -> int:
        with self._lock:
            return self._pending_rows

    def head_deadlines(self) -> dict[str, float]:
        """Tenant -> deadline of its FIFO head (the earliest servable
        deadline per tenant — service is FIFO within a tenant)."""
        with self._lock:
            return {
                u: fifo[0].deadline
                for u, fifo in self._tenants.items() if fifo
            }

    def oldest_head_deadline(self) -> float | None:
        """The earliest servable deadline across all tenants, or ``None``
        when the queue is empty — the batcher's deadline trigger."""
        heads = self.head_deadlines()
        return min(heads.values()) if heads else None

    def peek(self, user_id: str) -> SchedRequest | None:
        """The tenant's FIFO head without removing it."""
        with self._lock:
            fifo = self._tenants.get(user_id)
            return fifo[0] if fifo else None

    def pop(self, user_id: str) -> SchedRequest:
        """Remove and return the tenant's FIFO head."""
        with self._lock:
            fifo = self._tenants[user_id]
            req = fifo.popleft()
            if not fifo:
                del self._tenants[user_id]
            self._n_pending -= 1
            self._pending_rows -= req.n_rows
            return req

    def stats(self) -> dict:
        """Occupancy + admission counters for dashboards, read as one
        consistent snapshot under the lock."""
        with self._lock:
            return {
                "n_pending": self._n_pending,
                "pending_rows": self._pending_rows,
                "n_tenants_pending": len(self._tenants),
                "n_admitted": self.n_admitted,
                "n_rejected": self.n_rejected,
                "rows_admitted": self.rows_admitted,
                "slo_s": self.slo_s,
            }

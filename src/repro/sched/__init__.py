"""Continuous-batching request scheduler + self-driving codebook
lifecycle (ISSUE 7).

The package layers an ONLINE front onto the PR 4 serving session and
the PR 5/6 lifecycle machinery:

* :mod:`repro.sched.clock` — wall/virtual clock injection; everything
  below reads time through it, so tests are bit-deterministic.
* :mod:`repro.sched.queue` — per-tenant FIFO with bounded admission.
* :mod:`repro.sched.batcher` — dual-trigger (rows budget / SLO
  deadline) micro-batch formation, tenant-coherent and plan-cache
  friendly.
* :mod:`repro.sched.executor` — plan(k+1)/execute(k) overlap with
  ``serve_safe`` per-request semantics and batch-level fault isolation.
* :mod:`repro.sched.driver` — autonomous drift-poll -> journaled
  recluster -> rate-limited migration loop.
* :mod:`repro.sched.scheduler` — the facade tying them together.
"""
from .batcher import MicroBatch, MicroBatcher
from .clock import VirtualClock, WallClock
from .driver import LifecycleDriver
from .executor import PipelinedExecutor
from .queue import AdmissionError, RequestQueue, SchedRequest
from .scheduler import Scheduler

__all__ = [
    "AdmissionError",
    "LifecycleDriver",
    "MicroBatch",
    "MicroBatcher",
    "PipelinedExecutor",
    "RequestQueue",
    "SchedRequest",
    "Scheduler",
    "VirtualClock",
    "WallClock",
]

"""Clocks for the request scheduler (ISSUE 7).

Every scheduling decision — admission deadlines, the micro-batcher's
dual trigger, the lifecycle driver's poll window and migration rate
limit — reads time through one of these two clocks, never ``time.*``
directly.  That is what makes the scheduler testable: under a
``VirtualClock`` a test advances time by hand and every trigger,
deadline, and rate budget fires deterministically, bit-for-bit
reproducibly; production swaps in ``WallClock`` without touching any
scheduling code.
"""
from __future__ import annotations

import time


class VirtualClock:
    """Manually advanced clock for deterministic scheduler tests and
    benchmarks: ``now()`` returns the virtual time, ``advance``/``sleep``
    move it forward (sleeping never blocks)."""

    def __init__(self, start: float = 0.0) -> None:
        self._now = float(start)

    def now(self) -> float:
        """Current virtual time in seconds."""
        return self._now

    def advance(self, dt: float) -> float:
        """Move virtual time forward by ``dt`` seconds (never backward)."""
        if dt < 0:
            raise ValueError(f"cannot advance time backward (dt={dt})")
        self._now += float(dt)
        return self._now

    def sleep(self, dt: float) -> None:
        """Virtual sleep: advances time, returns immediately."""
        self.advance(dt)


class WallClock:
    """Monotonic wall clock for production use (immune to NTP steps)."""

    def now(self) -> float:
        """Seconds from an arbitrary monotonic origin."""
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        """Real sleep."""
        if dt > 0:
            time.sleep(dt)

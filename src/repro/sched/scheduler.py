"""The continuous-batching scheduler facade (ISSUE 7 tentpole).

``Scheduler`` is the online front of ``ForestServer``: callers submit
per-user requests as they arrive and get back tickets; the scheduler
coalesces them into micro-batches under the dual trigger (row budget /
SLO deadline), overlaps host planning with device execution across
consecutive batches, and — when a ``LifecycleDriver`` is attached —
re-clusters the fleet codebook autonomously in low-load gaps with
rate-limited migration.

    sched = Scheduler(server, lifecycle=LifecycleDriver(server, clock))
    ticket = sched.submit("user00042", rows)   # returns immediately
    sched.pump()                               # form + dispatch due batches
    ticket.wait(); ticket.prediction           # resolved serve_safe result
    sched.flush()                              # drain everything

The pump loop is explicitly driven (no hidden thread): a production
host calls ``pump`` from its event loop; tests drive it with a
``VirtualClock`` for bit-deterministic batching, triggering, and
lifecycle decisions.  Execution overlap lives in ``PipelinedExecutor``
and defaults on under a wall clock, off (inline, deterministic) under a
virtual clock.
"""
from __future__ import annotations

import numpy as np

from .batcher import MicroBatch, MicroBatcher
from .clock import VirtualClock, WallClock
from .executor import PipelinedExecutor
from .queue import RequestQueue, SchedRequest


class Scheduler:
    """Continuous-batching request scheduler over one ``ForestServer``."""

    def __init__(
        self,
        server,
        clock=None,
        queue: RequestQueue | None = None,
        batcher: MicroBatcher | None = None,
        lifecycle=None,
        safe: bool = True,
        overlap: bool | None = None,
        fault_hook=None,
        prefetcher=None,
    ) -> None:
        self.server = server
        self.clock = clock if clock is not None else WallClock()
        self.queue = queue if queue is not None else RequestQueue()
        self.batcher = batcher if batcher is not None else MicroBatcher()
        self.lifecycle = lifecycle
        self.prefetcher = prefetcher
        if overlap is None:
            # virtual time has no concurrency to overlap with — run inline
            # so tests are single-threaded deterministic
            overlap = not isinstance(self.clock, VirtualClock)
        self.executor = PipelinedExecutor(
            server, self.clock, safe=safe, overlap=overlap,
            fault_hook=fault_hook, prefetcher=prefetcher,
        )
        self.completed: list[SchedRequest] = []

    # ---------------- intake ----------------------------------------------
    def submit(
        self,
        user_id: str,
        rows: np.ndarray,
        deadline_s: float | None = None,
    ) -> SchedRequest:
        """Admit one request (deadline = now + SLO unless overridden) and
        return its ticket.  Raises ``sched.AdmissionError`` when the
        queue's admission bounds are full.  Call ``pump`` to let due
        micro-batches form and dispatch."""
        return self.queue.submit(
            user_id, rows, self.clock.now(), deadline_s=deadline_s
        )

    # ---------------- the pump loop ---------------------------------------
    def pump(self) -> int:
        """One scheduler step at the current clock time: form and
        dispatch every micro-batch whose trigger is due, then tick the
        lifecycle driver.  Returns the number of batches dispatched."""
        n = 0
        while True:
            batch = self.batcher.form(self.queue, self.clock.now())
            if batch is None:
                break
            self._dispatch(batch)
            n += 1
        if self.lifecycle is not None:
            self.lifecycle.tick(self.clock.now(), self.queue.pending_rows)
        return n

    def next_due_in(self) -> float | None:
        """Seconds until the deadline trigger next fires (<= 0: due now;
        ``None``: queue empty) — what an event loop sleeps between pumps."""
        oldest = self.queue.oldest_head_deadline()
        if oldest is None:
            return None
        return (
            oldest - self.batcher.plan_headroom_s - self.clock.now()
        )

    def flush(self, drain: bool = True) -> int:
        """Dispatch everything still queued regardless of triggers, then
        (by default) block until the executor drains.  Returns the number
        of batches dispatched."""
        n = 0
        while True:
            batch = self.batcher.form(
                self.queue, self.clock.now(), flush=True
            )
            if batch is None:
                break
            self._dispatch(batch)
            n += 1
        if drain:
            self.executor.drain()
        return n

    def _dispatch(self, batch: MicroBatch) -> None:
        self.completed.extend(batch.requests)  # resolved in flight order
        self.executor.submit(batch)

    def close(self) -> None:
        """Flush, drain, and stop the executor worker (and the residency
        prefetcher's, when one is attached)."""
        self.flush()
        self.executor.close()
        if self.prefetcher is not None:
            self.prefetcher.close()

    # ---------------- observability ---------------------------------------
    def latency_stats(self, slack_s: float = 0.0) -> dict:
        """Latency distribution over resolved requests: p50/p99/max
        arrival-to-completion, SLO attainment, and deadline misses beyond
        ``slack_s``."""
        done = [r for r in self.completed if r.done]
        if not done:
            return {"n_completed": 0}
        lat = np.array([r.latency_s for r in done])
        excess = np.array([r.deadline_excess_s for r in done])
        misses = int((excess > slack_s).sum())
        return {
            "n_completed": len(done),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "max_ms": round(float(lat.max()) * 1e3, 3),
            "deadline_misses": misses,
            "slo_attainment": round(1.0 - misses / len(done), 4),
            "max_deadline_excess_ms": round(float(excess.max()) * 1e3, 3),
            "slack_s": slack_s,
        }

    def stats(self) -> dict:
        """One dict for the whole scheduling layer: queue occupancy and
        admission counters, batch-formation trigger mix, executor
        counters, latency distribution, request status counts, and the
        lifecycle driver's state when attached."""
        statuses: dict[str, int] = {}
        for r in self.completed:
            if r.done:
                statuses[r.status] = statuses.get(r.status, 0) + 1
        return {
            "queue": self.queue.stats(),
            "batcher": self.batcher.stats(),
            "executor": self.executor.stats(),
            "latency": self.latency_stats(),
            "statuses": statuses,
            "lifecycle": (
                self.lifecycle.stats() if self.lifecycle is not None
                else None
            ),
        }

"""Self-driving codebook lifecycle (ISSUE 7 tentpole piece 4).

``LifecycleDriver`` closes the loop the ROADMAP called "self-driving":
the PR 5 drift monitor decides WHEN to recluster and the PR 5/6
migration machinery does the moving, with no human calling
``recluster()``:

* **watch** — polls ``drift_report`` on a LOAD-AWARE window: the poll
  interval stretches with queue depth so a busy scheduler is not taxed
  with observability work (the report itself is memoized on the store
  registry version — the ISSUE 7 satellite bugfix — so an unchanged
  fleet polls for free).
* **trigger** — once the monitor recommends a recluster AND the queue is
  in a low-load gap (pending rows at or under ``low_load_rows``), the
  driver runs a journaled ``recluster(mode, migrate=False)``: successor
  codebook built and installed, nothing migrated yet.  Mixed-generation
  serving (PR 5) keeps every request exact from this moment on.
* **migrate** — per-user migration is RATE-LIMITED to
  ``migrate_users_per_s`` (a budget accumulator over clock time, at most
  ``max_users_per_tick`` per tick), each user journaled
  intent-before/commit-after exactly like ``lifecycle.recluster`` would,
  so serving latency stays inside the SLO mid-migration and a crash at
  any point is recoverable via ``resume_recluster``.  Superseded-
  generation GC runs strictly after the journal commits.

The driver is a plain ``tick(now, pending_rows)`` callable — the
scheduler invokes it from its pump loop, so under a virtual clock every
poll, trigger, and migration step is deterministic.
"""
from __future__ import annotations

from ..store.lifecycle import (
    MigrationJournal,
    RemapTable,
    drift_report,
    migrate_user,
    recluster,
)


class LifecycleDriver:
    """Autonomous drift-poll -> recluster -> rate-limited-migration loop
    over a ``ForestServer``'s store."""

    def __init__(
        self,
        server,
        clock,
        poll_interval_s: float = 1.0,
        max_poll_interval_s: float = 8.0,
        recluster_threshold: float = 0.2,
        low_load_rows: int = 256,
        migrate_users_per_s: float = 50.0,
        max_users_per_tick: int = 8,
        mode: str = "extend",
        seed: int = 0,
        verify: bool = True,
        journal_path: str | None = None,
        scrubber=None,
        scrub_interval_s: float = 2.0,
        scrub_shards_per_tick: int = 64,
    ) -> None:
        self.server = server
        self.clock = clock
        self.poll_interval_s = float(poll_interval_s)
        self.max_poll_interval_s = float(max_poll_interval_s)
        self.recluster_threshold = float(recluster_threshold)
        self.low_load_rows = int(low_load_rows)
        self.migrate_users_per_s = float(migrate_users_per_s)
        self.max_users_per_tick = int(max_users_per_tick)
        self.mode = mode
        self.seed = seed
        self.verify = verify
        self.journal_path = journal_path
        # background scrubbing (ISSUE 8): a ``store.durable.Scrubber``
        # ticked in the same low-load gaps as recluster — durability work
        # must not tax a loaded queue
        self.scrubber = scrubber
        self.scrub_interval_s = float(scrub_interval_s)
        self.scrub_shards_per_tick = int(scrub_shards_per_tick)
        self._next_scrub: float | None = None
        # state machine: "watching" -> "migrating" -> "watching"
        self.state = "watching"
        self._next_poll: float | None = None
        self._remap: RemapTable | None = None
        self._pending: list[str] = []
        self._journal: MigrationJournal | None = None
        self._budget = 0.0
        self._last_budget_t: float | None = None
        # counters for dashboards / the bench
        self.n_polls = 0
        self.n_reclusters = 0
        self.n_migrated = 0
        self.n_migration_ticks = 0
        self.n_deferred = 0
        self.n_recluster_failures = 0
        self.n_scrub_ticks = 0
        self.n_scrub_failures = 0
        self.last_report: dict | None = None
        self.last_error: str | None = None

    @property
    def store(self):
        return self.server.store

    # ---------------- the tick --------------------------------------------
    def tick(self, now: float, pending_rows: int) -> None:
        """One driver step, called from the scheduler's pump loop with
        the current queue depth (rows) for load awareness."""
        if self.state == "migrating":
            self._migrate_some(now)
            return
        self._maybe_scrub(now, pending_rows)
        if self._next_poll is not None and now < self._next_poll:
            return
        # load-aware window: a loaded queue stretches the poll interval
        # (linearly in queue depth, capped), an idle one polls at base rate
        load = pending_rows / max(self.low_load_rows, 1)
        interval = min(
            self.poll_interval_s * (1.0 + load), self.max_poll_interval_s
        )
        self._next_poll = now + interval
        # drop quarantines whose delta changed since (repair/migration)
        # before reading the set — serve_safe does the same refresh, but
        # an idle fleet may not see a serve between repair and poll
        self.server._refresh_quarantine()
        report = drift_report(
            self.store,
            recluster_threshold=self.recluster_threshold,
            exclude=tuple(self.server.quarantined_users),
        )
        self.n_polls += 1
        self.last_report = {
            k: report[k]
            for k in (
                "n_users", "codebook_generation", "n_pending_migration",
                "fallback_user_fraction", "fallback_overhead_fraction",
                "recommend_recluster",
            )
        }
        if (
            report["recommend_recluster"]
            and report["n_pending_migration"] == 0
            and pending_rows <= self.low_load_rows
        ):
            if self.server.quarantined_users:
                # a quarantined delta cannot be decoded, hence cannot be
                # migrated — defer until it is repaired or dropped
                self.n_deferred += 1
                return
            try:
                self._start_recluster(now)
            except Exception as e:  # noqa: BLE001 — a failed recluster
                # must not take the scheduler's pump loop down with it
                self.n_recluster_failures += 1
                self.last_error = f"{type(e).__name__}: {e}"
                self.state = "watching"
                self._pending = []

    def _maybe_scrub(self, now: float, pending_rows: int) -> None:
        """One bounded scrub tick when the queue is in a low-load gap and
        the scrub interval elapsed.  A scrubber fault is counted, never
        propagated — durability maintenance must not take down the pump
        loop."""
        if (
            self.scrubber is None
            or pending_rows > self.low_load_rows
            or (self._next_scrub is not None and now < self._next_scrub)
        ):
            return
        self._next_scrub = now + self.scrub_interval_s
        try:
            self.scrubber.tick(self.scrub_shards_per_tick)
            self.n_scrub_ticks += 1
        except Exception as e:  # noqa: BLE001 — keep the pump loop alive
            self.n_scrub_failures += 1
            self.last_error = f"{type(e).__name__}: {e}"

    # ---------------- recluster + rate-limited migration ------------------
    def _start_recluster(self, now: float) -> None:
        """Build + install the successor generation (journaled), then
        hand the per-user migration to the rate limiter."""
        journal = MigrationJournal(path=self.journal_path)
        result = recluster(
            self.store, mode=self.mode, seed=self.seed,
            migrate=False, journal=journal,
        )
        self._journal = journal
        self._remap = result.remap
        self._pending = [
            u for u in self.store.user_ids
            if self.store.delta(u).codebook_generation
            != self.store.generation
        ]
        self._budget = 0.0
        self._last_budget_t = now
        self.n_reclusters += 1
        if self._pending:
            self.state = "migrating"
        else:
            self._finish_migration()

    def _migrate_some(self, now: float) -> None:
        """Migrate up to the rate budget's worth of users this tick."""
        last = self._last_budget_t if self._last_budget_t is not None else now
        dt = max(now - last, 0.0)
        self._last_budget_t = now
        self._budget = min(
            self._budget + dt * self.migrate_users_per_s,
            float(self.max_users_per_tick),
        )
        n = min(int(self._budget), len(self._pending))
        if n == 0:
            return
        self.n_migration_ticks += 1
        journal, remap = self._journal, self._remap
        for u in self._pending[:n]:
            journal.log_migrate_intent(u, self.store.delta(u).to_bytes())
            rec = migrate_user(
                self.store, u, remap, seed=self.seed, verify=self.verify
            )
            journal.log_migrate_commit(u, rec["status"])
            self.n_migrated += 1
        del self._pending[:n]
        self._budget -= n
        if not self._pending:
            self._finish_migration()

    def _finish_migration(self) -> None:
        """Commit the journal, then (and only then) GC superseded
        codebook generations — the PR 6 crash-safety ordering."""
        self._journal.log_committed()
        self.store.drop_unreferenced_codebooks()
        self.state = "watching"
        self._remap = None
        self._next_poll = None  # re-poll immediately: drift is repaired

    def stats(self) -> dict:
        """Driver state + counters for dashboards and the bench."""
        return {
            "state": self.state,
            "n_polls": self.n_polls,
            "n_reclusters": self.n_reclusters,
            "n_migrated": self.n_migrated,
            "n_migration_ticks": self.n_migration_ticks,
            "n_deferred": self.n_deferred,
            "n_recluster_failures": self.n_recluster_failures,
            "last_error": self.last_error,
            "n_pending_migration": len(self._pending),
            "migrate_users_per_s": self.migrate_users_per_s,
            "mode": self.mode,
            "n_scrub_ticks": self.n_scrub_ticks,
            "n_scrub_failures": self.n_scrub_failures,
            "scrub": (
                self.scrubber.stats() if self.scrubber is not None
                else None
            ),
            "last_report": self.last_report,
            "journal": (
                self._journal.summary() if self._journal is not None
                else None
            ),
        }

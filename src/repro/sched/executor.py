"""Pipelined micro-batch execution (ISSUE 7 tentpole piece 3).

``PipelinedExecutor`` runs micro-batches through a ``ForestServer``,
overlapping the HOST half of batch *k+1* with the DEVICE half of batch
*k*: ``submit`` pre-builds the plan for the incoming batch on the
caller's thread (grouping, argsort, engine cost model — all host work,
memoized into the server's ``PlanCache``) while the single worker
thread is still blocked on the previous batch's kernel; when the worker
reaches the new batch, its plan stage is a cache hit and it goes
straight to pack/execute.  Ordering is preserved (one worker, FIFO
queue), so results are identical to inline execution.

Per-request semantics are exactly ``ForestServer.serve_safe`` (ISSUE
6): quarantined users come back ``status="quarantined"`` while healthy
users in the same micro-batch are served, transient arena faults are
retried/degraded inside the server.  On top of that the executor adds
BATCH-level fault isolation: an exception that escapes the serve path
(or the chaos ``fault_hook``) marks just that batch's requests
``status="failed"`` and the scheduler keeps going.

``overlap=False`` executes inline on the caller's thread — same
results, fully deterministic — which is what the virtual-clock tests
use.
"""
from __future__ import annotations

import queue as _queue
import threading

from ..runtime.guards import guarded_by
from .batcher import MicroBatch

_STOP = object()


@guarded_by(
    "_idle",
    "_inflight", "n_batches", "n_failed_batches", "n_preplanned",
)
class PipelinedExecutor:
    """Single-consumer micro-batch executor over one ``ForestServer``.

    ``_idle`` (a ``Condition``) is the one lock: it already guarded the
    in-flight count for backpressure, and since ISSUE 9 it also guards
    the batch counters — ``_run`` mutates them on the worker thread
    while ``stats`` reads them from the pump thread."""

    def __init__(
        self,
        server,
        clock,
        safe: bool = True,
        overlap: bool = True,
        max_inflight: int = 2,
        fault_hook=None,
        prefetcher=None,
    ) -> None:
        self.server = server
        self.clock = clock
        self.safe = bool(safe)
        self.overlap = bool(overlap)
        # residency prefetcher (store.residency.Prefetcher): the pre-plan
        # slot hands it batch k+1's users while batch k executes, so
        # demoted users' shards are read + parsed off the serve path
        self.prefetcher = prefetcher
        if max_inflight < 1:
            raise ValueError(
                f"max_inflight must be positive, got {max_inflight}"
            )
        self.max_inflight = int(max_inflight)
        self.fault_hook = fault_hook
        self.n_batches = 0
        self.n_failed_batches = 0
        self.n_preplanned = 0
        self._inflight = 0
        self._idle = threading.Condition()
        self._work: _queue.SimpleQueue = _queue.SimpleQueue()
        self._worker: threading.Thread | None = None
        if self.overlap:
            self._worker = threading.Thread(
                target=self._worker_loop, name="sched-executor", daemon=True
            )
            self._worker.start()

    # ---------------- submit side (host stage) ----------------------------
    def submit(self, batch: MicroBatch) -> None:
        """Dispatch one micro-batch.  Pre-plans on the calling thread
        (overlapping the in-flight batch's device work), then executes —
        on the worker thread when overlapped, inline otherwise.

        Submission applies BACKPRESSURE: it blocks while ``max_inflight``
        batches are already queued (double buffering by default).  The
        bound matters beyond memory: pre-planning is host work that
        contends with the worker's own host stages, so racing arbitrarily
        far ahead *slows the pipeline down* — one batch ahead captures
        the whole overlap win."""
        if self.overlap:
            with self._idle:
                self._idle.wait_for(
                    lambda: self._inflight < self.max_inflight
                )
                self._inflight += 1
            self._preplan(batch)
            self._work.put(batch)
        else:
            if self.prefetcher is not None:
                # no overlap to hide the warm behind, but the prefetch
                # accounting (and its determinism under VirtualClock)
                # must match the overlapped path
                self._prefetch(batch)
            self._run(batch)

    def _preplan(self, batch: MicroBatch) -> None:
        """Build (and memoize) the plan the serve path will need, using
        row COUNTS only — plans don't depend on row values, so this is
        pure host work the device never waits for.  Quarantined users are
        left out to match the healthy subset ``serve_safe`` will plan."""
        quarantined = (
            set(self.server.quarantined_users) if self.safe else ()
        )
        reqs = [
            (r.user_id, r.n_rows)
            for r in batch.requests if r.user_id not in quarantined
        ]
        if not reqs:
            return
        if self.prefetcher is not None:
            self._prefetch(batch)
        try:
            self.server.plan(reqs)
            with self._idle:
                self.n_preplanned += 1
        except Exception:  # noqa: BLE001 — planning faults surface (and
            # are isolated) at execute time; pre-planning is best-effort
            pass

    def _prefetch(self, batch: MicroBatch) -> None:
        """Warm the batch's demoted users' shards (best-effort): under
        overlap this runs in the plan-of-k+1 slot, so the disk read +
        RFD1 parse overlaps batch k's device work.  The prefetcher
        filters quarantined users itself (it holds the server)."""
        try:
            self.prefetcher.request(r.user_id for r in batch.requests)
        except Exception:  # noqa: BLE001 — prefetch is advisory; the
            # serve path surfaces real faults through quarantine
            pass

    # ---------------- worker side (device stage) --------------------------
    def _worker_loop(self) -> None:
        while True:
            batch = self._work.get()
            if batch is _STOP:
                return
            try:
                self._run(batch)
            finally:
                with self._idle:
                    self._inflight -= 1
                    self._idle.notify_all()

    def _run(self, batch: MicroBatch) -> None:
        with self._idle:
            self.n_batches += 1
        requests = [(r.user_id, r.rows) for r in batch.requests]
        try:
            if self.fault_hook is not None:
                self.fault_hook(batch)
            if self.safe:
                statuses = self.server.serve_safe(requests)
                for r, st in zip(batch.requests, statuses):
                    r.status = st.status
                    r.prediction = st.prediction
                    r.detail = st.detail
                    r.degraded = st.degraded
            else:
                preds = self.server.serve(requests)
                for r, p in zip(batch.requests, preds):
                    r.status = "ok"
                    r.prediction = p
        except Exception as e:  # noqa: BLE001 — batch-level isolation:
            # one poisoned batch must not kill the scheduler loop
            with self._idle:
                self.n_failed_batches += 1
            detail = f"{type(e).__name__}: {e}"
            for r in batch.requests:
                r.status = "failed"
                r.detail = detail
        now = self.clock.now()
        for r in batch.requests:
            r.completed_t = now
            r._resolve()

    # ---------------- lifecycle -------------------------------------------
    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted batch has executed.  Returns True
        when drained (always, under the inline executor)."""
        if not self.overlap:
            return True
        with self._idle:
            return self._idle.wait_for(
                lambda: self._inflight == 0, timeout
            )

    def close(self) -> None:
        """Drain and stop the worker thread (idempotent)."""
        if self._worker is None:
            return
        self.drain()
        self._work.put(_STOP)
        self._worker.join()
        self._worker = None

    def stats(self) -> dict:
        """Execution counters for dashboards, snapshotted under the
        lock (the worker thread mutates them concurrently)."""
        with self._idle:
            return {
                "n_batches": self.n_batches,
                "n_failed_batches": self.n_failed_batches,
                "n_preplanned": self.n_preplanned,
                "overlap": self.overlap,
                "max_inflight": self.max_inflight,
                "safe": self.safe,
            }

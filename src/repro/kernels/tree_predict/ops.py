"""jit'd wrappers: ForestModel-level prediction via the Pallas kernels,
plus the multi-device sharded entry for the segmented serving kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .tree_predict import forest_predict, forest_predict_agg


def predict_forest_kernel(model, x_raw: np.ndarray, interpret: bool | None = None):
    """Ensemble prediction matching repro.forest.predict_forest, but through
    the fused-aggregation Pallas kernel (votes / fit sums are reduced
    in-kernel across the tree-tile grid axis). Returns (n,) predictions."""
    xb = jnp.asarray(model.binner.transform(x_raw), jnp.int32)
    cfg = model.cfg
    if cfg.task == "classification":
        # per-tree argmax class encoded as scalar fit
        fit = jnp.asarray(model.node_fit.argmax(-1), jnp.float32)
        votes = forest_predict_agg(
            xb,
            jnp.asarray(model.feature),
            jnp.asarray(model.threshold),
            fit,
            jnp.asarray(model.is_internal),
            max_depth=cfg.max_depth,
            n_classes=cfg.n_classes,
            interpret=interpret,
        )  # (N, C)
        return np.asarray(votes.argmax(-1))
    fit = jnp.asarray(model.node_fit[..., 0], jnp.float32)
    sums = forest_predict_agg(
        xb,
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        fit,
        jnp.asarray(model.is_internal),
        max_depth=cfg.max_depth,
        interpret=interpret,
    )  # (N,)
    return np.asarray(sums / model.n_trees)


def predict_forest_kernel_per_tree(
    model, x_raw: np.ndarray, interpret: bool | None = None
):
    """(T, N) per-tree leaf fits through the unaggregated kernel (kept for
    sigma^2-style per-tree diagnostics and as a parity reference)."""
    xb = jnp.asarray(model.binner.transform(x_raw), jnp.int32)
    cfg = model.cfg
    if cfg.task == "classification":
        fit = jnp.asarray(model.node_fit.argmax(-1), jnp.float32)
    else:
        fit = jnp.asarray(model.node_fit[..., 0], jnp.float32)
    return forest_predict(
        xb,
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        fit,
        jnp.asarray(model.is_internal),
        max_depth=cfg.max_depth,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Sharded ragged tree axis (ISSUE 3 tentpole piece 3)
# ---------------------------------------------------------------------------

def partition_segments_by_load(
    seg_trees: np.ndarray, n_shards: int
) -> list[list[int]]:
    """Greedy bin-pack of segment (user) ids onto ``n_shards`` devices by
    per-segment tree count: heaviest segment first onto the least-loaded
    shard.  Returns one list of segment ids per shard (possibly empty)."""
    seg_trees = np.asarray(seg_trees, np.int64)
    shards: list[list[int]] = [[] for _ in range(n_shards)]
    loads = np.zeros(n_shards, np.int64)
    for s in np.argsort(-seg_trees, kind="stable"):
        k = int(np.argmin(loads))
        shards[k].append(int(s))
        loads[k] += int(seg_trees[s])
    return shards


def estimate_shard_speedup(seg_trees: np.ndarray, n_shards: int) -> float:
    """Predicted sharded-engine speedup for a batch: total tree load over
    the heaviest shard's load under the greedy bin-pack (1.0 = one user
    dominates and sharding buys nothing; ``n_shards`` = perfectly even).
    The serving session's engine cost model compares this against its
    minimum-speedup threshold instead of blindly sharding on any
    multi-device host."""
    seg_trees = np.asarray(seg_trees, np.int64)
    total = int(seg_trees.sum())
    if total == 0 or n_shards <= 1:
        return 1.0
    shards = partition_segments_by_load(seg_trees, n_shards)
    max_load = max(
        (sum(int(seg_trees[s]) for s in shard) for shard in shards if shard),
        default=total,
    )
    return total / max(max_load, 1)


@functools.lru_cache(maxsize=None)
def _sharded_callable(
    n_devices: int, max_depth: int, n_classes: int, block_trees: int,
    block_obs: int, tb2: int, interpret: bool,
):
    """Build (once per static config) the jitted shard_map program: each
    device runs the pipelined segmented kernel on ITS tree shard against
    the full replicated batch, then the (N, C) partials all-reduce."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from .tree_predict import _forest_predict_agg_seg_pipelined_impl

    mesh = Mesh(np.array(jax.devices()[:n_devices]), ("shard",))

    def per_device(xb, oseg, code, fit, tseg, chunk_lo, chunk_hi):
        part = _forest_predict_agg_seg_pipelined_impl(
            xb, oseg, code[0], fit[0], tseg[0], chunk_lo[0], chunk_hi[0],
            max_depth, n_classes, block_trees, block_obs, tb2, interpret,
        )
        if n_classes == 0:
            part = part[:, None]
        return jax.lax.psum(part, "shard")

    fn = shard_map(
        per_device,
        mesh=mesh,
        in_specs=(
            P(), P(), P("shard"), P("shard"), P("shard"), P("shard"),
            P("shard"),
        ),
        out_specs=P(),
        check_rep=False,  # pallas_call has no replication rule
    )
    return jax.jit(fn)


def forest_predict_agg_segmented_sharded(
    xb,  # (N, d) int32, replicated
    obs_seg,  # (N,) int32, replicated
    code,  # (S, T_pad, H) float32 fused tiles, one tree shard per device
    fit,  # (S, T_pad, H) float32
    tree_seg,  # (S, T_pad) int32, -1 marks padding trees
    chunk_lo,  # (S, ceil(N / block_obs)) int32 per-shard fori_loop bounds
    chunk_hi,  # (S, ceil(N / block_obs)) int32
    max_depth: int,
    tb2: int,
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Multi-device ragged serving: the tree axis is SHARDED across devices
    (one stacked shard per device, load-balanced by
    ``partition_segments_by_load``), observations are replicated, each
    device accumulates partial votes/sums over its own trees through the
    pipelined DMA kernel, and the (N, C) aggregate all-reduces with one
    ``psum`` — fleets whose hot tree set exceeds one core's VMEM/HBM scale
    out instead of thrashing.

    Vote counts stay integer-exact under the reduction (float32 holds
    integers exactly below 2**24), so classification results are bit-exact
    against the single-device engines."""
    from .tree_predict import _F32_EXACT_INT, _validate_f32_exact

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    s = code.shape[0]
    n_dev = len(jax.devices())
    if s > n_dev:
        raise ValueError(f"{s} tree shards but only {n_dev} devices")
    n, d = xb.shape
    # same guards as the single-device packed entry: out-of-range values
    # must raise, not silently round through the float32 one-hot gathers
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    arrays = {"xb": xb} if isinstance(xb, np.ndarray) else {}
    _validate_f32_exact(max_depth, d, **arrays)
    fn = _sharded_callable(
        s, max_depth, n_classes, block_trees, min(block_obs, n), int(tb2),
        interpret,
    )
    out = fn(
        jnp.asarray(xb, jnp.int32), jnp.asarray(obs_seg, jnp.int32),
        jnp.asarray(code), jnp.asarray(fit),
        jnp.asarray(tree_seg, jnp.int32), jnp.asarray(chunk_lo, jnp.int32),
        jnp.asarray(chunk_hi, jnp.int32),
    )
    return out[:, 0] if n_classes == 0 else out

"""jit'd wrapper: ForestModel-level prediction via the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .tree_predict import forest_predict, forest_predict_agg


def predict_forest_kernel(model, x_raw: np.ndarray, interpret: bool | None = None):
    """Ensemble prediction matching repro.forest.predict_forest, but through
    the fused-aggregation Pallas kernel (votes / fit sums are reduced
    in-kernel across the tree-tile grid axis). Returns (n,) predictions."""
    xb = jnp.asarray(model.binner.transform(x_raw), jnp.int32)
    cfg = model.cfg
    if cfg.task == "classification":
        # per-tree argmax class encoded as scalar fit
        fit = jnp.asarray(model.node_fit.argmax(-1), jnp.float32)
        votes = forest_predict_agg(
            xb,
            jnp.asarray(model.feature),
            jnp.asarray(model.threshold),
            fit,
            jnp.asarray(model.is_internal),
            max_depth=cfg.max_depth,
            n_classes=cfg.n_classes,
            interpret=interpret,
        )  # (N, C)
        return np.asarray(votes.argmax(-1))
    fit = jnp.asarray(model.node_fit[..., 0], jnp.float32)
    sums = forest_predict_agg(
        xb,
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        fit,
        jnp.asarray(model.is_internal),
        max_depth=cfg.max_depth,
        interpret=interpret,
    )  # (N,)
    return np.asarray(sums / model.n_trees)


def predict_forest_kernel_per_tree(
    model, x_raw: np.ndarray, interpret: bool | None = None
):
    """(T, N) per-tree leaf fits through the unaggregated kernel (kept for
    sigma^2-style per-tree diagnostics and as a parity reference)."""
    xb = jnp.asarray(model.binner.transform(x_raw), jnp.int32)
    cfg = model.cfg
    if cfg.task == "classification":
        fit = jnp.asarray(model.node_fit.argmax(-1), jnp.float32)
    else:
        fit = jnp.asarray(model.node_fit[..., 0], jnp.float32)
    return forest_predict(
        xb,
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        fit,
        jnp.asarray(model.is_internal),
        max_depth=cfg.max_depth,
        interpret=interpret,
    )

"""jit'd wrapper: ForestModel-level prediction via the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .tree_predict import forest_predict


def predict_forest_kernel(model, x_raw: np.ndarray, interpret: bool | None = None):
    """Ensemble prediction matching repro.forest.predict_forest, but through
    the Pallas traversal kernel. Returns (n,) predictions."""
    xb = jnp.asarray(model.binner.transform(x_raw), jnp.int32)
    cfg = model.cfg
    if cfg.task == "classification":
        # per-tree argmax class encoded as scalar fit
        fit = jnp.asarray(model.node_fit.argmax(-1), jnp.float32)
    else:
        fit = jnp.asarray(model.node_fit[..., 0], jnp.float32)
    per_tree = forest_predict(
        xb,
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        fit,
        jnp.asarray(model.is_internal),
        max_depth=cfg.max_depth,
        interpret=interpret,
    )  # (T, N)
    if cfg.task == "classification":
        votes = jnp.stack(
            [(per_tree == c).sum(0) for c in range(cfg.n_classes)], -1
        )
        return np.asarray(votes.argmax(-1))
    return np.asarray(per_tree.mean(0))

"""Batched random-forest inference Pallas TPU kernels — the paper's serving
hot spot (predict-from-compressed decodes trees, then this evaluates them).

Layout: trees in heap form (node i -> children 2i+1 / 2i+2), so traversal is
pure arithmetic + gathers, no pointers.  Tiling: each program holds a
(BT, Hp) tile of tree arrays and a (BN, d) tile of binned observations in
VMEM and walks ``max_depth`` levels for all (tree, obs) pairs at once — VPU
select ops + MXU one-hot contractions.  Trees are tiny and reused across the
whole observation tile, so the kernel is gather-throughput-bound in VMEM
rather than HBM-bound: per HBM byte of tree data we do BN gathers.

Gathers use TWO-LEVEL one-hot contractions: a heap index over ``Hp`` nodes is
split into (hi, lo) = (idx >> lo_bits, idx & (Hlo - 1)) and gathered as
``sum_l one_hot(hi) @ tab[:, hi, :] * one_hot(lo)``.  The one-hot operands
are (BT, BN, Hhi) + (BT, BN, Hlo) ~ O(sqrt(H)) per element instead of the
(BT, BN, H) materialization of a flat one-hot — the VMEM working set stays
flat as depth grows (depth 14 => 180x smaller level scratch).

Three kernels share the traversal:

* ``forest_predict``       -> (T, N) per-(tree, obs) leaf fits;
* ``forest_predict_agg``   -> in-kernel ensemble aggregation over the
  tree-tile grid axis: (N,) fit sums (regression) or (N, C) vote counts
  (classification).  Output HBM traffic shrinks by ~T/block_trees x, and the
  host-side ensemble reduction disappears.
* ``forest_predict_agg_segmented`` -> ragged multi-tenant aggregation: trees
  and observations carry int32 segment (user) ids, and a (tree, obs) pair
  contributes only when the ids match.  Many users' forests pack into ONE
  tree axis (no per-user padding) and one kernel launch serves the whole
  mixed batch — the multi-tenant store's serving front-end
  (``repro.launch.serve_store``).

The segmented kernel comes in TWO engines (``engine=`` on the wrapper):

* ``"simple"``  — the original grid-per-tree-tile kernel, kept verbatim as
  the differential oracle and the PR 2 serving baseline;
* ``"pipelined"`` (default when inputs allow) — one launch per batch with a
  MANUAL double-buffered DMA pipeline: tree tiles live in HBM
  (``memory_space=ANY``) and the kernel streams them into two VMEM slots
  with ``pltpu.make_async_copy`` so the NEXT tile's upload overlaps the
  CURRENT tile's traversal.  Two further wins ride on the rework:

  - **fused node attributes**: (feature, threshold, is_internal) pack into
    one power-of-two-scaled float32 code word
    ``feat * 2 * TB + thr * 2 + inter`` (``TB`` = threshold field width
    rounded up to a power of two), so each traversal level performs ONE
    two-level heap gather instead of three.  All field scales are powers
    of two, so the f32 divide/floor decode is exact below 2**24 — the
    wrapper verifies the packed range and falls back to ``"simple"``
    otherwise.
  - **block-diagonal chunk skipping**: per observation block the wrapper
    precomputes (host side) the [lo, hi) range of tree chunks whose
    segment set intersects the block's, shipped via SMEM; with rows and
    trees sorted by segment the kernel touches ~sum_u T_u * N_u work, not
    T_total * N_total, in ONE launch with no host round-trips between
    chunks.

Precision guard: node attributes round-trip through float32 one-hot einsums,
which is exact only below 2**24 — ``forest_predict*`` validate static shapes
and (when inputs are concrete) data ranges and raise instead of silently
corrupting (see tests/test_serve_path.py boundary test).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_F32_EXACT_INT = 1 << 24  # float32 has a 24-bit significand


def _validate_f32_exact(max_depth: int, d: int, **arrays) -> None:
    """Raise if a value routed through the float32 one-hot path could exceed
    the exactly-representable integer range.

    Host numpy arrays are checked with numpy (free); concrete device arrays
    are checked too, which costs a device sync — hot loops (the streamed
    serve driver) pass numpy tiles so the check never blocks dispatch.
    Tracers can't be value-checked and are skipped."""
    h = (1 << (max_depth + 1)) - 1
    if h >= _F32_EXACT_INT:
        raise ValueError(
            f"max_depth={max_depth} gives {h} heap nodes >= 2**24; node ids "
            "would corrupt in the float32 one-hot gathers"
        )
    if d >= _F32_EXACT_INT:
        raise ValueError(f"n_features={d} >= 2**24 overflows float32 gathers")
    for name, arr in arrays.items():
        if isinstance(arr, jax.core.Tracer):
            continue  # under jit/vmap tracing: shapes checked, values can't be
        if not arr.size:
            continue
        if isinstance(arr, np.ndarray):
            big = int(np.max(np.abs(arr))) >= _F32_EXACT_INT
        else:
            big = int(jnp.max(jnp.abs(arr))) >= _F32_EXACT_INT
        if big:
            raise ValueError(
                f"{name} contains values >= 2**24, not exactly representable "
                "in the float32 one-hot gathers"
            )


def _heap_split(h_pad: int) -> tuple[int, int, int]:
    """(lo_bits, n_lo, n_hi) for the two-level gather over h_pad heap slots."""
    lo_bits = max(1, h_pad.bit_length() // 2)
    n_lo = 1 << lo_bits
    n_hi = pl.cdiv(h_pad, n_lo)
    return lo_bits, n_lo, n_hi


def _pad_heap(a: jnp.ndarray, h_pad: int) -> jnp.ndarray:
    t, h = a.shape
    if h == h_pad:
        return a
    return jnp.pad(a, ((0, 0), (0, h_pad - h)))


def _two_level_gather(tab3, oh_hi, oh_lo):
    """tab3 (BT, Hhi, Hlo) f32, oh_hi (BT, BN, Hhi), oh_lo (BT, BN, Hlo)
    -> (BT, BN) gathered values."""
    rows = jnp.einsum(
        "tnh,thl->tnl", oh_hi, tab3, preferred_element_type=jnp.float32
    )
    return (rows * oh_lo).sum(-1)


def _traverse(xb, feat, thr, inter, *, max_depth, lo_bits, n_lo, n_hi, d):
    """Shared (BT, BN) heap traversal; returns final node indices."""
    bt = feat.shape[0]
    bn = xb.shape[0]
    feat3 = feat.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    thr3 = thr.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    inter3 = inter.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    xbf = xb.astype(jnp.float32)
    idx = jnp.zeros((bt, bn), jnp.int32)

    def level(_, idx):
        oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
        oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
        fe = _two_level_gather(feat3, oh_hi, oh_lo).astype(jnp.int32)
        th = _two_level_gather(thr3, oh_hi, oh_lo).astype(jnp.int32)
        it = _two_level_gather(inter3, oh_hi, oh_lo) > 0.5
        ohf = jax.nn.one_hot(jnp.clip(fe, 0, d - 1), d, dtype=jnp.float32)
        xv = jnp.einsum(
            "tnd,nd->tn", ohf, xbf, preferred_element_type=jnp.float32
        ).astype(jnp.int32)
        child = jnp.where(xv <= th, 2 * idx + 1, 2 * idx + 2)
        return jnp.where(it, child, idx)

    return jax.lax.fori_loop(0, max_depth, level, idx)


def _tree_predict_kernel(
    xb_ref, feat_ref, thr_ref, fit_ref, inter_ref, out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt = fit_ref.shape[0]
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    out_ref[...] = _two_level_gather(fit3, oh_hi, oh_lo)


def _tree_predict_agg_kernel(
    xb_ref, feat_ref, thr_ref, fit_ref, inter_ref, out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
    n_classes: int, block_trees: int, n_trees: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt, bn = idx.shape
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    leaf = _two_level_gather(fit3, oh_hi, oh_lo)  # (BT, BN)
    # mask trees past T (grid padding): their tile rows hold garbage
    j = pl.program_id(1)
    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (bt, bn), 0)
    valid = (tree_ids + j * block_trees < n_trees).astype(jnp.float32)
    if n_classes > 0:
        oh_c = jax.nn.one_hot(
            leaf.astype(jnp.int32), n_classes, dtype=jnp.float32
        )
        contrib = (oh_c * valid[..., None]).sum(0)  # (BN, C) vote counts
    else:
        contrib = (leaf * valid).sum(0)[:, None]  # (BN, 1) fit sum

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("max_depth", "block_trees", "block_obs", "interpret"),
)
def _forest_predict_impl(
    xb, feature, threshold, fit, is_internal,
    max_depth, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    grid = (pl.cdiv(t, block_trees), pl.cdiv(n, block_obs))
    kernel = functools.partial(
        _tree_predict_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (j, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec(
            (block_trees, block_obs), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(xb, feature, threshold, fit, inter)


def forest_predict(
    xb: jnp.ndarray,  # (N, d) int32
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (T, N) per-(tree, obs) leaf fits."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    return _forest_predict_impl(
        xb, feature, threshold, fit, is_internal,
        max_depth, min(block_trees, t), min(block_obs, n), interpret,
    )


def _tree_predict_agg_seg_kernel(
    xb_ref, oseg_ref, tseg_ref, feat_ref, thr_ref, fit_ref, inter_ref,
    out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
    n_classes: int, block_trees: int, n_trees: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt, bn = idx.shape
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    leaf = _two_level_gather(fit3, oh_hi, oh_lo)  # (BT, BN)
    # a (tree, obs) pair contributes iff the tree is real (grid padding) AND
    # its segment (user) id matches the observation's segment id
    j = pl.program_id(1)
    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (bt, bn), 0)
    in_range = tree_ids + j * block_trees < n_trees
    same_seg = tseg_ref[...] == oseg_ref[...]  # (BT,1) vs (1,BN) -> (BT,BN)
    valid = (in_range & same_seg).astype(jnp.float32)
    if n_classes > 0:
        oh_c = jax.nn.one_hot(
            leaf.astype(jnp.int32), n_classes, dtype=jnp.float32
        )
        contrib = (oh_c * valid[..., None]).sum(0)  # (BN, C) vote counts
    else:
        contrib = (leaf * valid).sum(0)[:, None]  # (BN, 1) fit sum

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_depth", "n_classes", "block_trees", "block_obs", "interpret"
    ),
)
def _forest_predict_agg_seg_impl(
    xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
    max_depth, n_classes, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    c_out = n_classes if n_classes > 0 else 1
    # tree tiles on the LAST grid axis (same reason as the unsegmented agg
    # kernel: consecutive steps revisit the same output block for +=)
    grid = (pl.cdiv(n, block_obs), pl.cdiv(t, block_trees))
    kernel = functools.partial(
        _tree_predict_agg_seg_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
        n_classes=n_classes, block_trees=block_trees, n_trees=t,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (j, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_obs), lambda i, j: (0, i)),
            pl.BlockSpec((block_trees, 1), lambda i, j: (j, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec((block_obs, c_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), jnp.float32),
        interpret=interpret,
    )(xb, obs_seg, tree_seg, feature, threshold, fit, inter)
    return out[:, 0] if n_classes == 0 else out


def _forest_predict_agg_segmented_simple(
    xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
    max_depth, n_classes, block_trees, block_obs, interpret,
):
    """The original segmented kernel (PR 2) — grid over (obs, tree) tiles
    with += accumulation.  Kept verbatim as the ``engine="simple"`` oracle
    and serving baseline."""
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    obs_seg = jnp.asarray(obs_seg, jnp.int32).reshape(1, n)
    tree_seg = jnp.asarray(tree_seg, jnp.int32).reshape(t, 1)
    return _forest_predict_agg_seg_impl(
        xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
        max_depth, n_classes, min(block_trees, t), min(block_obs, n),
        interpret,
    )


# ---------------------------------------------------------------------------
# Pipelined engine: fused node attributes + double-buffered DMA over chunks
# ---------------------------------------------------------------------------

def fused_threshold_base(max_threshold: int) -> int:
    """``TB``: threshold field width of the fused code word, rounded up to a
    power of two so every decode divide/floor is exact in float32."""
    return 1 << max(int(max_threshold), 1).bit_length()


def fuse_node_attrs(
    feature: np.ndarray, threshold: np.ndarray, is_internal: np.ndarray,
    tb: int,
) -> np.ndarray:
    """Pack (feature, threshold, is_internal) into one float32 code table:
    ``code = (feature * TB + threshold) * 2 + is_internal``.  Requires
    non-negative fields, ``threshold < TB``, and the packed range below
    2**24 (caller-checked via ``fused_code_limit``)."""
    code = (
        np.asarray(feature, np.int64) * (2 * tb)
        + np.asarray(threshold, np.int64) * 2
        + np.asarray(is_internal, np.int64)
    )
    return code.astype(np.float32)


def fused_code_limit(d: int, tb: int) -> int:
    """Largest code word the fused packing can produce: feature d-1,
    threshold TB-1, internal 1."""
    return (d - 1) * 2 * tb + (tb - 1) * 2 + 1


def segment_chunk_ranges(
    obs_seg: np.ndarray,  # (N,) int32, any order (sorted => tight ranges)
    tree_seg: np.ndarray,  # (T_pad,) int32, -1 = padding
    block_trees: int,
    block_obs: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Per observation block, the [lo, hi) range of tree chunks whose
    segment set intersects the block's — the kernel's fori_loop bounds.

    Always CORRECT for any ordering (the in-kernel segment mask filters
    non-matching pairs); TIGHT when rows and trees are sorted by segment,
    where it recovers the block-diagonal work bound ~sum_u T_u * N_u."""
    obs_seg = np.asarray(obs_seg, np.int64)
    tree_seg = np.asarray(tree_seg, np.int64)
    n, t_pad = len(obs_seg), len(tree_seg)
    n_chunks = t_pad // block_trees
    g = max(-(-n // block_obs), 1)
    n_segs = int(max(obs_seg.max(initial=0), tree_seg.max(initial=0))) + 1
    # membership matrices via one flat scatter each; segment -1 (padding)
    # lands in the dropped 0th column
    chunk_of = np.repeat(np.arange(n_chunks), block_trees)
    seg_in_chunk = np.zeros((n_chunks, n_segs + 1), bool)
    seg_in_chunk[chunk_of, np.clip(tree_seg, -1, n_segs - 1) + 1] = True
    block_of = np.repeat(np.arange(g), block_obs)[:n]
    seg_in_block = np.zeros((g, n_segs + 1), bool)
    seg_in_block[block_of, np.clip(obs_seg, -1, n_segs - 1) + 1] = True
    need = seg_in_block[:, 1:] @ seg_in_chunk[:, 1:].T  # (g, n_chunks)
    any_ = need.any(1)
    lo = np.where(any_, need.argmax(1), 0).astype(np.int32)
    hi = np.where(
        any_, n_chunks - need[:, ::-1].argmax(1), 0
    ).astype(np.int32)
    return lo, hi


def _tree_predict_agg_seg_pipelined_kernel(
    chunk_lo_ref, chunk_hi_ref,  # SMEM (G,) int32 fori_loop bounds
    xb_ref, oseg_ref,  # VMEM blocks
    code_hbm, fit_hbm, tseg_hbm,  # ANY/HBM, DMA'd per chunk
    out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
    n_classes: int, block_trees: int, tb2: float,
):
    i = pl.program_id(0)
    lo = chunk_lo_ref[i]
    hi = chunk_hi_ref[i]
    bn = xb_ref.shape[0]
    c_out = out_ref.shape[-1]
    xbf = xb_ref[...].astype(jnp.float32)
    osegs = oseg_ref[...]  # (1, BN)

    def body(code_s, fit_s, tseg_s, sems):
        # one DMA triple per (slot, chunk); fresh descriptors are cheap —
        # start() and wait() pair up through the per-(slot, k) semaphore
        def dma(slot, ci, k):
            src, dst = (
                (code_hbm, code_s), (fit_hbm, fit_s), (tseg_hbm, tseg_s)
            )[k]
            return pltpu.make_async_copy(
                src.at[pl.ds(ci * block_trees, block_trees)],
                dst.at[slot],
                sems.at[slot, k],
            )

        @pl.when(lo < hi)
        def _():  # warm-up: fill slot 0 before the steady-state loop
            for k in range(3):
                dma(0, lo, k).start()

        def chunk_step(step, acc):
            ci = lo + step
            cur = step % 2

            @pl.when(ci + 1 < hi)
            def _():  # overlap: next chunk uploads while this one computes
                for k in range(3):
                    dma((step + 1) % 2, ci + 1, k).start()

            for k in range(3):
                dma(cur, ci, k).wait()
            code3 = code_s[cur].reshape(block_trees, n_hi, n_lo)
            idx = jnp.zeros((block_trees, bn), jnp.int32)

            def level(_, idx):
                oh_hi = jax.nn.one_hot(
                    idx >> lo_bits, n_hi, dtype=jnp.float32
                )
                oh_lo = jax.nn.one_hot(
                    idx & (n_lo - 1), n_lo, dtype=jnp.float32
                )
                c = _two_level_gather(code3, oh_hi, oh_lo)
                # power-of-two field scales: divide/floor decode is exact
                fe = jnp.floor(c / tb2)
                rem = c - fe * tb2
                th = jnp.floor(rem * 0.5)
                it = rem - 2.0 * th
                ohf = jax.nn.one_hot(
                    jnp.clip(fe.astype(jnp.int32), 0, d - 1), d,
                    dtype=jnp.float32,
                )
                xv = jnp.einsum(
                    "tnd,nd->tn", ohf, xbf,
                    preferred_element_type=jnp.float32,
                )
                child = jnp.where(xv <= th, 2 * idx + 1, 2 * idx + 2)
                return jnp.where(it > 0.5, child, idx)

            idx = jax.lax.fori_loop(0, max_depth, level, idx)
            fit3 = fit_s[cur].reshape(block_trees, n_hi, n_lo)
            oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
            oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
            leaf = _two_level_gather(fit3, oh_hi, oh_lo)  # (BT, BN)
            # padding trees carry segment -1, which never matches a row
            valid = (tseg_s[cur] == osegs).astype(jnp.float32)
            if n_classes > 0:
                oh_c = jax.nn.one_hot(
                    leaf.astype(jnp.int32), n_classes, dtype=jnp.float32
                )
                return acc + (oh_c * valid[..., None]).sum(0)
            return acc + (leaf * valid).sum(0)[:, None]

        acc = jax.lax.fori_loop(
            0, hi - lo, chunk_step, jnp.zeros((bn, c_out), jnp.float32)
        )
        out_ref[...] = acc

    pl.run_scoped(
        body,
        pltpu.VMEM((2, block_trees, n_hi * n_lo), jnp.float32),
        pltpu.VMEM((2, block_trees, n_hi * n_lo), jnp.float32),
        pltpu.VMEM((2, block_trees, 1), jnp.int32),
        pltpu.SemaphoreType.DMA((2, 3)),
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_depth", "n_classes", "block_trees", "block_obs", "tb2",
        "interpret",
    ),
)
def _forest_predict_agg_seg_pipelined_impl(
    xb, obs_seg, code, fit, tree_seg, chunk_lo, chunk_hi,
    max_depth, n_classes, block_trees, block_obs, tb2, interpret,
):
    t_pad, h = code.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    code = _pad_heap(code, h_pad)
    fit = _pad_heap(fit, h_pad)
    c_out = n_classes if n_classes > 0 else 1
    grid = (pl.cdiv(n, block_obs),)
    kernel = functools.partial(
        _tree_predict_agg_seg_pipelined_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
        n_classes=n_classes, block_trees=block_trees, tb2=float(tb2),
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_obs, d), lambda i: (i, 0)),
            pl.BlockSpec((1, block_obs), lambda i: (0, i)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_obs, c_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), jnp.float32),
        interpret=interpret,
    )(
        chunk_lo, chunk_hi, xb, obs_seg.reshape(1, n), code, fit,
        tree_seg.reshape(t_pad, 1),
    )
    return out[:, 0] if n_classes == 0 else out


def forest_predict_agg_segmented_packed(
    xb,  # (N, d) int32
    obs_seg,  # (N,) int32
    code,  # (T_pad, H) float32 fused node attrs (fuse_node_attrs)
    fit,  # (T_pad, H) float32
    tree_seg,  # (T_pad,) int32, -1 marks padding trees
    chunk_lo,  # (ceil(N / block_obs),) int32
    chunk_hi,  # (ceil(N / block_obs),) int32
    max_depth: int,
    tb2: int,  # 2 * fused_threshold_base(...)
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Low-level pipelined entry for PRE-FUSED tree tiles (the device tile
    arena stores this layout): one launch, double-buffered DMA over tree
    chunks.  ``T_pad`` must be a positive multiple of ``block_trees`` with
    padding trees marked ``tree_seg == -1``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t_pad, _ = code.shape
    n, d = xb.shape
    if t_pad % block_trees != 0 or t_pad == 0:
        raise ValueError(
            f"T_pad={t_pad} must be a positive multiple of "
            f"block_trees={block_trees}"
        )
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    # value-check code only when it is a host array: device-resident code
    # comes from the arena, whose constructor already rejects schemas that
    # could reach 2**24 — re-reducing it here would force a device sync on
    # every serving batch and serialize the dispatch the pipeline overlaps
    arrays = {"xb": xb}
    if isinstance(code, np.ndarray):
        arrays["code"] = code
    _validate_f32_exact(max_depth, d, **arrays)
    return _forest_predict_agg_seg_pipelined_impl(
        xb, jnp.asarray(obs_seg, jnp.int32), code, fit,
        jnp.asarray(tree_seg, jnp.int32), jnp.asarray(chunk_lo, jnp.int32),
        jnp.asarray(chunk_hi, jnp.int32), max_depth, n_classes, block_trees,
        min(block_obs, n), int(tb2), interpret,
    )


def _is_concrete(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def forest_predict_agg_segmented(
    xb: jnp.ndarray,  # (N, d) int32
    obs_seg: jnp.ndarray,  # (N,) or (N, 1) int32 segment (user) id per row
    tree_seg: jnp.ndarray,  # (T,) or (T, 1) int32 segment (user) id per tree
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32 (class ids for classification)
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
    engine: str | None = None,
) -> jnp.ndarray:
    """Ragged multi-tenant serving kernel: per-row ensemble aggregation
    restricted to the trees whose segment id matches the row's.

    Trees from MANY users' forests concatenate along the T axis (ragged —
    users need not have equal tree counts) and a mixed batch of many users'
    observations concatenates along N; one launch returns, per row, the
    (N,) fit sum / (N, C) vote counts over that row's own forest only.
    Segment ids are compared as int32 inside the kernel (they never route
    through the float32 one-hot gathers), so any int32 id is safe.

    ``engine``: ``"pipelined"`` (fused-attribute double-buffered DMA, one
    launch), ``"simple"`` (the PR 2 oracle), or ``None`` to pick
    ``"pipelined"`` whenever the inputs are concrete, the node attributes
    are non-negative, and the fused code word fits below 2**24.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    obs_seg = (
        obs_seg.reshape(-1) if hasattr(obs_seg, "reshape") else obs_seg
    )
    tree_seg = (
        tree_seg.reshape(-1) if hasattr(tree_seg, "reshape") else tree_seg
    )
    if engine is None or engine == "pipelined":
        eligible = t > 0 and n > 0 and _is_concrete(
            xb, obs_seg, tree_seg, feature, threshold, fit, is_internal
        )
        if eligible:
            feat_h = np.asarray(feature)
            thr_h = np.asarray(threshold)
            tb = fused_threshold_base(int(thr_h.max(initial=0)))
            eligible = (
                int(feat_h.min(initial=0)) >= 0
                and int(thr_h.min(initial=0)) >= 0
                and fused_code_limit(d, tb) < _F32_EXACT_INT
            )
        if not eligible:
            if engine == "pipelined":
                raise ValueError(
                    "engine='pipelined' needs concrete non-negative "
                    "feature/threshold arrays whose fused code word fits "
                    "below 2**24 (and a non-empty batch)"
                )
            engine = "simple"
        else:
            code = fuse_node_attrs(
                feat_h, thr_h, np.asarray(is_internal), tb
            )
            block_trees = min(block_trees, t)
            t_pad = -(-t // block_trees) * block_trees
            tseg_h = np.asarray(tree_seg, np.int32)
            pad = t_pad - t
            if pad:
                code = np.pad(code, ((0, pad), (0, 0)))
                fit = np.pad(np.asarray(fit), ((0, pad), (0, 0)))
                tseg_h = np.pad(tseg_h, (0, pad), constant_values=-1)
            oseg_h = np.asarray(obs_seg, np.int32)
            block_obs = min(block_obs, n)
            chunk_lo, chunk_hi = segment_chunk_ranges(
                oseg_h, tseg_h, block_trees, block_obs
            )
            return forest_predict_agg_segmented_packed(
                xb, oseg_h, jnp.asarray(code), jnp.asarray(fit, jnp.float32),
                tseg_h, chunk_lo, chunk_hi, max_depth, 2 * tb,
                n_classes=n_classes, block_trees=block_trees,
                block_obs=block_obs, interpret=interpret,
            )
    if engine != "simple":
        raise ValueError(f"unknown segmented engine {engine!r}")
    return _forest_predict_agg_segmented_simple(
        xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
        max_depth, n_classes, block_trees, block_obs, interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_depth", "n_classes", "block_trees", "block_obs", "interpret"
    ),
)
def _forest_predict_agg_impl(
    xb, feature, threshold, fit, is_internal,
    max_depth, n_classes, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    c_out = n_classes if n_classes > 0 else 1
    # tree tiles on the LAST grid axis: consecutive steps revisit the same
    # output block, which is what makes the += accumulation well-defined
    grid = (pl.cdiv(n, block_obs), pl.cdiv(t, block_trees))
    kernel = functools.partial(
        _tree_predict_agg_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
        n_classes=n_classes, block_trees=block_trees, n_trees=t,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (j, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (i, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec((block_obs, c_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), jnp.float32),
        interpret=interpret,
    )(xb, feature, threshold, fit, inter)
    return out[:, 0] if n_classes == 0 else out


def forest_predict_agg(
    xb: jnp.ndarray,  # (N, d) int32
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32 (class ids for classification)
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused decode->predict serving kernel with IN-KERNEL ensemble
    aggregation across the tree-tile grid axis.

    Returns (N,) summed leaf fits when ``n_classes == 0`` (regression; divide
    by T for the ensemble mean) or (N, C) per-class vote counts otherwise —
    HBM output traffic is O(N) instead of O(T * N).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    return _forest_predict_agg_impl(
        xb, feature, threshold, fit, is_internal,
        max_depth, n_classes, min(block_trees, t), min(block_obs, n),
        interpret,
    )

"""Batched random-forest inference Pallas TPU kernel — the paper's serving
hot spot (predict-from-compressed decodes trees, then this evaluates them).

Layout: trees in heap form (node i -> children 2i+1 / 2i+2), so traversal is
pure arithmetic + gathers, no pointers.  Tiling: grid = (obs_tiles, tree_tiles);
each program holds a (BT, H) tile of tree arrays and a (BN, d) tile of
binned observations in VMEM and walks ``max_depth`` levels for all
(tree, obs) pairs at once — VPU select/gather ops, no MXU.  Trees are tiny
(H = 2^(depth+1)-1 nodes) and reused across the whole observation tile, so
the kernel is gather-throughput-bound in VMEM rather than HBM-bound: per
HBM byte of tree data we do BN gathers, which is the TPU-native answer to
the pointer-chasing CPU traversal (DESIGN.md hardware-adaptation).

Within the kernel the (tree, obs) traversal is expressed with a fori_loop
over depth; gathers use one-hot matmuls (take-along-axis lowers poorly on
TPU vector memory for small tables, one-hot contractions hit the MXU
instead — this is the standard trick for small-table gathers on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tree_predict_kernel(
    xb_ref, feat_ref, thr_ref, fit_ref, inter_ref, out_ref,
    *, max_depth: int, n_heap: int, d: int,
):
    xb = xb_ref[...]  # (BN, d) int32
    feat = feat_ref[...]  # (BT, H) int32
    thr = thr_ref[...]  # (BT, H) int32
    fit = fit_ref[...]  # (BT, H) f32
    inter = inter_ref[...]  # (BT, H) int32 (0/1)

    bt = feat.shape[0]
    bn = xb.shape[0]
    idx = jnp.zeros((bt, bn), jnp.int32)

    def level(_, idx):
        # gather per-(tree,obs) node attributes via one-hot contraction
        oh = jax.nn.one_hot(idx, n_heap, dtype=jnp.float32)  # (BT,BN,H)
        fe = jnp.einsum("tnh,th->tn", oh, feat.astype(jnp.float32)).astype(jnp.int32)
        th = jnp.einsum("tnh,th->tn", oh, thr.astype(jnp.float32)).astype(jnp.int32)
        it = jnp.einsum("tnh,th->tn", oh, inter.astype(jnp.float32)) > 0.5
        # gather observation feature values: one-hot over d
        ohf = jax.nn.one_hot(jnp.clip(fe, 0, d - 1), d, dtype=jnp.float32)
        xv = jnp.einsum("tnd,nd->tn", ohf, xb.astype(jnp.float32)).astype(jnp.int32)
        child = jnp.where(xv <= th, 2 * idx + 1, 2 * idx + 2)
        return jnp.where(it, child, idx)

    idx = jax.lax.fori_loop(0, max_depth, level, idx)
    oh = jax.nn.one_hot(idx, n_heap, dtype=jnp.float32)
    out_ref[...] = jnp.einsum("tnh,th->tn", oh, fit)


@functools.partial(
    jax.jit,
    static_argnames=("max_depth", "block_trees", "block_obs", "interpret"),
)
def forest_predict(
    xb: jnp.ndarray,  # (N, d) int32
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (T, N) per-(tree, obs) leaf fits."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, h = feature.shape
    n, d = xb.shape
    block_trees = min(block_trees, t)
    block_obs = min(block_obs, n)
    grid = (pl.cdiv(t, block_trees), pl.cdiv(n, block_obs))

    kernel = functools.partial(
        _tree_predict_kernel, max_depth=max_depth, n_heap=h, d=d
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (j, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec(
            (block_trees, block_obs), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(xb, feature, threshold, fit, is_internal.astype(jnp.int32))

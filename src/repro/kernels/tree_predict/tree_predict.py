"""Batched random-forest inference Pallas TPU kernels — the paper's serving
hot spot (predict-from-compressed decodes trees, then this evaluates them).

Layout: trees in heap form (node i -> children 2i+1 / 2i+2), so traversal is
pure arithmetic + gathers, no pointers.  Tiling: each program holds a
(BT, Hp) tile of tree arrays and a (BN, d) tile of binned observations in
VMEM and walks ``max_depth`` levels for all (tree, obs) pairs at once — VPU
select ops + MXU one-hot contractions.  Trees are tiny and reused across the
whole observation tile, so the kernel is gather-throughput-bound in VMEM
rather than HBM-bound: per HBM byte of tree data we do BN gathers.

Gathers use TWO-LEVEL one-hot contractions: a heap index over ``Hp`` nodes is
split into (hi, lo) = (idx >> lo_bits, idx & (Hlo - 1)) and gathered as
``sum_l one_hot(hi) @ tab[:, hi, :] * one_hot(lo)``.  The one-hot operands
are (BT, BN, Hhi) + (BT, BN, Hlo) ~ O(sqrt(H)) per element instead of the
(BT, BN, H) materialization of a flat one-hot — the VMEM working set stays
flat as depth grows (depth 14 => 180x smaller level scratch).

Three kernels share the traversal:

* ``forest_predict``       -> (T, N) per-(tree, obs) leaf fits;
* ``forest_predict_agg``   -> in-kernel ensemble aggregation over the
  tree-tile grid axis: (N,) fit sums (regression) or (N, C) vote counts
  (classification).  Output HBM traffic shrinks by ~T/block_trees x, and the
  host-side ensemble reduction disappears.
* ``forest_predict_agg_segmented`` -> ragged multi-tenant aggregation: trees
  and observations carry int32 segment (user) ids, and a (tree, obs) pair
  contributes only when the ids match.  Many users' forests pack into ONE
  tree axis (no per-user padding) and one kernel launch serves the whole
  mixed batch — the multi-tenant store's serving front-end
  (``repro.launch.serve_store``).

Precision guard: node attributes round-trip through float32 one-hot einsums,
which is exact only below 2**24 — ``forest_predict*`` validate static shapes
and (when inputs are concrete) data ranges and raise instead of silently
corrupting (see tests/test_serve_path.py boundary test).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_F32_EXACT_INT = 1 << 24  # float32 has a 24-bit significand


def _validate_f32_exact(max_depth: int, d: int, **arrays) -> None:
    """Raise if a value routed through the float32 one-hot path could exceed
    the exactly-representable integer range.

    Host numpy arrays are checked with numpy (free); concrete device arrays
    are checked too, which costs a device sync — hot loops (the streamed
    serve driver) pass numpy tiles so the check never blocks dispatch.
    Tracers can't be value-checked and are skipped."""
    h = (1 << (max_depth + 1)) - 1
    if h >= _F32_EXACT_INT:
        raise ValueError(
            f"max_depth={max_depth} gives {h} heap nodes >= 2**24; node ids "
            "would corrupt in the float32 one-hot gathers"
        )
    if d >= _F32_EXACT_INT:
        raise ValueError(f"n_features={d} >= 2**24 overflows float32 gathers")
    for name, arr in arrays.items():
        if isinstance(arr, jax.core.Tracer):
            continue  # under jit/vmap tracing: shapes checked, values can't be
        if not arr.size:
            continue
        if isinstance(arr, np.ndarray):
            big = int(np.max(np.abs(arr))) >= _F32_EXACT_INT
        else:
            big = int(jnp.max(jnp.abs(arr))) >= _F32_EXACT_INT
        if big:
            raise ValueError(
                f"{name} contains values >= 2**24, not exactly representable "
                "in the float32 one-hot gathers"
            )


def _heap_split(h_pad: int) -> tuple[int, int, int]:
    """(lo_bits, n_lo, n_hi) for the two-level gather over h_pad heap slots."""
    lo_bits = max(1, h_pad.bit_length() // 2)
    n_lo = 1 << lo_bits
    n_hi = pl.cdiv(h_pad, n_lo)
    return lo_bits, n_lo, n_hi


def _pad_heap(a: jnp.ndarray, h_pad: int) -> jnp.ndarray:
    t, h = a.shape
    if h == h_pad:
        return a
    return jnp.pad(a, ((0, 0), (0, h_pad - h)))


def _two_level_gather(tab3, oh_hi, oh_lo):
    """tab3 (BT, Hhi, Hlo) f32, oh_hi (BT, BN, Hhi), oh_lo (BT, BN, Hlo)
    -> (BT, BN) gathered values."""
    rows = jnp.einsum(
        "tnh,thl->tnl", oh_hi, tab3, preferred_element_type=jnp.float32
    )
    return (rows * oh_lo).sum(-1)


def _traverse(xb, feat, thr, inter, *, max_depth, lo_bits, n_lo, n_hi, d):
    """Shared (BT, BN) heap traversal; returns final node indices."""
    bt = feat.shape[0]
    bn = xb.shape[0]
    feat3 = feat.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    thr3 = thr.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    inter3 = inter.astype(jnp.float32).reshape(bt, n_hi, n_lo)
    xbf = xb.astype(jnp.float32)
    idx = jnp.zeros((bt, bn), jnp.int32)

    def level(_, idx):
        oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
        oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
        fe = _two_level_gather(feat3, oh_hi, oh_lo).astype(jnp.int32)
        th = _two_level_gather(thr3, oh_hi, oh_lo).astype(jnp.int32)
        it = _two_level_gather(inter3, oh_hi, oh_lo) > 0.5
        ohf = jax.nn.one_hot(jnp.clip(fe, 0, d - 1), d, dtype=jnp.float32)
        xv = jnp.einsum(
            "tnd,nd->tn", ohf, xbf, preferred_element_type=jnp.float32
        ).astype(jnp.int32)
        child = jnp.where(xv <= th, 2 * idx + 1, 2 * idx + 2)
        return jnp.where(it, child, idx)

    return jax.lax.fori_loop(0, max_depth, level, idx)


def _tree_predict_kernel(
    xb_ref, feat_ref, thr_ref, fit_ref, inter_ref, out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt = fit_ref.shape[0]
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    out_ref[...] = _two_level_gather(fit3, oh_hi, oh_lo)


def _tree_predict_agg_kernel(
    xb_ref, feat_ref, thr_ref, fit_ref, inter_ref, out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
    n_classes: int, block_trees: int, n_trees: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt, bn = idx.shape
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    leaf = _two_level_gather(fit3, oh_hi, oh_lo)  # (BT, BN)
    # mask trees past T (grid padding): their tile rows hold garbage
    j = pl.program_id(1)
    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (bt, bn), 0)
    valid = (tree_ids + j * block_trees < n_trees).astype(jnp.float32)
    if n_classes > 0:
        oh_c = jax.nn.one_hot(
            leaf.astype(jnp.int32), n_classes, dtype=jnp.float32
        )
        contrib = (oh_c * valid[..., None]).sum(0)  # (BN, C) vote counts
    else:
        contrib = (leaf * valid).sum(0)[:, None]  # (BN, 1) fit sum

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("max_depth", "block_trees", "block_obs", "interpret"),
)
def _forest_predict_impl(
    xb, feature, threshold, fit, is_internal,
    max_depth, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    grid = (pl.cdiv(t, block_trees), pl.cdiv(n, block_obs))
    kernel = functools.partial(
        _tree_predict_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (j, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec(
            (block_trees, block_obs), lambda i, j: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret,
    )(xb, feature, threshold, fit, inter)


def forest_predict(
    xb: jnp.ndarray,  # (N, d) int32
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (T, N) per-(tree, obs) leaf fits."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    return _forest_predict_impl(
        xb, feature, threshold, fit, is_internal,
        max_depth, min(block_trees, t), min(block_obs, n), interpret,
    )


def _tree_predict_agg_seg_kernel(
    xb_ref, oseg_ref, tseg_ref, feat_ref, thr_ref, fit_ref, inter_ref,
    out_ref,
    *, max_depth: int, lo_bits: int, n_lo: int, n_hi: int, d: int,
    n_classes: int, block_trees: int, n_trees: int,
):
    idx = _traverse(
        xb_ref[...], feat_ref[...], thr_ref[...], inter_ref[...],
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
    )
    bt, bn = idx.shape
    fit3 = fit_ref[...].reshape(bt, n_hi, n_lo)
    oh_hi = jax.nn.one_hot(idx >> lo_bits, n_hi, dtype=jnp.float32)
    oh_lo = jax.nn.one_hot(idx & (n_lo - 1), n_lo, dtype=jnp.float32)
    leaf = _two_level_gather(fit3, oh_hi, oh_lo)  # (BT, BN)
    # a (tree, obs) pair contributes iff the tree is real (grid padding) AND
    # its segment (user) id matches the observation's segment id
    j = pl.program_id(1)
    tree_ids = jax.lax.broadcasted_iota(jnp.int32, (bt, bn), 0)
    in_range = tree_ids + j * block_trees < n_trees
    same_seg = tseg_ref[...] == oseg_ref[...]  # (BT,1) vs (1,BN) -> (BT,BN)
    valid = (in_range & same_seg).astype(jnp.float32)
    if n_classes > 0:
        oh_c = jax.nn.one_hot(
            leaf.astype(jnp.int32), n_classes, dtype=jnp.float32
        )
        contrib = (oh_c * valid[..., None]).sum(0)  # (BN, C) vote counts
    else:
        contrib = (leaf * valid).sum(0)[:, None]  # (BN, 1) fit sum

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_depth", "n_classes", "block_trees", "block_obs", "interpret"
    ),
)
def _forest_predict_agg_seg_impl(
    xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
    max_depth, n_classes, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    c_out = n_classes if n_classes > 0 else 1
    # tree tiles on the LAST grid axis (same reason as the unsegmented agg
    # kernel: consecutive steps revisit the same output block for +=)
    grid = (pl.cdiv(n, block_obs), pl.cdiv(t, block_trees))
    kernel = functools.partial(
        _tree_predict_agg_seg_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
        n_classes=n_classes, block_trees=block_trees, n_trees=t,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (j, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_obs), lambda i, j: (0, i)),
            pl.BlockSpec((block_trees, 1), lambda i, j: (j, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec((block_obs, c_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), jnp.float32),
        interpret=interpret,
    )(xb, obs_seg, tree_seg, feature, threshold, fit, inter)
    return out[:, 0] if n_classes == 0 else out


def forest_predict_agg_segmented(
    xb: jnp.ndarray,  # (N, d) int32
    obs_seg: jnp.ndarray,  # (N,) or (N, 1) int32 segment (user) id per row
    tree_seg: jnp.ndarray,  # (T,) or (T, 1) int32 segment (user) id per tree
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32 (class ids for classification)
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Ragged multi-tenant serving kernel: per-row ensemble aggregation
    restricted to the trees whose segment id matches the row's.

    Trees from MANY users' forests concatenate along the T axis (ragged —
    users need not have equal tree counts) and a mixed batch of many users'
    observations concatenates along N; one launch returns, per row, the
    (N,) fit sum / (N, C) vote counts over that row's own forest only.
    Segment ids are compared as int32 inside the kernel (they never route
    through the float32 one-hot gathers), so any int32 id is safe.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    obs_seg = jnp.asarray(obs_seg, jnp.int32).reshape(1, n)
    tree_seg = jnp.asarray(tree_seg, jnp.int32).reshape(t, 1)
    return _forest_predict_agg_seg_impl(
        xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
        max_depth, n_classes, min(block_trees, t), min(block_obs, n),
        interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "max_depth", "n_classes", "block_trees", "block_obs", "interpret"
    ),
)
def _forest_predict_agg_impl(
    xb, feature, threshold, fit, is_internal,
    max_depth, n_classes, block_trees, block_obs, interpret,
):
    t, h = feature.shape
    n, d = xb.shape
    lo_bits, n_lo, n_hi = _heap_split(h)
    h_pad = n_lo * n_hi
    feature, threshold, fit, inter = (
        _pad_heap(a, h_pad)
        for a in (feature, threshold, fit, is_internal.astype(jnp.int32))
    )
    c_out = n_classes if n_classes > 0 else 1
    # tree tiles on the LAST grid axis: consecutive steps revisit the same
    # output block, which is what makes the += accumulation well-defined
    grid = (pl.cdiv(n, block_obs), pl.cdiv(t, block_trees))
    kernel = functools.partial(
        _tree_predict_agg_kernel,
        max_depth=max_depth, lo_bits=lo_bits, n_lo=n_lo, n_hi=n_hi, d=d,
        n_classes=n_classes, block_trees=block_trees, n_trees=t,
    )
    tree_spec = lambda: pl.BlockSpec((block_trees, h_pad), lambda i, j: (j, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_obs, d), lambda i, j: (i, 0)),
            tree_spec(), tree_spec(), tree_spec(), tree_spec(),
        ],
        out_specs=pl.BlockSpec((block_obs, c_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), jnp.float32),
        interpret=interpret,
    )(xb, feature, threshold, fit, inter)
    return out[:, 0] if n_classes == 0 else out


def forest_predict_agg(
    xb: jnp.ndarray,  # (N, d) int32
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32 (class ids for classification)
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
    n_classes: int = 0,
    block_trees: int = 8,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused decode->predict serving kernel with IN-KERNEL ensemble
    aggregation across the tree-tile grid axis.

    Returns (N,) summed leaf fits when ``n_classes == 0`` (regression; divide
    by T for the ensemble mean) or (N, C) per-class vote counts otherwise —
    HBM output traffic is O(N) instead of O(T * N).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    t, _ = feature.shape
    n, d = xb.shape
    _validate_f32_exact(
        max_depth, d, feature=feature, threshold=threshold, xb=xb
    )
    if n_classes > 0 and n_classes >= _F32_EXACT_INT:
        raise ValueError("n_classes >= 2**24 overflows float32 vote counts")
    return _forest_predict_agg_impl(
        xb, feature, threshold, fit, is_internal,
        max_depth, n_classes, min(block_trees, t), min(block_obs, n),
        interpret,
    )

"""Pure-jnp oracle for batched forest traversal over heap-layout trees."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def forest_predict_reference(
    xb: jnp.ndarray,  # (N, d) int32 binned observations
    feature: jnp.ndarray,  # (T, H) int32
    threshold: jnp.ndarray,  # (T, H) int32
    fit: jnp.ndarray,  # (T, H) float32 per-node scalar fit
    is_internal: jnp.ndarray,  # (T, H) bool
    max_depth: int,
) -> jnp.ndarray:
    """Returns (T, N) leaf fit per (tree, observation)."""
    n, d = xb.shape

    def one_tree(f, th, nf, inter):
        idx = jnp.zeros(n, jnp.int32)
        for _ in range(max_depth):
            fe = f[idx]
            go_left = xb[jnp.arange(n), jnp.clip(fe, 0, d - 1)] <= th[idx]
            child = jnp.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = jnp.where(inter[idx], child, idx)
        return nf[idx]

    return jax.vmap(one_tree)(feature, threshold, fit, is_internal)


def forest_predict_agg_reference(
    xb: jnp.ndarray,
    feature: jnp.ndarray,
    threshold: jnp.ndarray,
    fit: jnp.ndarray,
    is_internal: jnp.ndarray,
    max_depth: int,
    n_classes: int = 0,
) -> jnp.ndarray:
    """Ensemble-aggregated oracle: (N,) leaf-fit sums (n_classes == 0) or
    (N, C) vote counts — the reduction the fused kernel performs in-kernel."""
    per_tree = forest_predict_reference(
        xb, feature, threshold, fit, is_internal, max_depth
    )  # (T, N)
    if n_classes > 0:
        votes = jax.nn.one_hot(per_tree.astype(jnp.int32), n_classes)
        return votes.sum(0)
    return per_tree.sum(0)


def forest_predict_agg_segmented_reference(
    xb: jnp.ndarray,
    obs_seg: jnp.ndarray,  # (N,) int32 segment id per observation
    tree_seg: jnp.ndarray,  # (T,) int32 segment id per tree
    feature: jnp.ndarray,
    threshold: jnp.ndarray,
    fit: jnp.ndarray,
    is_internal: jnp.ndarray,
    max_depth: int,
    n_classes: int = 0,
) -> jnp.ndarray:
    """Ragged multi-tenant oracle: aggregate each observation over the trees
    whose segment (user) id matches its own."""
    per_tree = forest_predict_reference(
        xb, feature, threshold, fit, is_internal, max_depth
    )  # (T, N)
    mask = (
        tree_seg.reshape(-1, 1) == obs_seg.reshape(1, -1)
    ).astype(per_tree.dtype)
    if n_classes > 0:
        votes = jax.nn.one_hot(per_tree.astype(jnp.int32), n_classes)
        return (votes * mask[..., None]).sum(0)
    return (per_tree * mask).sum(0)


def forest_predict_agg_segmented_packed_reference(
    xb: jnp.ndarray,
    obs_seg: jnp.ndarray,
    code: jnp.ndarray,  # (T_pad, H) fused node attrs (see fuse_node_attrs)
    fit: jnp.ndarray,  # (T_pad, H)
    tree_seg: jnp.ndarray,  # (T_pad,), -1 marks padding trees
    max_depth: int,
    tb2: int,  # 2 * threshold field width (a power of two)
    n_classes: int = 0,
) -> jnp.ndarray:
    """Oracle for the PACKED pipelined layout: un-fuse the float32 code
    table back into (feature, threshold, is_internal) with exact integer
    arithmetic and defer to the plain segmented reference — validates the
    fused encode/decode independently of the DMA kernel."""
    code_i = code.astype(jnp.int32)  # exact: fused codes are < 2**24
    feature = code_i // tb2
    rem = code_i - feature * tb2
    threshold = rem // 2
    is_internal = (rem % 2) == 1
    return forest_predict_agg_segmented_reference(
        xb, obs_seg, tree_seg, feature, threshold, fit, is_internal,
        max_depth, n_classes=n_classes,
    )

"""jit'd wrapper: (B,S,H,hd) model layout <-> (BH,S,hd) kernel layout."""
from __future__ import annotations

import jax.numpy as jnp

from .rwkv6_scan import wkv6_scan


def wkv6(r, k, v, w, u, state, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) float32.

    Returns (y (B,S,H,hd) float32, final state)."""
    b, s, h, hd = r.shape
    fold = lambda a: a.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, hd)).reshape(b * h, hd)
    sf = state.astype(jnp.float32).reshape(b * h, hd, hd)
    pad = (-s) % chunk
    if pad:
        rf, kf, vf = (jnp.pad(a, ((0, 0), (0, pad), (0, 0))) for a in (rf, kf, vf))
        wf = jnp.pad(wf, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    y, s_final = wkv6_scan(rf, kf, vf, wf, uf, sf, chunk=chunk, interpret=interpret)
    y = y[:, :s].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return y, s_final.reshape(b, h, hd, hd)

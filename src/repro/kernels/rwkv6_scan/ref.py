"""Pure-jnp oracle for the WKV6 recurrence kernel (mirrors models.rwkv6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_reference(r, k, v, w, u, state):
    """r,k,v,w: (BH, S, hd) float32; u: (BH, hd); state: (BH, hd, hd).

    y_t = r_t . (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y (BH, S, hd), final state)."""

    def step(s, inp):
        rt, kt, vt, wt = inp  # (BH, hd)
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bi,bij->bj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state

"""Chunked WKV6 recurrence Pallas TPU kernel.

Schedule: grid = (BH, n_chunks) with the chunk axis innermost/sequential;
the (hd, hd) recurrent state lives in VMEM scratch and is carried across
chunk steps (the Pallas revisiting idiom — same as the flash kernel).  Each
step DMAs one (C, hd) tile of r/k/v/w from HBM into VMEM and runs the
C-step recurrence on-chip, so HBM traffic is O(S*hd) rather than
O(S*hd*hd) — the kernel exists to keep the state resident.

Inside a chunk the update is expressed with outer products on the VPU
(hd=64 for rwkv6-1.6b; the state fits in a handful of vregs).  A fully
matmul-form intra-chunk expansion (MXU) is possible but needs log-space
decay handling; measured against the roofline, this op is memory-bound at
hd=64 so the VPU form already saturates (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    u = u_ref[0]  # (1, hd) — broadcast row
    r = r_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]

    def step(t, state):
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)  # (1, hd)
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        kv = kt.T * vt  # (hd, hd) outer product
        yt = rt @ (state + u.T * kv)  # (1, hd)
        y_ref[0, t, :] = yt[0]
        return wt.T * state + kv

    state = jax.lax.fori_loop(0, chunk, step, s_ref[...])
    s_ref[...] = state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        sT_ref[0] = state


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def wkv6_scan(
    r: jnp.ndarray,  # (BH, S, hd) float32
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # (BH, hd)
    state: jnp.ndarray,  # (BH, hd, hd)
    chunk: int = 64,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    n_chunks = s // chunk

    kernel = functools.partial(
        _wkv6_kernel, chunk=chunk, n_chunks=n_chunks
    )
    seq_spec = pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0))
    y, s_final = pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),  # u
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),  # s0
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u[:, None, :], state)
    return y, s_final

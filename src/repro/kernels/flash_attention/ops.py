"""jit'd public wrapper: GQA layout handling around the flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bh
from .ref import mha_reference


def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, S, KV, hd)
    v: jnp.ndarray,  # (B, S, KV, hd)
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Returns (B, S, H, hd). KV heads are repeated to H (GQA)."""
    if interpret is None:
        # interpret=True lets the kernel body run on CPU for validation
        interpret = jax.default_backend() == "cpu"
    b, s, h, hd = q.shape
    kv = k.shape[2]
    n_rep = h // kv
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), n_rep, axis=1).reshape(b * h, s, hd)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), n_rep, axis=1).reshape(b * h, s, hd)
    out = flash_attention_bh(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def flash_attention_reference(q, k, v, causal=True, window=None):
    """Same signature as flash_attention, evaluated with the jnp oracle."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    n_rep = h // kv
    qr = q.transpose(0, 2, 1, 3)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), n_rep, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), n_rep, axis=1)
    return mha_reference(qr, kr, vr, causal, window).transpose(0, 2, 1, 3)

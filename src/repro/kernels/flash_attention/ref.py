"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax

_NEG_INF = -1e30


def mha_reference(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, H, T, hd) (kv heads already repeated).

    Returns (B, H, S, hd)."""
    s, t = q.shape[2], k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    scores = scores / (q.shape[-1] ** 0.5)
    idx_s = jnp.arange(s)[:, None]
    idx_t = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= idx_s + (t - s) >= idx_t  # right-aligned causal
    if window is not None:
        mask &= idx_s + (t - s) - idx_t < window
    scores = jnp.where(mask[None, None], scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32)).astype(q.dtype)

"""Blockwise (flash) causal attention Pallas TPU kernel.

Tiling: grid = (batch*q_heads, n_q_blocks, n_kv_blocks) with the kv-block
axis INNERMOST so the output block (indexed only by the first two axes) is
revisited across kv steps; running max / sum / accumulator live in VMEM
scratch, carried across the kv sweep — the standard online-softmax flash
schedule mapped onto the Pallas revisiting-grid idiom.

Block shapes default to (128, head_dim): q/k/v tiles of 128x128 keep the MXU
fed (contraction dims are multiples of 128 for the assigned archs) and the
working set (3 tiles + accumulator + stats) well under VMEM.

Causal + sliding-window masking is applied per tile; fully-masked tiles
skip the matmuls via ``pl.when`` (on-diagonal tiles pay the mask, strictly
lower tiles don't).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_k: int, n_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level reachability: q row r attends to k col c iff c <= r
    # (causal) and r - c < window; fully-masked tiles skip both matmuls
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        run &= q_start - (k_start + block_k - 1) < window

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= rows >= cols
        if window is not None:
            mask &= rows - cols < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_bh(
    q: jnp.ndarray,  # (BH, S, hd)
    k: jnp.ndarray,  # (BH, T, hd)
    v: jnp.ndarray,  # (BH, T, hd)
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, hd = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    n_q = pl.cdiv(s, block_q)
    n_k = pl.cdiv(t, block_k)
    scale = hd**-0.5

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for the uniform quantization kernel (§7 quantizer)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_reference(x, lo, step, n_levels: int, dither=None):
    """x float -> (q int32, reconstruction float32).

    q = clip(floor((x - lo)/step + dither), 0, n_levels-1)
    recon = lo + (q + 0.5) * step   (midpoint reconstruction)
    """
    d = dither if dither is not None else 0.0
    q = jnp.clip(
        jnp.floor((x.astype(jnp.float32) - lo) / step + d), 0, n_levels - 1
    ).astype(jnp.int32)
    recon = lo + (q.astype(jnp.float32) + 0.5) * step
    return q, recon


def dequantize_reference(q, lo, step):
    return lo + (q.astype(jnp.float32) + 0.5) * step

"""jit'd wrapper: quantize/dequantize arbitrary-shape tensors."""
from __future__ import annotations

import jax.numpy as jnp

from .quantize import quantize
from .ref import dequantize_reference


def quantize_tensor(x, bits: int, dither: bool = False, seed: int = 0,
                    interpret: bool | None = None):
    """x: any shape -> (q int32 same shape, recon float32, (lo, step))."""
    n_levels = 1 << bits
    flat = x.reshape(-1)
    lo = float(flat.min())
    hi = float(flat.max())
    step = max((hi - lo) / n_levels, 1e-30)
    # kernel operates on 2-D tiles
    n = flat.shape[0]
    cols = 256 if n >= 256 else n
    pad = (-n) % cols
    x2 = jnp.pad(flat, (0, pad)).reshape(-1, cols)
    q, recon = quantize(x2, lo, step, n_levels, dither, seed, interpret=interpret)
    q = q.reshape(-1)[:n].reshape(x.shape)
    recon = recon.reshape(-1)[:n].reshape(x.shape)
    return q, recon, (lo, step)


def dequantize_tensor(q, lo: float, step: float):
    return dequantize_reference(q, lo, step)

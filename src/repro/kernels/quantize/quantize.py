"""Uniform (optionally dithered) quantization Pallas TPU kernel.

This is the compute half of the paper's §7 lossy fit quantization and of
the beyond-paper tensor codec (checkpoint/gradient compression): a pure
streaming VPU op.  Tiling: 1-D grid over row blocks; each step DMAs a
(block, cols) tile HBM->VMEM, does the affine+floor+clip, writes the int
tile (and optional midpoint reconstruction for error-feedback callers).
Memory-bound by construction — the roofline target is HBM bandwidth, and
the fused recon output avoids a second pass for error feedback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, seed_ref, q_ref, recon_ref, *,
                     lo: float, step: float, n_levels: int, dither: bool):
    x = x_ref[...].astype(jnp.float32)
    val = (x - lo) / step
    if dither:
        # cheap counter-based uniform dither in [-0.5, 0.5)
        pid = pl.program_id(0)
        shape = x.shape
        idx = (
            jax.lax.broadcasted_iota(jnp.uint32, shape, 0) * shape[1]
            + jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
            + jnp.uint32(pid * shape[0] * shape[1])
            + seed_ref[0, 0].astype(jnp.uint32)
        )
        z = idx * jnp.uint32(2654435761)
        z ^= z >> 16
        z *= jnp.uint32(2246822519)
        z ^= z >> 13
        u = z.astype(jnp.float32) / jnp.float32(4294967296.0) - 0.5
        val = val + u
    q = jnp.clip(jnp.floor(val), 0, n_levels - 1)
    q_ref[...] = q.astype(jnp.int32)
    recon_ref[...] = (lo + (q + 0.5) * step).astype(recon_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("lo", "step", "n_levels", "dither", "block", "interpret"),
)
def quantize(
    x: jnp.ndarray,  # (R, C)
    lo: float,
    step: float,
    n_levels: int,
    dither: bool = False,
    seed: int = 0,
    block: int = 256,
    interpret: bool | None = None,
):
    """Returns (q int32 (R, C), recon float32 (R, C))."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    r, c = x.shape
    block = min(block, r)
    kernel = functools.partial(
        _quantize_kernel,
        lo=float(lo), step=float(step), n_levels=n_levels, dither=dither,
    )
    seed_arr = jnp.full((1, 1), seed, jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(r, block),),
        in_specs=[
            pl.BlockSpec((block, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, c), lambda i: (i, 0)),
            pl.BlockSpec((block, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), jnp.int32),
            jax.ShapeDtypeStruct((r, c), jnp.float32),
        ],
        interpret=interpret,
    )(x, seed_arr)

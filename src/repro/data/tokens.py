"""Deterministic synthetic LM token pipeline.

Production shape: the global batch is sharded across hosts (each host
generates only its slice), batches are derived PURELY from (seed, step) so
the pipeline is stateless/resumable — restart at step k reproduces the
exact stream, which the fault-tolerance tests rely on.  A small background
prefetcher overlaps host-side generation with device compute.

The synthetic distribution is a order-2 Markov chain over the vocab with a
power-law unigram prior — enough structure for a 100M model's loss to drop
visibly in a few hundred steps (examples/lm_pretrain.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _batch_rng(cfg: TokenDataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )


def synth_batch(cfg: TokenDataConfig, step: int) -> dict[str, np.ndarray]:
    """Host-local slice of the global batch for ``step``."""
    assert cfg.global_batch % cfg.n_hosts == 0
    local = cfg.global_batch // cfg.n_hosts
    rng = _batch_rng(cfg, step)
    v = cfg.vocab_size
    # power-law unigram prior ...
    base = (rng.zipf(1.3, size=(local, cfg.seq_len + 1)) - 1).astype(np.int64) % v
    # ... + copy-run bigram structure: with prob p_copy a token repeats its
    # predecessor.  Both signals are learnable within tens of steps (the
    # unigram skew almost immediately), so smoke runs show a clear loss
    # drop from ln(V), while the residual stream stays non-trivial.
    keep = rng.random((local, cfg.seq_len + 1)) > 0.5
    keep[:, 0] = True
    pos = np.where(keep, np.arange(cfg.seq_len + 1)[None, :], 0)
    src = np.maximum.accumulate(pos, axis=1)
    mixed = np.take_along_axis(base, src, axis=1)
    return {
        "tokens": mixed[:, :-1].astype(np.int32),
        "labels": mixed[:, 1:].astype(np.int32),
    }


class Prefetcher:
    """Background thread generating batches a few steps ahead."""

    def __init__(self, cfg: TokenDataConfig, start_step: int, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._next
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)

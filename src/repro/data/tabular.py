"""Synthetic tabular datasets, size-matched to the paper's Table 2 rows.

No network access in this environment, so each UCI/Kaggle dataset is
replaced by a generator that matches its (#obs, #vars, numeric/categorical
mix, task) and produces a learnable non-linear target — tree-friendly
structure so the forests (and hence the codec's empirical models) behave
like the paper's: low-depth splits concentrate on a few informative
features, deep splits become uniform.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TabularSpec:
    name: str
    n_obs: int
    n_vars: int
    task: str  # "classification" | "regression"
    n_classes: int = 2
    n_categorical: int = 0
    paper_row: str = ""  # which Table-2 row this mirrors


def make_dataset(spec: TabularSpec, seed: int = 0):
    """Returns (X (n,d) float64, y, categorical mask (d,) bool)."""
    rng = np.random.default_rng(seed)
    n, d = spec.n_obs, spec.n_vars
    n_cat = min(spec.n_categorical, d)
    x = rng.normal(size=(n, d))
    # heavy-tailed + correlated columns, like real tabular data
    mix = rng.normal(size=(d, d)) * 0.3 + np.eye(d)
    x = x @ mix
    categorical = np.zeros(d, dtype=bool)
    for j in range(n_cat):
        k = int(rng.integers(3, 12))
        x[:, j] = rng.integers(0, k, size=n)
        categorical[j] = True
    # non-linear target over a sparse set of informative features
    n_inf = max(2, d // 4)
    inf = rng.choice(d, size=n_inf, replace=False)
    coef = rng.normal(size=n_inf) * 2.0
    signal = np.zeros(n)
    for c, j in zip(coef, inf):
        xj = x[:, j]
        signal += c * np.where(xj > np.median(xj), 1.0, -1.0) * np.abs(xj) ** 0.5
    signal += 0.5 * np.sin(3 * x[:, inf[0]]) * x[:, inf[-1]]
    noise = rng.normal(size=n) * signal.std() * 0.3
    y_cont = signal + noise
    if spec.task == "regression":
        return x, y_cont.astype(np.float64), categorical
    if spec.n_classes == 2:
        y = (y_cont > np.median(y_cont)).astype(np.int64)
    else:
        qs = np.quantile(y_cont, np.linspace(0, 1, spec.n_classes + 1)[1:-1])
        y = np.searchsorted(qs, y_cont).astype(np.int64)
    return x, y, categorical


# Table-2-matched specs (scaled_obs: CPU-budget row used by default in the
# benchmarks; the full paper sizes are kept for --full runs).
TABLE2_SPECS: list[TabularSpec] = [
    TabularSpec("iris", 150, 4, "classification", 3, 0, "Iris* (3 class)"),
    TabularSpec("wages", 534, 11, "classification", 2, 3, "Wages*"),
    TabularSpec("airfoil_reg", 1503, 5, "regression", paper_row="Airfoil+"),
    TabularSpec("airfoil_cls", 1503, 5, "classification", 2, 0, "Airfoil*"),
    TabularSpec("bike_reg", 10886, 11, "regression", n_categorical=4, paper_row="Bike Sharing+"),
    TabularSpec("naval_reg", 11934, 16, "regression", paper_row="Naval Plants+"),
    TabularSpec("naval_cls", 11934, 16, "classification", 2, 0, "Naval Plants*"),
    TabularSpec("shuttle", 14500, 9, "classification", 7, 0, "Shuttle*"),
    TabularSpec("forests", 15120, 55, "classification", 7, 10, "Forests*"),
    TabularSpec("adults", 48842, 14, "classification", 2, 7, "Adults*"),
    TabularSpec("liberty_reg", 50999, 32, "regression", n_categorical=16, paper_row="Liberty+"),
    TabularSpec("liberty_cls", 50999, 32, "classification", 2, 16, "Liberty*"),
    TabularSpec("otto", 61878, 94, "classification", 9, 0, "Otto*"),
]


def spec_by_name(name: str) -> TabularSpec:
    for s in TABLE2_SPECS:
        if s.name == name:
            return s
    raise KeyError(name)


def scaled(spec: TabularSpec, max_obs: int) -> TabularSpec:
    """CPU-budget copy of a spec (same vars/task, capped #obs)."""
    return TabularSpec(
        spec.name,
        min(spec.n_obs, max_obs),
        spec.n_vars,
        spec.task,
        spec.n_classes,
        spec.n_categorical,
        spec.paper_row,
    )

"""Feature binning — the HBM-friendly front door of the TPU-native CART.

The paper's trees split on raw observation values; on TPU we pre-quantize
every numerical feature into <=256 quantile bins (LightGBM-style histogram
CART).  This is the hardware adaptation recorded in DESIGN.md: it turns the
split-value alphabet finite *by construction* — which §3.2.2 observes is
effectively true for big data anyway — and makes split search a dense
fixed-shape histogram reduction.

Categorical features use their category id as the bin id (ordinal encoding;
see DESIGN.md deviations).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Binner:
    bin_edges: np.ndarray  # (d, n_bins - 1) float64 upper edges (inf-padded)
    n_bins_per_feature: np.ndarray  # (d,) actual alphabet size
    categorical: np.ndarray  # (d,) bool

    @property
    def n_features(self) -> int:
        return len(self.n_bins_per_feature)

    def transform(self, x: np.ndarray) -> np.ndarray:
        """(n, d) raw -> (n, d) int32 bin ids."""
        n, d = x.shape
        out = np.empty((n, d), dtype=np.int32)
        for j in range(d):
            if self.categorical[j]:
                out[:, j] = np.clip(
                    x[:, j].astype(np.int64), 0, self.n_bins_per_feature[j] - 1
                )
            else:
                out[:, j] = np.searchsorted(
                    self.bin_edges[j], x[:, j], side="left"
                )
        return out


def fit_binner(
    x: np.ndarray,
    n_bins: int = 64,
    categorical: np.ndarray | None = None,
) -> Binner:
    n, d = x.shape
    if categorical is None:
        categorical = np.zeros(d, dtype=bool)
    edges = np.full((d, n_bins - 1), np.inf, dtype=np.float64)
    alphabet = np.zeros(d, dtype=np.int32)
    for j in range(d):
        if categorical[j]:
            alphabet[j] = int(x[:, j].max()) + 1
            continue
        qs = np.quantile(x[:, j], np.linspace(0, 1, n_bins + 1)[1:-1])
        uniq = np.unique(qs)
        edges[j, : len(uniq)] = uniq
        alphabet[j] = len(uniq) + 1
    return Binner(edges, alphabet, categorical)

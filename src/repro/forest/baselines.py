"""The paper's two reference compression schemes (§6).

* ``standard_compress`` — serialize the full forest object (every attribute,
  64-bit numerics) and gzip-level deflate it.  Stands in for Matlab's
  ``compact(tree)`` + gzip.
* ``light_compress`` — keep ONLY what prediction needs (structure, splits,
  fits — the three attributes of §3), as tightly typed numpy arrays, then
  deflate.  This is the paper's apples-to-apples reference.

Both return real serialized byte sizes; ``light_report`` also reports the
paper's Table-1 buckets for the light scheme.
"""
from __future__ import annotations

import io
import pickle
import zlib

import numpy as np

from ..core.tree import Forest


def standard_compress(forest: Forest) -> bytes:
    """Full-fidelity pickle (64-bit everything, all attributes) + deflate."""
    blob = pickle.dumps(
        {
            "trees": [
                {
                    "feature": t.feature.astype(np.int64),
                    "threshold": t.threshold.astype(np.float64),
                    "children_left": t.children_left.astype(np.int64),
                    "children_right": t.children_right.astype(np.int64),
                    "node_fit": t.node_fit.astype(np.float64),
                    # the "unnecessary-for-prediction" attributes a standard
                    # toolkit serializes (per-node counts, impurities, ids)
                    "node_id": np.arange(t.n_nodes, dtype=np.int64),
                    "depth": t.depths().astype(np.int64),
                    "parent": t.parents().astype(np.int64),
                }
                for t in forest.trees
            ],
            "fit_values": forest.fit_values.astype(np.float64),
            "meta": forest.meta,
        },
        protocol=4,
    )
    return zlib.compress(blob, level=9)


def _light_blob(forest: Forest) -> dict[str, bytes]:
    """Minimal typed arrays per component (shared tightly-packed layout)."""

    def cat(arrs, dtype):
        return (
            np.concatenate(arrs).astype(dtype).tobytes() if arrs else b""
        )

    trees = forest.trees
    n_nodes = np.array([t.n_nodes for t in trees], np.int32)
    structure = cat([t.children_left for t in trees], np.int32) + cat(
        [t.children_right for t in trees], np.int32
    ) + n_nodes.tobytes()
    names = cat([t.feature for t in trees], np.int8 if forest.meta.n_features < 128 else np.int16)
    splits = cat([t.threshold for t in trees], np.int16)
    if forest.meta.task == "classification":
        fits = cat([t.node_fit for t in trees], np.int8 if forest.meta.n_classes < 128 else np.int32)
    else:
        # 64-bit orthodox losslessness, as in the paper's experiments
        fits = cat(
            [forest.fit_values[t.node_fit.astype(np.int64)] for t in trees],
            np.float64,
        )
    return {
        "structure": structure,
        "var_names": names,
        "split_values": splits,
        "fits": fits,
    }


def light_compress(forest: Forest) -> bytes:
    blobs = _light_blob(forest)
    out = io.BytesIO()
    for k in ("structure", "var_names", "split_values", "fits"):
        z = zlib.compress(blobs[k], level=9)
        out.write(len(z).to_bytes(4, "little"))
        out.write(z)
    return out.getvalue()


def light_report(forest: Forest) -> dict[str, int]:
    blobs = _light_blob(forest)
    rep = {
        k: len(zlib.compress(v, level=9)) for k, v in blobs.items()
    }
    rep["total"] = sum(rep.values())
    return rep

"""repro.forest — the random-forest substrate the paper assumes
(Matlab treeBagger stand-in), rebuilt TPU-natively in JAX."""

from .baselines import light_compress, light_report, standard_compress
from .binning import Binner, fit_binner
from .cart import CartConfig, grow_tree
from .forest import (
    ForestModel,
    per_tree_predictions,
    predict_forest,
    to_compact_forest,
    train_forest,
)

__all__ = [
    "Binner",
    "CartConfig",
    "ForestModel",
    "fit_binner",
    "grow_tree",
    "light_compress",
    "light_report",
    "per_tree_predictions",
    "predict_forest",
    "standard_compress",
    "to_compact_forest",
    "train_forest",
]

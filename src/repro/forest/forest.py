"""Random forest on top of the histogram CART grower.

``train_forest`` vmaps :func:`repro.forest.cart.grow_tree` over bootstrap
weights + PRNG keys (trees are i.i.d. given the data — the exact premise the
paper's codec exploits), in memory-bounded chunks.  ``to_compact_forest``
converts heap arrays to the codec's preorder compact trees.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tree import Forest, ForestMeta, Tree
from .binning import Binner
from .cart import CartConfig, grow_tree


@dataclass
class ForestModel:
    """Device-side forest: stacked heap arrays."""

    feature: np.ndarray  # (T, H) int32
    threshold: np.ndarray  # (T, H) int32
    node_fit: np.ndarray  # (T, H, C) float32
    is_internal: np.ndarray  # (T, H) bool
    node_count: np.ndarray  # (T, H) float32
    cfg: CartConfig
    binner: Binner
    n_train_obs: int

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]


def _bootstrap_weights(key, n_trees: int, n: int) -> jnp.ndarray:
    """Integer bootstrap counts per tree: n draws with replacement."""

    def one(k):
        idx = jax.random.randint(k, (n,), 0, n)
        return jnp.zeros(n, jnp.float32).at[idx].add(1.0)

    return jax.vmap(one)(jax.random.split(key, n_trees))


def train_forest(
    x_raw: np.ndarray,
    y: np.ndarray,
    binner: Binner,
    n_trees: int = 100,
    max_depth: int = 8,
    mtry: int = 0,
    min_samples_leaf: int = 1,
    task: str = "classification",
    n_classes: int = 2,
    seed: int = 0,
    chunk: int = 16,
) -> ForestModel:
    n, d = x_raw.shape
    xb = jnp.asarray(binner.transform(x_raw))
    n_bins = int(binner.n_bins_per_feature.max())
    if mtry <= 0:
        mtry = max(1, int(np.sqrt(d)) if task == "classification" else d // 3)
    cfg = CartConfig(
        n_features=d,
        n_bins=n_bins,
        max_depth=max_depth,
        mtry=mtry,
        min_samples_leaf=min_samples_leaf,
        task=task,
        n_classes=n_classes,
    )
    if task == "classification":
        y_enc = jax.nn.one_hot(jnp.asarray(y, jnp.int32), n_classes)
    else:
        yj = jnp.asarray(y, jnp.float32)
        y_enc = jnp.stack([yj, yj**2], axis=-1)

    key = jax.random.PRNGKey(seed)
    kw, kt = jax.random.split(key)
    weights = _bootstrap_weights(kw, n_trees, n)
    tkeys = jax.random.split(kt, n_trees)

    grow = jax.vmap(grow_tree, in_axes=(None, None, 0, 0, None))
    outs = []
    for s in range(0, n_trees, chunk):
        e = min(s + chunk, n_trees)
        outs.append(
            jax.tree.map(
                np.asarray,
                grow(xb, y_enc, weights[s:e], tkeys[s:e], cfg),
            )
        )
    feature, threshold, node_fit, is_internal, node_count = (
        np.concatenate([o[i] for o in outs], axis=0) for i in range(5)
    )
    return ForestModel(
        feature, threshold, node_fit, is_internal, node_count, cfg, binner, n
    )


def predict_forest(model: ForestModel, x_raw: np.ndarray) -> np.ndarray:
    """Batched heap traversal (pure JAX; the Pallas tree_predict kernel is
    the compact-tree twin used at serving time)."""
    xb = jnp.asarray(model.binner.transform(x_raw))
    feat = jnp.asarray(model.feature)
    thr = jnp.asarray(model.threshold)
    fit = jnp.asarray(model.node_fit)
    internal = jnp.asarray(model.is_internal)
    n = xb.shape[0]
    t = model.n_trees

    def tree_pred(f, th, nfit, inter):
        idx = jnp.zeros(n, jnp.int32)
        for _ in range(model.cfg.max_depth):
            fe = f[idx]
            go_left = xb[jnp.arange(n), jnp.clip(fe, 0, xb.shape[1] - 1)] <= th[idx]
            child = jnp.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = jnp.where(inter[idx], child, idx)
        return nfit[idx]  # (n, C)

    preds = jax.vmap(tree_pred)(feat, thr, fit, internal)  # (T, n, C)
    if model.cfg.task == "classification":
        votes = preds.argmax(-1)  # (T, n) per-tree class
        onehot = jax.nn.one_hot(votes, model.cfg.n_classes).sum(0)
        return np.asarray(onehot.argmax(-1))
    return np.asarray(preds[..., 0].mean(0))


def per_tree_predictions(model: ForestModel, x_raw: np.ndarray) -> np.ndarray:
    """(T, n) per-tree predictions — used by §7's sigma^2 estimator."""
    xb = jnp.asarray(model.binner.transform(x_raw))
    n = xb.shape[0]

    def tree_pred(f, th, nfit, inter):
        idx = jnp.zeros(n, jnp.int32)
        for _ in range(model.cfg.max_depth):
            fe = f[idx]
            go_left = xb[jnp.arange(n), jnp.clip(fe, 0, xb.shape[1] - 1)] <= th[idx]
            child = jnp.where(go_left, 2 * idx + 1, 2 * idx + 2)
            idx = jnp.where(inter[idx], child, idx)
        return nfit[idx]

    preds = jax.vmap(tree_pred)(
        jnp.asarray(model.feature),
        jnp.asarray(model.threshold),
        jnp.asarray(model.node_fit),
        jnp.asarray(model.is_internal),
    )
    if model.cfg.task == "classification":
        return np.asarray(preds.argmax(-1))
    return np.asarray(preds[..., 0])


def to_compact_forest(model: ForestModel) -> Forest:
    """Heap arrays -> preorder compact trees + forest-level fit dictionary
    (regression fits become indices into a distinct-64-bit-value table,
    mirroring the paper's fit dictionaries)."""
    cfg = model.cfg
    trees_raw = []
    all_fits = []
    for t in range(model.n_trees):
        feature, threshold, fit, internal = (
            model.feature[t],
            model.threshold[t],
            model.node_fit[t],
            model.is_internal[t],
        )
        # iterative preorder over the live heap nodes
        compact_of = {}
        seq = []
        st = [0]
        while st:
            i = st.pop()
            me = len(seq)
            seq.append(i)
            compact_of[i] = me
            if internal[i]:
                st.append(2 * i + 2)  # right pushed first -> left popped first
                st.append(2 * i + 1)
        n_nodes = len(seq)
        cf = np.full(n_nodes, -1, np.int32)
        ct = np.full(n_nodes, -1, np.int32)
        cl = np.full(n_nodes, -1, np.int32)
        cr = np.full(n_nodes, -1, np.int32)
        cfit_raw = np.zeros(n_nodes, np.float64)
        for me, i in enumerate(seq):
            if internal[i]:
                cf[me] = feature[i]
                ct[me] = threshold[i]
                cl[me] = compact_of[2 * i + 1]
                cr[me] = compact_of[2 * i + 2]
            if cfg.task == "classification":
                cfit_raw[me] = float(np.argmax(fit[i]))
            else:
                cfit_raw[me] = float(fit[i][0])
        trees_raw.append((cf, ct, cl, cr, cfit_raw))
        all_fits.append(cfit_raw)

    meta = ForestMeta(
        n_features=cfg.n_features,
        task=cfg.task,
        n_classes=cfg.n_classes,
        n_bins_per_feature=model.binner.n_bins_per_feature,
        bin_edges=model.binner.bin_edges,
        n_train_obs=model.n_train_obs,
        categorical=model.binner.categorical,
    )
    if cfg.task == "classification":
        trees = [
            Tree(cf, ct, cl, cr, cfit.astype(np.int64))
            for cf, ct, cl, cr, cfit in trees_raw
        ]
        return Forest(trees=trees, meta=meta)
    # regression: global distinct fit-value dictionary
    concat = np.concatenate(all_fits)
    fit_values, inv = np.unique(concat, return_inverse=True)
    trees = []
    off = 0
    for cf, ct, cl, cr, cfit in trees_raw:
        k = len(cfit)
        trees.append(Tree(cf, ct, cl, cr, inv[off : off + k].astype(np.int64)))
        off += k
    return Forest(trees=trees, meta=meta, fit_values=fit_values)

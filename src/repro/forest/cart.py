"""Histogram CART in pure JAX — level-wise growth over fixed-shape heap
arrays (the TPU adaptation of greedy recursive partitioning; see DESIGN.md).

Every tree is a perfect-heap layout of ``2^(max_depth+1) - 1`` slots:
node ``i`` has children ``2i+1`` / ``2i+2``.  Growth is level-synchronous:
one dense histogram + argmax per level, for all of the level's nodes at
once.  All shapes are static, so the whole forest is a single
``vmap(grow_tree)`` program — no pointer chasing, no recursion, no host
round-trips during growth.

Semantics vs classical CART: splits are chosen over the pre-binned feature
values (<=256 bins/feature), impurity is Gini (classification) or variance
(regression), ``mtry`` features are drawn per NODE as in Breiman's random
forest, bootstrap resampling is expressed as integer sample weights.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e30


@dataclass(frozen=True)
class CartConfig:
    n_features: int
    n_bins: int  # max bins over features (histogram width)
    max_depth: int = 8
    mtry: int = 0  # 0 => d/3 (reg) or sqrt(d) (cls), set in forest.py
    min_samples_leaf: int = 1
    task: str = "classification"  # or "regression"
    n_classes: int = 2

    @property
    def n_heap(self) -> int:
        return (1 << (self.max_depth + 1)) - 1


def _node_stats(stats_flat, cfg: CartConfig, n_nodes: int):
    """stats_flat: (n_nodes*d*B, C_stats) -> (n_nodes, d, B, C_stats)."""
    return stats_flat.reshape(n_nodes, cfg.n_features, cfg.n_bins, -1)


@partial(jax.jit, static_argnames=("cfg",))
def grow_tree(xb: jnp.ndarray, y_enc: jnp.ndarray, w: jnp.ndarray,
              key: jax.Array, cfg: CartConfig):
    """Grow one tree.

    xb:    (n, d) int32 bin ids
    y_enc: (n, C) float32 — one-hot classes, or [y, y^2] for regression
    w:     (n,)  float32 bootstrap weights (integer counts)
    key:   PRNG key for per-node feature subsampling

    Returns heap arrays:
      feature   (H,) int32   split feature, -1 where leaf/dead
      threshold (H,) int32   split bin (go left iff bin <= threshold)
      node_fit  (H, C) float32  per-node fitted value/class scores
      is_internal (H,) bool
      node_count (H,) float32  (diagnostics / min-leaf accounting)
    """
    n, d = xb.shape
    b = cfg.n_bins
    c = y_enc.shape[1]
    h = cfg.n_heap

    feature = jnp.full(h, -1, jnp.int32)
    threshold = jnp.full(h, -1, jnp.int32)
    node_fit = jnp.zeros((h, c), jnp.float32)
    is_internal = jnp.zeros(h, bool)
    node_count = jnp.zeros(h, jnp.float32)

    # per-sample state: current heap position; -2 once settled in a leaf
    pos = jnp.zeros(n, jnp.int32)
    wy = w[:, None] * y_enc  # (n, C)

    for level in range(cfg.max_depth + 1):
        lo = (1 << level) - 1
        n_nodes = 1 << level
        rel = pos - lo
        active = (rel >= 0) & (rel < n_nodes)
        relc = jnp.clip(rel, 0, n_nodes - 1)

        # ---- histograms: (n_nodes, d, B) counts and (.., C) sums ----------
        base = relc * (d * b)
        idx = base[:, None] + jnp.arange(d)[None, :] * b + xb  # (n, d)
        wmask = jnp.where(active, w, 0.0)
        cnt = jnp.zeros(n_nodes * d * b, jnp.float32).at[idx.reshape(-1)].add(
            jnp.broadcast_to(wmask[:, None], (n, d)).reshape(-1)
        ).reshape(n_nodes, d, b)
        ysum = (
            jnp.zeros((n_nodes * d * b, c), jnp.float32)
            .at[idx.reshape(-1)]
            .add(
                jnp.broadcast_to(
                    jnp.where(active[:, None], wy, 0.0)[:, None, :], (n, d, c)
                ).reshape(-1, c)
            )
            .reshape(n_nodes, d, b, c)
        )

        # ---- node totals & fits -------------------------------------------
        cnt_node = cnt[:, 0, :].sum(-1)  # (n_nodes,)
        ysum_node = ysum[:, 0, :, :].sum(-2)  # (n_nodes, C)
        fit = ysum_node / jnp.maximum(cnt_node, 1.0)[:, None]

        # ---- split scores ---------------------------------------------------
        cl = jnp.cumsum(cnt, axis=-1)  # (n_nodes, d, B) left count at bin<=t
        yl = jnp.cumsum(ysum, axis=-2)  # (n_nodes, d, B, C)
        cr = cnt_node[:, None, None] - cl
        yr = ysum_node[:, None, None, :] - yl
        if cfg.task == "regression":
            # y_enc = [y, y^2]; gain = SSE reduction = s1L^2/nL + s1R^2/nR - s1^2/n
            s1l, s1r = yl[..., 0], yr[..., 0]
            score = s1l**2 / jnp.maximum(cl, 1e-9) + s1r**2 / jnp.maximum(
                cr, 1e-9
            )
            parent = (ysum_node[:, 0] ** 2 / jnp.maximum(cnt_node, 1e-9))[
                :, None, None
            ]
        else:
            # Gini gain ∝ sum_c nLc^2/nL + nRc^2/nR - nc^2/n
            score = (yl**2).sum(-1) / jnp.maximum(cl, 1e-9) + (yr**2).sum(
                -1
            ) / jnp.maximum(cr, 1e-9)
            parent = ((ysum_node**2).sum(-1) / jnp.maximum(cnt_node, 1e-9))[
                :, None, None
            ]
        gain = score - parent  # (n_nodes, d, B)

        valid = (cl >= cfg.min_samples_leaf) & (cr >= cfg.min_samples_leaf)
        # per-node mtry feature draw (exactly mtry of d via top-k of uniforms)
        key, sub = jax.random.split(key)
        scores_f = jax.random.uniform(sub, (n_nodes, d))
        ranks = jnp.argsort(jnp.argsort(scores_f, axis=1), axis=1)
        fmask = ranks < max(cfg.mtry, 1)  # (n_nodes, d)
        gain = jnp.where(valid & fmask[:, :, None], gain, _NEG)

        flat = gain.reshape(n_nodes, d * b)
        best = jnp.argmax(flat, axis=-1)
        best_gain = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
        best_f = (best // b).astype(jnp.int32)
        best_t = (best % b).astype(jnp.int32)

        can_split = (
            (best_gain > 1e-7)
            & (cnt_node >= 2 * cfg.min_samples_leaf)
            & (level < cfg.max_depth)
        )

        sl = slice(lo, lo + n_nodes)
        feature = feature.at[sl].set(jnp.where(can_split, best_f, -1))
        threshold = threshold.at[sl].set(jnp.where(can_split, best_t, -1))
        node_fit = node_fit.at[sl].set(fit)
        is_internal = is_internal.at[sl].set(can_split & (cnt_node > 0))
        node_count = node_count.at[sl].set(cnt_node)

        # ---- route samples ---------------------------------------------------
        nf = best_f[relc]
        nt = best_t[relc]
        split_here = can_split[relc] & active
        go_left = xb[jnp.arange(n), jnp.clip(nf, 0, d - 1)] <= nt
        child = jnp.where(go_left, 2 * pos + 1, 2 * pos + 2)
        pos = jnp.where(split_here, child, jnp.where(active, -2, pos))

    return feature, threshold, node_fit, is_internal, node_count


def heap_children(h: int):
    i = np.arange(h)
    left = 2 * i + 1
    right = 2 * i + 2
    left[left >= h] = -1
    right[right >= h] = -1
    return left, right

"""repro.checkpoint — atomic, resumable, optionally entropy-coded."""

from .manager import (
    CheckpointConfig,
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointConfig",
    "CheckpointManager",
    "latest_step",
    "load_checkpoint",
    "save_checkpoint",
]

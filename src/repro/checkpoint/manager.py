"""Checkpoint manager: atomic save, auto-resume, elastic re-shard,
entropy-coded payloads (core.tensor_codec).

Layout:   <dir>/step_<k>/          one directory per step
            manifest.json          pytree structure + dtypes + pspecs
            state.npz | state.ctz  raw npz or entropy-coded payload
            COMMIT                 written LAST -> crash-safe marker

Guarantees exercised by tests:
  * a save interrupted anywhere leaves no COMMIT -> restore picks the
    previous step (atomicity),
  * restore onto a different mesh shape re-shards via device_put with the
    target sharding (elastic scaling),
  * entropy-coded checkpoints round-trip bit-exactly (lossless mode).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from dataclasses import dataclass
from pathlib import Path

import jax
import numpy as np

from ..core.tensor_codec import (
    CompressedTensors,
    compress_tensors,
    decompress_tensors,
    flatten_pytree,
    unflatten_pytree,
)


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    codec: str | None = None  # None | "lossless" | "q8" .. "q12"


def _codec_bits(codec: str | None) -> int | None:
    if codec is None or codec == "lossless":
        return None
    assert codec.startswith("q"), codec
    return int(codec[1:])


def save_checkpoint(directory, step: int, state, codec: str | None = None):
    """Atomic: write to tmp dir, fsync payload, COMMIT marker, rename."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory))
    try:
        host_state = jax.tree.map(np.asarray, state)
        flat = flatten_pytree(host_state)
        manifest = {
            "step": step,
            "codec": codec,
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if codec is None:
            np.savez(tmp / "state.npz", **flat)
        else:
            comp = compress_tensors(flat, bits=_codec_bits(codec))
            (tmp / "state.ctz").write_bytes(comp.to_bytes())
        with open(tmp / "COMMIT", "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.name.startswith("step_") and (p / "COMMIT").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory, step: int | None = None, shardings=None):
    """Load (state pytree, step). shardings: optional pytree of
    NamedSharding to place leaves onto (elastic re-shard path)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if manifest["codec"] is None:
        with np.load(d / "state.npz") as z:
            flat = {k: z[k] for k in z.files}
    else:
        comp = CompressedTensors.from_bytes((d / "state.ctz").read_bytes())
        flat = decompress_tensors(comp)
    state = unflatten_pytree(flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda leaf, sh: jax.device_put(leaf, sh), state, shardings
        )
    return state, step


class CheckpointManager:
    """Rolling checkpoints + auto-resume."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = Path(cfg.directory)

    def save(self, step: int, state):
        path = save_checkpoint(self.dir, step, state, self.cfg.codec)
        self._gc()
        return path

    def restore_or_none(self, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return load_checkpoint(self.dir, step, shardings)

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.iterdir()
            if p.name.startswith("step_") and (p / "COMMIT").exists()
        )
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        # drop orphaned tmp dirs from crashed saves
        for p in self.dir.glob(".tmp_ckpt_*"):
            shutil.rmtree(p, ignore_errors=True)

"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries and KV are projected through low-rank latents; the decode cache
stores only the compressed ``c_kv`` (kv_lora_rank) plus the shared RoPE key
(qk_rope_dim) per token — the whole point of MLA: a 512+64-wide cache versus
GQA's n_kv_heads*head_dim.  Decode uses the W_uk-absorption trick so scores
are computed directly in latent space.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, rms_norm
from .sharding import ax

_NEG_INF = -1e30


def init_mla(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    qk_n, qk_r, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)

    def lin(k, i, o):
        return (jax.random.normal(k, (i, o)) * i**-0.5).astype(dtype)

    return {
        "w_dq": lin(ks[0], d, qr),
        "q_norm": jnp.ones((qr,), dtype),
        "w_uq": lin(ks[1], qr, h * (qk_n + qk_r)),
        "w_dkv": lin(ks[2], d, kvr),
        "kv_norm": jnp.ones((kvr,), dtype),
        "w_kr": lin(ks[3], d, qk_r),
        "w_uk": lin(ks[4], kvr, h * qk_n),
        "w_uv": lin(ks[5], kvr, h * vh),
        "wo": lin(ks[6], h * vh, d),
    }


def _queries(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_n, qk_r = cfg.qk_nope_dim, cfg.qk_rope_dim
    c_q = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rq->bsq", c_q, p["w_uq"]).reshape(b, s, h, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg: ModelConfig, x, positions):
    c_kv = rms_norm(
        jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"], cfg.rms_eps
    )
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])  # shared across heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _mla_attend(p, cfg: ModelConfig, x, positions):
    """Shared train/prefill body; returns (out, c_kv, k_rope)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_n, qk_r, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rq->bsq", c_kv, p["w_uk"]).reshape(b, s, h, qk_n)
    v = jnp.einsum("bsr,rq->bsq", c_kv, p["w_uv"]).reshape(b, s, h, vh)
    q_nope = ax(q_nope, "batch", None, "heads", None)
    k_nope = ax(k_nope, "batch", None, "heads", None)
    v = ax(v, "batch", None, "heads", None)
    scale = (qk_n + qk_r) ** -0.5
    # MLA goes chunked already at 4k: with 128 heads (8 per device) the
    # dense f32 scores are (B,8,S,S) = 17 GiB/device at train_4k — the
    # dominant memory-roofline site of the deepseek-v3 cell — while the
    # chunked carry is only (2,B,8,C,hd).  (GQA archs with 1 local head
    # keep the dense path at 4k; see attention.BLOCKWISE_THRESHOLD.)
    if s >= 4096:
        # chunked path: fold the shared rope key into per-head keys so the
        # online-softmax kernel sees one (q, k, v) triple
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, qk_r))],
            axis=-1,
        )
        from .blockwise import chunked_attention

        out = chunked_attention(q_full, k_full, v, causal=True, scale=scale)
        out = out.reshape(b, s, -1)
    else:
        scores = (
            jnp.einsum("bshq,bthq->bhst", q_nope, k_nope)
            + jnp.einsum("bshq,btq->bhst", q_rope, k_rope)
        ) * scale
        idx = jnp.arange(s)
        mask = (idx[:, None] >= idx[None, :])[None, None]
        w = jax.nn.softmax(
            jnp.where(mask, scores.astype(jnp.float32), _NEG_INF), axis=-1
        ).astype(x.dtype)
        out = jnp.einsum("bhst,bthv->bshv", w, v).reshape(b, s, -1)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"]), c_kv, k_rope


def mla_train(p, cfg: ModelConfig, x, positions):
    """Full-sequence causal MLA (train / prefill): explicit k/v expansion."""
    out, _, _ = _mla_attend(p, cfg, x, positions)
    return out


def mla_prefill(p, cfg: ModelConfig, x, positions, max_len: int):
    """Full-sequence MLA returning the latent decode cache (c_kv, k_rope)."""
    s = x.shape[1]
    out, c_kv, k_rope = _mla_attend(p, cfg, x, positions)
    if max_len > s:
        c_kv = jnp.pad(c_kv, [(0, 0), (0, max_len - s), (0, 0)])
        k_rope = jnp.pad(k_rope, [(0, 0), (0, max_len - s), (0, 0)])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(p, cfg: ModelConfig, x, cache, position):
    """One-token decode with latent cache + W_uk/W_uv absorption."""
    b = x.shape[0]
    h = cfg.n_heads
    qk_n, qk_r, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q_nope, q_rope = _queries(p, cfg, x, position[:, None])  # (B,1,H,*)
    c_new, kr_new = _latents(p, cfg, x, position[:, None])  # (B,1,kvr),(B,1,qk_r)

    t = cache["c_kv"].shape[1]
    rows = jnp.arange(b)
    c_kv = cache["c_kv"].at[rows, position].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[rows, position].set(kr_new[:, 0])

    # absorption: score_nope = (q_nope W_uk^T) . c_kv  in latent space
    w_uk = p["w_uk"].reshape(kvr, h, qk_n)
    q_lat = jnp.einsum("bshq,rhq->bshr", q_nope, w_uk)  # (B,1,H,kvr)
    scale = (qk_n + qk_r) ** -0.5
    scores = (
        jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
        + jnp.einsum("bshq,btq->bhst", q_rope, k_rope)
    ) * scale
    mask = (jnp.arange(t)[None, :] <= position[:, None])[:, None, None, :]
    w = jax.nn.softmax(
        jnp.where(mask, scores.astype(jnp.float32), _NEG_INF), axis=-1
    ).astype(x.dtype)
    # output in latent space, then expand with W_uv
    o_lat = jnp.einsum("bhst,btr->bshr", w, c_kv)  # (B,1,H,kvr)
    w_uv = p["w_uv"].reshape(kvr, h, vh)
    out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv).reshape(b, 1, -1)
    return (
        jnp.einsum("bsq,qd->bsd", out, p["wo"]),
        {"c_kv": c_kv, "k_rope": k_rope},
    )

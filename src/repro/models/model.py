"""TransformerLM — one composable decoder-only LM covering all 10 assigned
architectures (dense GQA / MLA+MoE / RWKV6 / Hymba hybrid / modality-stub
backbones) with scan-over-layers, KV-cache decode and an optional MTP head.

Design rules:
  * params are plain dict pytrees; layers are STACKED on a leading L axis and
    executed with ``lax.scan`` — HLO size is depth-independent (80-layer
    InternVL compiles the same program as 1 layer), which keeps the 64
    dry-run compiles tractable and production compile times flat.
  * activations carry logical sharding annotations (models.sharding.ax);
    the launcher decides what they mean.
  * ``forward`` (train/prefill), ``decode_step`` (one token, cache), both
    pure functions of (cfg, params, ...).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (
    attention_decode,
    attention_prefill,
    attention_train,
    init_attention,
    init_kv_cache,
)
from .layers import chunked_ce_loss, cross_entropy_loss, rms_norm, swiglu
from .mla import init_mla, init_mla_cache, mla_decode, mla_prefill, mla_train
from .moe import init_moe, moe_apply
from .rwkv6 import (
    channel_mix_decode,
    channel_mix_train,
    init_channel_mix,
    init_rwkv6,
    init_rwkv6_cache,
    rwkv6_decode,
    rwkv6_prefill,
    rwkv6_train,
)
from .sharding import ax
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_prefill, ssm_train


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _res_ax(cfg: ModelConfig, x):
    """Residual-stream sharding between layers.

    Attention archs carry the stream sequence-sharded over the model axis
    (Megatron-style sequence parallelism): the lax.scan layer stash then
    holds a 1/model-axis slice per layer instead of the full (B,S,d).
    Recurrent archs (rwkv6 / hymba's SSM branch) scan over time, so their
    stream stays batch-sharded only.
    """
    if cfg.attn_type in ("gqa", "mla"):
        return ax(x, "batch", "seq_sp", None)
    return ax(x, "batch", None, None)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_mlp_dense(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": (jax.random.normal(k1, (d, f)) * d**-0.5).astype(dtype),
        "w3": (jax.random.normal(k2, (d, f)) * d**-0.5).astype(dtype),
        "w2": (jax.random.normal(k3, (f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.mlp_bias:
        p["b1"] = jnp.zeros((f,), dtype)
        p["b3"] = jnp.zeros((f,), dtype)
        p["b2"] = jnp.zeros((d,), dtype)
    return p


def _init_layer(key, cfg: ModelConfig, moe: bool):
    dtype = _dtype(cfg)
    d = cfg.d_model
    k_attn, k_mlp, k_x = jax.random.split(key, 3)
    p: dict[str, Any] = {
        "norm1": jnp.ones((d,), dtype),
        "norm2": jnp.ones((d,), dtype),
    }
    if cfg.attn_type == "gqa":
        p["attn"] = init_attention(k_attn, cfg, dtype)
    elif cfg.attn_type == "mla":
        p["attn"] = init_mla(k_attn, cfg, dtype)
    elif cfg.attn_type == "rwkv6":
        p["attn"] = init_rwkv6(k_attn, cfg, dtype)
    elif cfg.attn_type == "hymba":
        ka, km = jax.random.split(k_attn)
        p["attn"] = init_attention(ka, cfg, dtype)
        p["ssm"] = init_ssm(km, cfg, dtype)
        p["norm_attn_out"] = jnp.ones((d,), dtype)
        p["norm_ssm_out"] = jnp.ones((d,), dtype)
        p["branch_beta"] = jnp.ones((2,), dtype)
    else:
        raise ValueError(cfg.attn_type)
    if cfg.attn_type == "rwkv6":
        p["mlp"] = init_channel_mix(k_mlp, cfg, dtype)
    elif moe:
        p["mlp"] = init_moe(k_mlp, cfg, dtype)
    else:
        p["mlp"] = _init_mlp_dense(k_mlp, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict[str, Any]:
    dtype = _dtype(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    k_emb, k_layers, k_dense, k_head, k_mtp = jax.random.split(key, 5)
    n_moe_layers = cfg.n_layers - cfg.n_dense_layers
    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_emb, (v, d)) * 0.02).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(k_head, (d, v)) * d**-0.5
        ).astype(dtype)
    moe = cfg.mlp_type == "moe"
    if moe and cfg.n_dense_layers:
        params["layers_dense"] = jax.vmap(
            lambda k: _init_layer(k, cfg, moe=False)
        )(jax.random.split(k_dense, cfg.n_dense_layers))
    params["layers"] = jax.vmap(lambda k: _init_layer(k, cfg, moe=moe))(
        jax.random.split(k_layers, n_moe_layers if moe else cfg.n_layers)
    )
    if cfg.mtp_depth:
        km1, km2 = jax.random.split(k_mtp)
        params["mtp"] = {
            "proj": (jax.random.normal(km1, (2 * d, d)) * (2 * d) ** -0.5).astype(dtype),
            "block": _init_layer(km2, cfg, moe=False),
            "norm": jnp.ones((d,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _block_train(cfg: ModelConfig, p, x, positions, moe: bool, use_flash: bool):
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    if cfg.attn_type == "gqa":
        a = attention_train(p["attn"], cfg, h, positions, use_flash)
    elif cfg.attn_type == "mla":
        a = mla_train(p["attn"], cfg, h, positions)
    elif cfg.attn_type == "rwkv6":
        a = rwkv6_train(p["attn"], cfg, h)
    else:  # hymba: parallel attention + SSM heads on the same input
        att = attention_train(p["attn"], cfg, h, positions, use_flash)
        ssm = ssm_train(p["ssm"], cfg, h)
        att = rms_norm(att, p["norm_attn_out"], cfg.rms_eps)
        ssm = rms_norm(ssm, p["norm_ssm_out"], cfg.rms_eps)
        beta = p["branch_beta"]
        a = 0.5 * (beta[0] * att + beta[1] * ssm)
    x = x + a
    x = _res_ax(cfg, x)
    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    if cfg.attn_type == "rwkv6":
        m = channel_mix_train(p["mlp"], h)
    elif moe:
        m, aux = moe_apply(p["mlp"], cfg, h)
    else:
        m = swiglu(
            h, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"],
            p["mlp"].get("b1"), p["mlp"].get("b3"), p["mlp"].get("b2"),
        )
    x = x + m
    return _res_ax(cfg, x), aux


def _block_decode(cfg: ModelConfig, p, x, cache, position, moe: bool):
    """One-token step. cache: this layer's cache pytree. Returns x, cache."""
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    if cfg.attn_type == "gqa":
        a, kv = attention_decode(p["attn"], cfg, h, cache, position)
        new_cache = kv
    elif cfg.attn_type == "mla":
        a, new_cache = mla_decode(p["attn"], cfg, h, cache, position)
    elif cfg.attn_type == "rwkv6":
        a, state, xprev = rwkv6_decode(p["attn"], cfg, h, cache)
        new_cache = dict(cache, state=state, x_prev_tm=xprev)
    else:  # hymba
        att, kv = attention_decode(p["attn"], cfg, h, cache["kv"], position)
        ssm_o, ssm_c = ssm_decode(p["ssm"], cfg, h, cache["ssm"])
        att = rms_norm(att, p["norm_attn_out"], cfg.rms_eps)
        ssm_o = rms_norm(ssm_o, p["norm_ssm_out"], cfg.rms_eps)
        beta = p["branch_beta"]
        a = 0.5 * (beta[0] * att + beta[1] * ssm_o)
        new_cache = {"kv": kv, "ssm": ssm_c}
    x = x + a
    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    if cfg.attn_type == "rwkv6":
        m, xprev_cm = channel_mix_decode(p["mlp"], h, cache["x_prev_cm"])
        new_cache = dict(new_cache, x_prev_cm=xprev_cm)
    elif moe:
        m, _ = moe_apply(p["mlp"], cfg, h)
    else:
        m = swiglu(
            h, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"],
            p["mlp"].get("b1"), p["mlp"].get("b3"), p["mlp"].get("b2"),
        )
    return x + m, new_cache


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def embed_inputs(cfg: ModelConfig, params, tokens, frontend_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend is not None and frontend_embeds is not None:
        nf = frontend_embeds.shape[1]
        pad = x.shape[1] - nf
        fe = jnp.pad(frontend_embeds.astype(x.dtype), ((0, 0), (0, pad), (0, 0)))
        is_frontend = (jnp.arange(x.shape[1]) < nf)[None, :, None]
        x = jnp.where(is_frontend, fe, x)
    return ax(x, "batch", None, None)


_REMAT_POLICIES = {
    # save nothing: recompute the whole block in backward (min memory)
    "full": None,
    # save MXU outputs (matmul results), recompute elementwise ops
    "dots": "dots_saveable",
}


def _maybe_remat(fn, remat: str | None):
    if remat is None:
        return fn
    policy = _REMAT_POLICIES[remat]
    if policy is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=getattr(jax.checkpoint_policies, policy)
    )


def hidden_states(
    cfg: ModelConfig,
    params,
    tokens,
    frontend_embeds=None,
    use_flash: bool = False,
    remat: str | None = None,
):
    """tokens (B,S) -> (final-normed hidden (B,S,d), moe aux loss)."""
    b, s = tokens.shape
    x = embed_inputs(cfg, params, tokens, frontend_embeds)
    x = _res_ax(cfg, x)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    moe = cfg.mlp_type == "moe"

    def dense_body(carry, layer_p):
        x, aux = carry
        x, a = _block_train(cfg, layer_p, x, positions, False, use_flash)
        return (x, aux + a), None

    def body(carry, layer_p):
        x, aux = carry
        x, a = _block_train(cfg, layer_p, x, positions, moe, use_flash)
        return (x, aux + a), None

    dense_body = _maybe_remat(dense_body, remat)
    body = _maybe_remat(body, remat)
    aux = jnp.zeros((), jnp.float32)
    if "layers_dense" in params:
        (x, aux), _ = jax.lax.scan(dense_body, (x, aux), params["layers_dense"])
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
    return rms_norm(x, params["final_norm"], cfg.rms_eps), aux


def lm_head(cfg: ModelConfig, params):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(
    cfg: ModelConfig,
    params,
    tokens,
    frontend_embeds=None,
    use_flash: bool = False,
    remat: str | None = None,
):
    """tokens (B,S) -> logits (B,S,V), aux (moe load-balance loss)."""
    x, aux = hidden_states(cfg, params, tokens, frontend_embeds, use_flash,
                           remat)
    logits = jnp.einsum("bsd,dv->bsv", x, lm_head(cfg, params))
    return ax(logits, "batch", None, "vocab"), aux


# below this sequence length the full logits tensor is cheap enough to
# materialize; above it the loss scans over sequence chunks (rematted)
_CE_CHUNK_THRESHOLD = 2048
_CE_CHUNK = 512


def loss_fn(
    cfg: ModelConfig,
    params,
    tokens,
    labels,
    frontend_embeds=None,
    aux_weight: float = 0.01,
    use_flash: bool = False,
    remat: str | None = None,
):
    s = tokens.shape[1]
    x, aux = hidden_states(cfg, params, tokens, frontend_embeds, use_flash,
                           remat)
    head = lm_head(cfg, params)
    if s >= _CE_CHUNK_THRESHOLD and s % _CE_CHUNK == 0:
        loss = chunked_ce_loss(x, head, labels, _CE_CHUNK)
    else:
        logits = ax(jnp.einsum("bsd,dv->bsv", x, head),
                    "batch", None, "vocab")
        loss = cross_entropy_loss(logits, labels)
    if cfg.mlp_type == "moe":
        loss = loss + aux_weight * aux
    return loss


# ---------------------------------------------------------------------------
# prefill: full-prompt forward that also builds the decode cache
# ---------------------------------------------------------------------------
def _block_prefill(cfg: ModelConfig, p, x, positions, moe: bool, max_len: int,
                   use_flash: bool):
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    if cfg.attn_type == "gqa":
        a, cache = attention_prefill(p["attn"], cfg, h, positions, max_len,
                                     use_flash)
    elif cfg.attn_type == "mla":
        a, cache = mla_prefill(p["attn"], cfg, h, positions, max_len)
    elif cfg.attn_type == "rwkv6":
        a, cache = rwkv6_prefill(p["attn"], cfg, h)
    else:  # hymba
        att, kv = attention_prefill(p["attn"], cfg, h, positions, max_len,
                                    use_flash)
        ssm_o, ssm_c = ssm_prefill(p["ssm"], cfg, h)
        att = rms_norm(att, p["norm_attn_out"], cfg.rms_eps)
        ssm_o = rms_norm(ssm_o, p["norm_ssm_out"], cfg.rms_eps)
        beta = p["branch_beta"]
        a = 0.5 * (beta[0] * att + beta[1] * ssm_o)
        cache = {"kv": kv, "ssm": ssm_c}
    x = x + a
    x = _res_ax(cfg, x)
    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    if cfg.attn_type == "rwkv6":
        m = channel_mix_train(p["mlp"], h)
        cache = dict(cache, x_prev_cm=h[:, -1, :])
    elif moe:
        m, _ = moe_apply(p["mlp"], cfg, h)
    else:
        m = swiglu(
            h, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"],
            p["mlp"].get("b1"), p["mlp"].get("b3"), p["mlp"].get("b2"),
        )
    return _res_ax(cfg, x + m), cache


def prefill(
    cfg: ModelConfig,
    params,
    tokens,
    frontend_embeds=None,
    max_len: int | None = None,
    use_flash: bool = False,
):
    """Process the whole prompt; return (last-token logits (B,V), cache).

    The returned cache is layout-identical to init_cache(cfg, B, max_len)
    so decode_step continues from position S.
    """
    b, s = tokens.shape
    max_len = max_len or s
    x = embed_inputs(cfg, params, tokens, frontend_embeds)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    moe = cfg.mlp_type == "moe"

    def mk_body(is_moe):
        def body(x, layer_p):
            x, cache = _block_prefill(cfg, layer_p, x, positions, is_moe,
                                      max_len, use_flash)
            return x, cache

        return body

    cache: dict[str, Any] = {"pos": jnp.full((b,), s, jnp.int32)}
    if "layers_dense" in params:
        x, dense_caches = jax.lax.scan(mk_body(False), x,
                                       params["layers_dense"])
        cache["layers_dense"] = dense_caches
    x, layer_caches = jax.lax.scan(mk_body(moe), x, params["layers"])
    cache["layers"] = layer_caches
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return ax(logits, "batch", "vocab"), cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _init_layer_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.attn_type == "gqa":
        return init_kv_cache(cfg, batch, max_len, dtype)
    if cfg.attn_type == "mla":
        return init_mla_cache(cfg, batch, max_len, dtype)
    if cfg.attn_type == "rwkv6":
        return init_rwkv6_cache(cfg, batch, dtype)
    return {  # hymba
        "kv": init_kv_cache(cfg, batch, max_len, dtype),
        "ssm": init_ssm_cache(cfg, batch, dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-layer caches + current position."""
    dtype = _dtype(cfg)

    def stack(n):
        one = _init_layer_cache(cfg, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), one
        )

    cache: dict[str, Any] = {
        "layers": stack(
            cfg.n_layers - (cfg.n_dense_layers if cfg.mlp_type == "moe" else 0)
        ),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.mlp_type == "moe" and cfg.n_dense_layers:
        cache["layers_dense"] = stack(cfg.n_dense_layers)
    return cache


def decode_step(cfg: ModelConfig, params, tokens, cache):
    """tokens (B,) current token ids -> (logits (B,V), new cache)."""
    b = tokens.shape[0]
    position = cache["pos"]
    x = jnp.take(params["embed"], tokens[:, None], axis=0)
    moe = cfg.mlp_type == "moe"

    def mk_body(is_moe):
        def body(x, scanned):
            layer_p, layer_c = scanned
            x, new_c = _block_decode(cfg, layer_p, x, layer_c, position, is_moe)
            return x, new_c

        return body

    if "layers_dense" in cache:
        x, new_dense = jax.lax.scan(
            mk_body(False), x, (params["layers_dense"], cache["layers_dense"])
        )
    x, new_layers = jax.lax.scan(
        mk_body(moe), x, (params["layers"], cache["layers"])
    )
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    new_cache = dict(cache, layers=new_layers, pos=position + 1)
    if "layers_dense" in cache:
        new_cache["layers_dense"] = new_dense
    return logits, new_cache


# ---------------------------------------------------------------------------
# MTP (deepseek-v3 optional multi-token-prediction head)
# ---------------------------------------------------------------------------
def mtp_loss(cfg: ModelConfig, params, tokens, labels_next, labels_next2):
    """Main next-token loss + depth-1 MTP loss sharing the embedding/head."""
    logits, aux = forward(cfg, params, tokens)
    main = cross_entropy_loss(logits, labels_next)
    p = params["mtp"]
    b, s = tokens.shape
    h_last = jnp.take(params["embed"], labels_next, axis=0)  # teacher forcing
    # combine current hidden stream with next-token embedding
    x = jnp.concatenate(
        [jnp.take(params["embed"], tokens, axis=0), h_last], axis=-1
    )
    x = jnp.einsum("bsd,dk->bsk", x, p["proj"])
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x, _ = _block_train(cfg, p["block"], x, positions, False, False)
    x = rms_norm(x, p["norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits2 = jnp.einsum("bsd,dv->bsv", x, head)
    mtp = cross_entropy_loss(logits2, labels_next2)
    return main + 0.3 * mtp + 0.01 * aux

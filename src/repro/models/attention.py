"""GQA/MQA/MHA attention with RoPE, qk-norm, optional bias, sliding window,
KV-cache decode, and an optional Pallas flash path for TPU."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .blockwise import chunked_attention
from .layers import apply_rope, rms_norm
from .sharding import ax

_NEG_INF = -1e30

# Full-sequence attention switches to the chunked online-softmax path at
# this length: keeps peak memory O(S * chunk) instead of O(S^2) and keeps
# HLO FLOPs at the causal optimum via the paired schedule (blockwise.py).
# Measured at train_4k: the chunked path cuts compute 7% and peak memory
# 9%, but its scan-residual traffic RAISES the memory roofline term 22%
# (the dense (S,S) scores are cheaper than per-block residual stacking at
# this size) — so the dense path keeps 4k and chunked starts at 8k, where
# it wins on every term (§Perf iteration log).
BLOCKWISE_THRESHOLD = 8192


def init_attention(key, cfg: ModelConfig, dtype) -> dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * d**-0.5).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd)) * d**-0.5).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd)) * d**-0.5).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int):
    """q (B,S,H,hd), k/v (B,T,KV,hd); mask (B,1,S,T) or (1,1,S,T) bool."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    q = q.reshape(b, s, kvh, n_rep, hd)
    scores = jnp.einsum("bsgrh,btgh->bgrst", q, k) / (hd**0.5)
    scores = jnp.where(mask[:, :, None], scores.astype(jnp.float32), _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgh->bsgrh", w, v)
    return out.reshape(b, s, h, hd)


def _head_padding(cfg: ModelConfig) -> tuple[int, int]:
    """(kv_pad, rep_pad) so kv_pad*rep_pad divides the model axis evenly.

    Archs whose head count doesn't divide the 16-way model axis
    (starcoder2/granite: 24H, hymba: 25H/5KV) would otherwise be silently
    REPLICATED by the ax() divisibility guard — a full axis-factor (16x)
    of redundant attention FLOPs.  Padding heads to the next layout that
    divides costs only the pad ratio (1.33x for 24->32, 1.92x for
    hymba's 5x5 -> 8x6) and keeps every real head sharded.
    """
    from .sharding import _axis_size, current_mesh, current_rules

    mesh = current_mesh()
    kv, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    if mesh is None:
        return kv, rep
    axis = _axis_size(mesh, (current_rules() or {}).get("heads"))
    if (kv * rep) % axis == 0:
        return kv, rep
    best = None
    for kv_pad in range(kv, kv + axis + 1):
        for rep_pad in range(rep, rep + axis + 1):
            if (kv_pad * rep_pad) % axis == 0:
                if best is None or kv_pad * rep_pad < best[0] * best[1]:
                    best = (kv_pad, rep_pad)
    return best if best else (kv, rep)


def _shard_qkv(cfg: ModelConfig, q, k, v):
    """Shard attention over the model axis, padding heads if needed.

    Returns (q, k, v, (kv_pad, rep_pad)); padded q/k/v have
    kv_pad*rep_pad total / kv_pad kv heads.  Callers slice the output
    back with _unpad_heads.
    """
    b, s, _, hd = q.shape
    kv, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    kv_pad, rep_pad = _head_padding(cfg)
    if (kv_pad, rep_pad) != (kv, rep):
        q = q.reshape(b, s, kv, rep, hd)
        q = jnp.pad(q, [(0, 0), (0, 0), (0, kv_pad - kv),
                        (0, rep_pad - rep), (0, 0)])
        q = q.reshape(b, s, kv_pad * rep_pad, hd)
        pad_kv = [(0, 0), (0, 0), (0, kv_pad - kv), (0, 0)]
        k = jnp.pad(k, pad_kv)
        v = jnp.pad(v, pad_kv)
    q = ax(q, "batch", None, "heads", None)
    k = ax(k, "batch", None, "kv_heads", None)
    v = ax(v, "batch", None, "kv_heads", None)
    return q, k, v, (kv_pad, rep_pad)


def _unpad_heads(cfg: ModelConfig, out, pads):
    """out (B,S,kv_pad*rep_pad,hd) -> (B,S,H,hd) real heads only."""
    kv, rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    kv_pad, rep_pad = pads
    if (kv_pad, rep_pad) == (kv, rep):
        return out
    b, s, _, hd = out.shape
    out = out.reshape(b, s, kv_pad, rep_pad, hd)[:, :, :kv, :rep]
    return out.reshape(b, s, kv * rep, hd)


def _attend_full(q, k, v, cfg: ModelConfig, use_flash: bool):
    """Causal self-attention over the full sequence, picking the path:
    Pallas flash kernel (TPU) > chunked online-softmax (long seq) > dense.
    Shapes may carry padded heads (see _head_padding)."""
    s = q.shape[1]
    n_rep = q.shape[2] // k.shape[2]
    if use_flash:
        from ..kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, causal=True,
                               window=cfg.sliding_window or None)
    # (measured on hymba train_4k: routing windowed attention blockwise
    # already at 2*window cuts peak memory 114->76 GiB and compute 18%,
    # but the scan-residual traffic raises the dominant memory TERM
    # 55->90 s — so the dense path keeps short windowed sequences and
    # blockwise starts at the usual threshold, where the near-diagonal
    # block table wins on every metric.)
    if s >= BLOCKWISE_THRESHOLD:
        return chunked_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            q_chunk=min(1024, cfg.sliding_window or 1024),
            kv_chunk=min(1024, cfg.sliding_window or 1024),
        )
    idx = jnp.arange(s)
    mask = idx[:, None] >= idx[None, :]
    if cfg.sliding_window:
        mask &= idx[:, None] - idx[None, :] < cfg.sliding_window
    return _sdpa(q, k, v, mask[None, None], n_rep)


def attention_train(p, cfg: ModelConfig, x, positions, use_flash: bool = False):
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    q, k, v, pads = _shard_qkv(cfg, q, k, v)
    out = _attend_full(q, k, v, cfg, use_flash)
    out = _unpad_heads(cfg, ax(out, "batch", None, "heads", None), pads)
    return jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["wo"])


def attention_prefill(
    p, cfg: ModelConfig, x, positions, max_len: int, use_flash: bool = False
):
    """Full-sequence attention that also returns the decode-ready KV cache.

    The cache buffer matches init_kv_cache(max_len): with a sliding window
    it is the ring buffer holding the last ``window`` tokens (assumes
    window | S so ring slots line up with a plain tail slice).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    q, k, v, pads = _shard_qkv(cfg, q, k, v)
    out = _attend_full(q, k, v, cfg, use_flash)
    out = _unpad_heads(cfg, ax(out, "batch", None, "heads", None), pads)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(b, s, -1), p["wo"])

    # the decode cache stores REAL kv heads only (init_kv_cache layout)
    k = k[:, :, : cfg.n_kv_heads]
    v = v[:, :, : cfg.n_kv_heads]
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if length < s:
        assert s % length == 0, (s, length)
        k_buf, v_buf = k[:, -length:], v[:, -length:]
    elif length > s:
        pad = [(0, 0), (0, length - s), (0, 0), (0, 0)]
        k_buf, v_buf = jnp.pad(k, pad), jnp.pad(v, pad)
    else:
        k_buf, v_buf = k, v
    return out, {"k": k_buf, "v": v_buf}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
    }


def attention_decode(p, cfg: ModelConfig, x, cache, position):
    """One-token decode step.

    x: (B, 1, d); cache {k,v}: (B, T, KV, hd); position: (B,) current index.
    With a sliding window the cache is a ring buffer of size window.
    Returns (out (B,1,d), new cache).
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, position[:, None])
    t = cache["k"].shape[1]
    slot = jnp.where(
        cfg.sliding_window > 0, position % jnp.maximum(t, 1), position
    )
    # scatter ONE slot per row — a one-hot masked rewrite would read and
    # write the whole cache every decode step (2x the unavoidable
    # attention read; decode is memory-bound, so that's a 2-3x tax)
    rows = jnp.arange(b)
    k = cache["k"].at[rows, slot].set(k_new[:, 0])
    v = cache["v"].at[rows, slot].set(v_new[:, 0])
    k = ax(k, "batch", None, "kv_heads", None)
    v = ax(v, "batch", None, "kv_heads", None)

    idx = jnp.arange(t)[None, :]  # (1, T)
    if cfg.sliding_window:
        # ring buffer: every slot written within the last `t` tokens is valid
        mask = (idx <= position[:, None]) | (position[:, None] >= t)
    else:
        mask = idx <= position[:, None]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = _sdpa(q, k, v, mask[:, None, None, :], n_rep)
    out = jnp.einsum("bsq,qd->bsd", out.reshape(b, 1, -1), p["wo"])
    return out, {"k": k, "v": v}

"""Selective SSM (Mamba-style) branch used by Hymba's parallel heads.

Diagonal selective state space: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,
y_t = C_t h_t + D x_t, with data-dependent dt/B/C.  Depthwise causal conv of
width 4 in front (implemented as explicit shifts — static shapes, no conv op
needed).  State is (d_inner, ssm_state) per layer: O(1) in context length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

_CONV_W = 4


def init_ssm(key, cfg: ModelConfig, dtype):
    d, di, st = cfg.d_model, cfg.d_inner_, cfg.ssm_state
    ks = jax.random.split(key, 7)

    def lin(k, i, o):
        return (jax.random.normal(k, (i, o)) * i**-0.5).astype(dtype)

    return {
        "w_in": lin(ks[0], d, 2 * di),  # u and gate z
        "conv_w": (jax.random.normal(ks[1], (_CONV_W, di)) * 0.5).astype(dtype),
        "w_dt": lin(ks[2], di, di),
        "dt_bias": jnp.zeros((di,), dtype),
        "w_b": lin(ks[3], di, st),
        "w_c": lin(ks[4], di, st),
        "a_log": jnp.zeros((di, st), dtype),  # A = -exp(a_log)
        "d_skip": jnp.ones((di,), dtype),
        "w_out": lin(ks[5], di, d),
    }


def _conv(u, conv_w, conv_cache=None):
    """Depthwise causal width-4 conv via shifts. u: (B,S,di)."""
    b, s, di = u.shape
    if conv_cache is None:
        pad = jnp.zeros((b, _CONV_W - 1, di), u.dtype)
    else:
        pad = conv_cache  # (B, 3, di) — last 3 inputs
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+3, di)
    out = sum(
        full[:, i : i + s, :] * conv_w[i] for i in range(_CONV_W)
    )
    new_cache = full[:, -(_CONV_W - 1) :, :]
    return jax.nn.silu(out), new_cache


def _ssm_params(p, u):
    dt = jax.nn.softplus(
        jnp.einsum("bsd,de->bse", u, p["w_dt"]) + p["dt_bias"]
    ).astype(jnp.float32)
    bmat = jnp.einsum("bsd,dn->bsn", u, p["w_b"]).astype(jnp.float32)
    cmat = jnp.einsum("bsd,dn->bsn", u, p["w_c"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, st)
    return dt, bmat, cmat, a


def selective_scan(u, dt, bmat, cmat, a, d_skip, h0, chunk: int = 16):
    """u: (B,S,di); dt: (B,S,di); b/c: (B,S,st); a: (di,st); h0: (B,di,st).

    Two-level scan: the outer lax.scan carries the (B,di,st) fp32 state
    once per ``chunk`` steps; the inner steps are UNROLLED so XLA fuses
    the whole chunk into one kernel and the state never round-trips HBM
    between timesteps.  (Mamba-1's per-(di,st) data-dependent decay is
    not matmul-separable like WKV6, so this is the chunking that exists;
    measured 506.8 -> see EXPERIMENTS.md §Perf on the hymba train cell.)
    The plain per-step scan is the chunk=1 special case.
    """
    b, s, di = u.shape
    uf = u.astype(jnp.float32)

    def step(h, ut, dtt, bt, ct):
        da = jnp.exp(dtt[..., None] * a)  # (B,di,st)
        h = da * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    if chunk > 1 and s % chunk == 0 and s > chunk:
        n = s // chunk
        resh3 = lambda t: t.reshape(b, n, chunk, -1).transpose(1, 0, 2, 3)
        xs = (resh3(uf), resh3(dt), resh3(bmat), resh3(cmat))

        @jax.checkpoint  # rematted: backward recomputes the chunk instead
        def chunk_body(h, inp):  # of stacking per-step (B,di,st) residuals
            uc, dc, bc, cc = inp  # (B,C,*)
            ys = []
            for i in range(chunk):  # unrolled: fuses into one kernel
                h, y = step(h, uc[:, i], dc[:, i], bc[:, i], cc[:, i])
                ys.append(y)
            return h, jnp.stack(ys, axis=1)  # (B,C,di)

        h, ys = jax.lax.scan(chunk_body, h0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    else:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (uf, dt, bmat, cmat))
        h, ys = jax.lax.scan(lambda h, i: step(h, *i), h0, xs)
        y = jnp.moveaxis(ys, 0, 1)
    return y + uf * d_skip.astype(jnp.float32), h


def ssm_train(p, cfg: ModelConfig, x):
    out, _ = ssm_prefill(p, cfg, x)
    return out


def ssm_prefill(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> ((B,S,d), decode cache {h, conv})."""
    uz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_cache = _conv(u, p["conv_w"])
    dt, bmat, cmat, a = _ssm_params(p, u)
    h0 = jnp.zeros((x.shape[0], cfg.d_inner_, cfg.ssm_state), jnp.float32)
    y, h = selective_scan(u, dt, bmat, cmat, a, p["d_skip"], h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"h": h, "conv": conv_cache}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner_, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, cfg.d_inner_), dtype),
    }


def ssm_decode(p, cfg: ModelConfig, x, cache):
    """x: (B,1,d). Returns (out (B,1,d), new cache)."""
    uz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_cache = _conv(u, p["conv_w"], cache["conv"])
    dt, bmat, cmat, a = _ssm_params(p, u)
    y, h = selective_scan(u, dt, bmat, cmat, a, p["d_skip"], cache["h"])
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"h": h, "conv": conv_cache}

"""Chunked online-softmax attention in pure jnp (XLA flash attention).

This is the sub-quadratic attention path used by the 32k-prefill and 500k
shapes when lowering on backends where the Pallas kernel is unavailable
(the CPU dry-run) — and it is also the memory-bounded fallback on TPU for
shapes the kernel does not cover.  Math matches kernels/flash_attention.

Key property for the roofline: the set of (q-block, kv-block) pairs is
enumerated STATICALLY from the causal/window structure, so masked-out
blocks are never computed — HLO FLOPs stay ~optimal (half the rectangle
for causal, O(S*window) for sliding-window) instead of the 2x-waste of a
masked dense rectangle.  Rows are padded to the max block count with
invalid entries masked inside the online-softmax update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _block_table(n_q: int, n_k: int, q_chunk: int, kv_chunk: int,
                 causal: bool, window: int):
    """Static (idx, valid) arrays: for each q block, which kv blocks touch it."""
    rows = []
    for i in range(n_q):
        q_lo, q_hi = i * q_chunk, i * q_chunk + q_chunk - 1
        j_hi = (q_hi // kv_chunk) if causal else n_k - 1
        j_lo = 0
        if window:
            j_lo = max(0, (q_lo - window + 1) // kv_chunk)
        rows.append(list(range(j_lo, min(j_hi, n_k - 1) + 1)))
    width = max(len(r) for r in rows)
    idx = [[r[m] if m < len(r) else 0 for m in range(width)] for r in rows]
    valid = [[m < len(r) for m in range(width)] for r in rows]
    return jnp.asarray(idx, jnp.int32), jnp.asarray(valid, jnp.bool_)


def chunked_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, T, KV, hd)
    v: jnp.ndarray,  # (B, T, KV, hd_v)
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    scale: float | None = None,
) -> jnp.ndarray:
    """Online-softmax attention over statically enumerated blocks.

    GQA layout: H = KV * rep.  Never materializes more than one
    (q_chunk x kv_chunk) score tile per (batch, head) at a time.

    Plain causal self-attention uses the BALANCED PAIRING schedule
    (_paired_causal): q-row p is co-scheduled with row nq-1-p so every
    scan iteration does constant work with no masked-out padding blocks
    — total FLOPs = the causal optimum, not the dense rectangle.
    Windowed / cross attention falls back to the padded block table.
    Returns (B, S, H, hd_v) in q.dtype.
    """
    if (causal and not window and q.shape[1] == k.shape[1]
            and q_chunk == kv_chunk and q.shape[1] >= 2 * q_chunk
            and (q.shape[1] // q_chunk) % 2 == 0):
        return _paired_causal(q, k, v, chunk=q_chunk, scale=scale)
    return _table_attention(q, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk, scale=scale)


def _table_attention(q, k, v, *, causal, window, q_chunk, kv_chunk, scale):
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    rep = h // kv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    assert s % q_chunk == 0 and t % kv_chunk == 0, (s, t, q_chunk, kv_chunk)
    n_q, n_k = s // q_chunk, t // kv_chunk
    scale = scale if scale is not None else hd**-0.5

    idx, valid = _block_table(n_q, n_k, q_chunk, kv_chunk, causal, window)

    # (n_q, B, qc, KV, rep, hd)
    qs = q.reshape(b, n_q, q_chunk, kv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
    q_base = jnp.arange(n_q, dtype=jnp.int32) * q_chunk
    k_off = jnp.arange(kv_chunk, dtype=jnp.int32)

    def one_q_block(carry, xs):
        del carry
        q_i, idx_row, valid_row, base = xs
        q_pos = base + jnp.arange(q_chunk, dtype=jnp.int32)

        def one_kv_block(st, xs_inner):
            m, l, acc = st  # (B,KV,rep,qc), same, (B,KV,rep,qc,hd_v) f32
            j, ok = xs_inner
            kb = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
            sc = jnp.einsum(
                "bqgrh,bkgh->bgrqk", q_i.astype(jnp.float32),
                kb.astype(jnp.float32),
            ) * scale
            k_pos = j * kv_chunk + k_off
            mask = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask &= ok
            sc = jnp.where(mask, sc, _NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", p, vb.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        init = (
            jnp.full((b, kv, rep, q_chunk), _NEG_INF, jnp.float32),
            jnp.zeros((b, kv, rep, q_chunk), jnp.float32),
            jnp.zeros((b, kv, rep, q_chunk, hd_v), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(one_kv_block, init, (idx_row, valid_row))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,rep,qc,hd_v)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B,qc,KV,rep,hd_v)

    _, outs = jax.lax.scan(one_q_block, None, (qs, idx, valid, q_base))
    # (n_q, B, qc, KV, rep, hd_v) -> (B, S, H, hd_v)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd_v)
    return out.astype(q.dtype)


def _paired_causal(q, k, v, *, chunk: int, scale: float | None):
    """Causal attention with the balanced (p, nq-1-p) row pairing.

    Row p needs p+1 kv blocks and row nq-1-p needs nq-p, so a pair always
    needs nq+1 — the inner scan has constant length and every block it
    computes is live (the only masking left is the two diagonal tiles).
    FLOPs = nq(nq+1)/2 block-pairs per (b, head) = the causal optimum.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    hd_v = v.shape[-1]
    rep = h // kv
    nq = s // chunk
    half = nq // 2
    scale = scale if scale is not None else hd**-0.5

    # (nq, B, C, KV, rep, hd)
    qs = q.reshape(b, nq, chunk, kv, rep, hd).transpose(1, 0, 2, 3, 4, 5)
    q_lo, q_hi = qs[:half], qs[half:][::-1]  # pair p: rows (p, nq-1-p)
    p_idx = jnp.arange(half, dtype=jnp.int32)
    diag_mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))

    def one_pair(carry, xs):
        del carry
        ql, qh, p = xs  # (B,C,KV,rep,hd) x2, scalar row index
        row_hi = nq - 1 - p

        def inner(st, l):
            m, lsum, acc = st  # (2,B,KV,rep,C), ..., (2,B,KV,rep,C,hd_v)
            sel = (l > p).astype(jnp.int32)  # 0 -> low row, 1 -> high row
            j = jnp.where(sel == 0, l, l - p - 1)
            diag = jnp.where(sel == 0, j == p, j == row_hi)
            kb = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
            q_blk = jnp.where(sel == 0, ql, qh)
            sc = jnp.einsum(
                "bqgrh,bkgh->bgrqk", q_blk.astype(jnp.float32),
                kb.astype(jnp.float32),
            ) * scale
            sc = jnp.where(
                jnp.logical_or(~diag, diag_mask)[None, None, None],
                sc, _NEG_INF,
            )
            m_prev = m[sel]
            l_prev = lsum[sel]
            acc_prev = acc[sel]
            m_new = jnp.maximum(m_prev, sc.max(-1))
            pmat = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + pmat.sum(-1)
            acc_new = acc_prev * alpha[..., None] + jnp.einsum(
                "bgrqk,bkgh->bgrqh", pmat, vb.astype(jnp.float32)
            )
            pick = (jnp.arange(2) == sel)[:, None, None, None, None]
            m = jnp.where(pick, m_new[None], m)
            lsum = jnp.where(pick, l_new[None], lsum)
            acc = jnp.where(pick[..., None], acc_new[None], acc)
            return (m, lsum, acc), None

        init = (
            jnp.full((2, b, kv, rep, chunk), _NEG_INF, jnp.float32),
            jnp.zeros((2, b, kv, rep, chunk), jnp.float32),
            jnp.zeros((2, b, kv, rep, chunk, hd_v), jnp.float32),
        )
        (m, lsum, acc), _ = jax.lax.scan(
            inner, init, jnp.arange(nq + 1, dtype=jnp.int32)
        )
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]
        return None, out.transpose(0, 1, 4, 2, 3, 5)  # (2,B,C,KV,rep,hd_v)

    _, outs = jax.lax.scan(one_pair, None, (q_lo, q_hi, p_idx))
    # outs: (half, 2, B, C, KV, rep, hd_v); row order: [p] and [nq-1-p]
    lo = outs[:, 0]
    hi = outs[:, 1][::-1]
    rows = jnp.concatenate([lo, hi], 0)  # (nq, B, C, KV, rep, hd_v)
    out = rows.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd_v)
    return out.astype(q.dtype)

"""repro.models — composable decoder-only LM covering the 10 assigned
architectures."""

from .model import (
    decode_step,
    embed_inputs,
    forward,
    init_cache,
    init_params,
    loss_fn,
    mtp_loss,
    prefill,
)

__all__ = [
    "decode_step",
    "embed_inputs",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "mtp_loss",
    "prefill",
]

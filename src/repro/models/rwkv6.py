"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

State is O(H * hd * hd) per layer regardless of context length — this arch
(with hymba) carries the long_500k shape.  Training uses ``lax.scan`` over
time (the Pallas ``rwkv6_scan`` kernel is the chunked TPU version; ref.py
mirrors the math here).

Simplifications vs the full Finch release (noted in DESIGN.md): single-lerp
token shift (not ddlerp) and RMS head-norm instead of GroupNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rms_norm

_LORA = 64


def init_rwkv6(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 10)

    def lin(k, i, o, scale=None):
        return (jax.random.normal(k, (i, o)) * (scale or i**-0.5)).astype(dtype)

    h, hd = cfg.n_heads, cfg.head_dim_
    return {
        "mu": (jnp.ones((5, d)) * 0.5).astype(dtype),  # r,k,v,w,g shift mixes
        "w_r": lin(ks[0], d, d),
        "w_k": lin(ks[1], d, d),
        "w_v": lin(ks[2], d, d),
        "w_g": lin(ks[3], d, d),
        "w_o": lin(ks[4], d, d),
        "w0": (jnp.zeros((d,)) - 5.0).astype(dtype),  # base decay (slow)
        "w_lora_a": lin(ks[5], d, _LORA, 0.01),
        "w_lora_b": lin(ks[6], _LORA, d, 0.01),
        "u": (jnp.zeros((h, hd))).astype(dtype),  # per-head bonus
        "head_norm": jnp.ones((hd,), dtype),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": (jnp.ones((2, d)) * 0.5).astype(dtype),  # k, r shift mixes
        "w_k": (jax.random.normal(k1, (d, f)) * d**-0.5).astype(dtype),
        "w_v": (jax.random.normal(k2, (f, d)) * f**-0.5).astype(dtype),
        "w_r": (jax.random.normal(k3, (d, d)) * d**-0.5).astype(dtype),
    }


def _token_shift(x, x_prev):
    """x: (B,S,d). Returns x_{t-1} with x_prev filling t=0."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix_inputs(p, cfg: ModelConfig, x, x_prev):
    xs = _token_shift(x, x_prev)
    mu = p["mu"]  # (5, d)
    mix = lambda i: x + (xs - x) * mu[i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(xw A) B))
    dw = jnp.einsum(
        "bsl,ld->bsd", jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"])),
        p["w_lora_b"],
    )
    logw = p["w0"].astype(jnp.float32) + dw.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(b, s, h, hd)  # in (0,1)
    return r, k, v, g, w


def wkv_scan(r, k, v, w, u, state):
    """The WKV6 recurrence (float32 state for stability).

    r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd).
    Returns (out (B,S,H,hd), final state).
      y_t = r_t . (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + uf[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 16):
    """Chunk-parallel WKV6 (jnp twin of kernels/rwkv6_scan; math identical
    to wkv_scan).

    The naive scan reads+writes the (B,H,hd,hd) fp32 state from HBM every
    timestep — the dominant roofline term for rwkv6 training (measured
    2527s memory term at train_4k).  The chunked form carries the state
    once per ``chunk`` steps and turns the within-chunk work into MXU
    matmuls via log-space decays:

      y_t = (r_t * e^{L_{t-1}}) . S_0                    (inter-chunk)
          + sum_{i<t} [(r_t e^{L_{t-1}}) . (k_i e^{-L_i})] v_i   (intra)
          + (r_t . (u * k_t)) v_t                        (bonus diag)
      S' = e^{L_C} * S_0 + sum_i (k_i e^{L_C - L_i}) v_i^T

    with L the cumulative per-channel log-decay inside the chunk.  The
    intra-chunk score exponent L_{t-1} - L_i (i < t) is a sum of
    log-decays strictly AFTER i, hence <= 0 — computed as an explicit
    (C,C) pairwise difference it can never overflow, for any decay rate
    (the factored matmul form k_i e^{-L_i} can; see tests).  The
    pairwise tensor is (B,C,C,H,hd) with C=16 — a few MB.
    """
    b, s, h, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))  # (B,S,H,hd) <= 0
    uf = u.astype(jnp.float32)

    resh = lambda a: a.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    rs, ks, vs, lws = resh(rf), resh(kf), resh(vf), resh(lw)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), -1)  # strict i<t

    def body(s0, inp):
        rc, kc, vc, lwc = inp  # (B,C,H,hd)
        L = jnp.cumsum(lwc, axis=1)  # inclusive
        L_ex = L - lwc  # exclusive (L_{t-1})
        rr = rc * jnp.exp(L_ex)  # <= |r|
        y_inter = jnp.einsum("bchk,bhkj->bchj", rr, s0)
        # stable pairwise decay: exponent <= 0 for every valid (t, i)
        delta = L_ex[:, :, None] - L[:, None]  # (B,C,C,H,hd), [t,i]
        delta = jnp.where(tri[None, :, :, None, None], delta, -jnp.inf)
        scores = jnp.einsum("bthk,bihk,btihk->bhti", rc, kc,
                            jnp.exp(delta))
        y_intra = jnp.einsum("bhti,bihj->bthj", scores, vc)
        diag = jnp.einsum("bchk,bchk->bch", rc, uf[None, None] * kc)
        y = y_inter + y_intra + diag[..., None] * vc
        k_tail = kc * jnp.exp(L[:, -1:] - L)  # <= |k|
        s1 = jnp.exp(L[:, -1])[..., None] * s0 + jnp.einsum(
            "bchk,bchj->bhkj", k_tail, vc
        )
        return s1, y

    state, ys = jax.lax.scan(body, state.astype(jnp.float32),
                             (rs, ks, vs, lws))
    # (n, B, C, H, hd) -> (B, S, H, hd)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return y, state


# sequence length at which the chunked form takes over from the plain scan
WKV_CHUNK_THRESHOLD = 64


def rwkv6_train(p, cfg: ModelConfig, x, positions=None):
    out, _ = rwkv6_prefill(p, cfg, x)
    return out


def rwkv6_prefill(p, cfg: ModelConfig, x):
    """Full-sequence time-mix; also returns (final wkv state, last input) —
    the O(1)-size decode cache pieces for this branch."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    x_prev = jnp.zeros((b, d), x.dtype)
    r, k, v, g, w = _mix_inputs(p, cfg, x, x_prev)
    state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if s >= WKV_CHUNK_THRESHOLD and s % 16 == 0:
        y, state = wkv_chunked(r, k, v, w, p["u"], state)
    else:
        y, state = wkv_scan(r, k, v, w, p["u"], state)
    y = rms_norm(y, p["head_norm"], cfg.rms_eps).astype(x.dtype)
    y = y.reshape(b, -1, d) * g.astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["w_o"])
    return out, {"state": state, "x_prev_tm": x[:, -1, :]}


def channel_mix_train(p, x, x_prev=None):
    b, _, d = x.shape
    xp = x_prev if x_prev is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, xp)
    xk = x + (xs - x) * p["mu"][0]
    xr = x + (xs - x) * p["mu"][1]
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]))
    return r * jnp.einsum("bsf,fd->bsd", k, p["w_v"])


# ---------------------------------------------------------------------------
# decode: O(1) state per layer = (wkv state, x_prev_timemix, x_prev_chanmix)
# ---------------------------------------------------------------------------
def init_rwkv6_cache(cfg: ModelConfig, batch: int, dtype):
    h, hd, d = cfg.n_heads, cfg.head_dim_, cfg.d_model
    return {
        "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_prev_tm": jnp.zeros((batch, d), dtype),
        "x_prev_cm": jnp.zeros((batch, d), dtype),
    }


def rwkv6_decode(p_tm, cfg: ModelConfig, x, cache):
    """x: (B,1,d). Returns (time-mix out, updated cache piece)."""
    b, _, d = x.shape
    r, k, v, g, w = _mix_inputs(p_tm, cfg, x, cache["x_prev_tm"])
    y, state = wkv_scan(r, k, v, w, p_tm["u"], cache["state"])
    y = rms_norm(y, p_tm["head_norm"], cfg.rms_eps).astype(x.dtype)
    y = y.reshape(b, 1, d) * g.astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p_tm["w_o"])
    return out, state, x[:, 0, :]


def channel_mix_decode(p_cm, x, x_prev):
    out = channel_mix_train(p_cm, x, x_prev)
    return out, x[:, 0, :]

"""Mixture-of-Experts MLP.

Two dispatch paths:

* ``_moe_apply_dense`` — single-device / no-mesh reference: top-k routing,
  position-in-expert via one-hot cumsum over ALL tokens, scatter into a
  dense (experts, capacity, d_model) buffer.  Correct everywhere, but on a
  sharded mesh the global cumsum is a cross-device prefix sum and the
  (N*k, d) replicated dispatch tensors dominate the roofline (measured:
  the deepseek-v3 train cell was the most collective-bound of the sweep).

* ``_moe_apply_ep`` — expert-parallel shard_map path used whenever a mesh
  is installed and experts divide (after padding) the model axis: every
  device routes its LOCAL tokens to its LOCAL expert shard (local cumsum,
  local capacity buffer, local grouped matmuls) and one psum over the
  ``model`` axis combines partial outputs.  No global prefix sum, no
  replicated (N*k, d) tensors, and the only collective is the same-sized
  all-reduce a dense TP MLP needs anyway.

DeepSeek-V3 details supported: 1 shared expert always on, softmax gating
over top-k renormalized probs, auxiliary load-balance loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import swiglu
from .sharding import ax, batch_axes_in, current_mesh, current_rules


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d**-0.5).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f)) * d**-0.5).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d, f)) * d**-0.5).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": (jax.random.normal(k1, (d, fs)) * d**-0.5).astype(dtype),
            "w3": (jax.random.normal(k2, (d, fs)) * d**-0.5).astype(dtype),
            "w2": (jax.random.normal(k3, (fs, d)) * fs**-0.5).astype(dtype),
        }
    return p


def moe_apply(p, cfg: ModelConfig, x):
    """x: (B,S,d) -> (out (B,S,d), aux load-balance loss)."""
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.shape["model"] > 1:
        return _moe_apply_ep(p, cfg, x, mesh)
    return _moe_apply_dense(p, cfg, x)


def _moe_apply_ep(p, cfg: ModelConfig, x, mesh):
    """Expert-parallel dispatch under shard_map (see module docstring).

    Experts are padded up to a multiple of the model axis when needed
    (granite's 40 -> 48 on a 16-way axis); padded experts get -inf router
    logits and all-zero weights, so they are never selected and cost only
    the pad ratio in expert-matmul FLOPs.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tp = mesh.shape["model"]
    e_pad = -(-e // tp) * tp
    router = p["router"]
    w1, w3, w2 = p["w1"], p["w3"], p["w2"]
    if e_pad != e:
        router = jnp.pad(router, [(0, 0), (0, e_pad - e)])
        pad_e = [(0, e_pad - e), (0, 0), (0, 0)]
        w1, w3, w2 = (jnp.pad(w, pad_e) for w in (w1, w3, w2))
    batch_ax = batch_axes_in()
    if batch_ax is not None and b % _axsize(mesh, batch_ax) != 0:
        batch_ax = None
    other = tuple(a for a in mesh.axis_names if a != "model")
    cap_loc = max(
        int((b // max(_axsize(mesh, batch_ax), 1)) * s * k / e
            * cfg.capacity_factor),
        1,
    )

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(
            P(batch_ax, None, None),  # x: tokens local to the data shard
            P(None, None),  # router replicated
            P("model", None, None),  # expert weights: EP over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(batch_ax, None, None), P()),
        check_vma=False,
    )
    def body(xb, router_b, w1b, w3b, w2b):
        bl, sl, _ = xb.shape
        n = bl * sl
        xf = xb.reshape(n, d)
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router_b)
        logits = jnp.where(jnp.arange(e_pad) < e, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_ids = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        ce = jax.nn.one_hot(top_ids[:, 0], e_pad).mean(0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, other) if other else aux
        aux = jax.lax.pmean(aux, "model")  # identical on every model rank

        e_loc = e_pad // tp
        rank = jax.lax.axis_index("model")
        lo = rank * e_loc
        flat_ids = top_ids.reshape(n * k)
        gate = top_p.reshape(n * k).astype(xb.dtype)
        mine = (flat_ids >= lo) & (flat_ids < lo + e_loc)
        le = jnp.where(mine, flat_ids - lo, 0)

        # LOCAL position-in-expert: cumsum over this shard's tokens only
        oh = jax.nn.one_hot(le, e_loc, dtype=jnp.int32) * mine[:, None]
        pos = jnp.cumsum(oh, axis=0) - oh
        flat_pos = jnp.take_along_axis(pos, le[:, None], 1)[:, 0]
        keep = mine & (flat_pos < cap_loc)
        flat_pos = jnp.where(keep, flat_pos, 0)

        # index-only dispatch: scatter TOKEN IDS into slots (4-byte ints),
        # then gather token vectors slot-wise — data movement is
        # capacity-sized, never (N*k, d)-sized
        le_oob = jnp.where(keep, le, e_loc)  # OOB rows drop
        tok_of = jnp.full((e_loc, cap_loc), n, jnp.int32).at[
            le_oob, flat_pos
        ].set(jnp.arange(n * k, dtype=jnp.int32) // k, mode="drop")
        gate_of = jnp.zeros((e_loc, cap_loc), xb.dtype).at[
            le_oob, flat_pos
        ].set(gate, mode="drop")
        buf = jnp.take(xf, jnp.clip(tok_of, 0, n - 1).reshape(-1), axis=0)
        buf = buf.reshape(e_loc, cap_loc, d)

        h = jnp.einsum("ecd,edf->ecf", buf, w1b)
        g = jnp.einsum("ecd,edf->ecf", buf, w3b)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, w2b)

        # combine: scatter-add slots back to their tokens (empty slots have
        # gate 0 and an OOB token id -> dropped)
        part = jnp.zeros((n + 1, d), xb.dtype).at[
            tok_of.reshape(-1)
        ].add((y * gate_of[..., None]).reshape(-1, d), mode="drop")[:n]
        out = jax.lax.psum(part, "model")
        return out.reshape(bl, sl, d), aux

    out, aux = body(x, router, w1, w3, w2)
    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + swiglu(x, sp["w1"], sp["w3"], sp["w2"])
    return out, aux


def _axsize(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _moe_apply_dense(p, cfg: ModelConfig, x):
    """Reference dispatch (no mesh): global one-hot cumsum + dense buffer."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    top_p, top_ids = jax.lax.top_k(probs, k)  # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(0)  # mean router prob per expert
    ce = jax.nn.one_hot(top_ids[:, 0], e).mean(0)  # top-1 dispatch fraction
    aux = e * jnp.sum(me * ce)

    capacity = max(int(n * k / e * cfg.capacity_factor), 1)

    flat_ids = top_ids.reshape(n * k)  # expert of each (token, slot)
    flat_gate = top_p.reshape(n * k).astype(x.dtype)
    oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # (N*k, E)
    pos = jnp.cumsum(oh, axis=0) - oh  # position within expert
    flat_pos = jnp.take_along_axis(pos, flat_ids[:, None], 1)[:, 0]
    keep = flat_pos < capacity
    flat_pos = jnp.where(keep, flat_pos, 0)

    x_rep = jnp.repeat(xf, k, axis=0)  # (N*k, d) token per slot
    contrib = jnp.where(keep[:, None], x_rep, 0)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[flat_ids, flat_pos].add(contrib, mode="drop")
    buf = ax(buf, "experts", None, None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = jax.nn.silu(h) * g
    y = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    y = ax(y, "experts", None, None)

    gathered = y[flat_ids, flat_pos]  # (N*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0) * flat_gate[:, None]
    out = gathered.reshape(n, k, d).sum(1).reshape(b, s, d)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + swiglu(x, sp["w1"], sp["w3"], sp["w2"])
    return out, aux

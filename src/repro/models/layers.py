"""Shared model primitives: norms, RoPE, MLPs, embeddings, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import ax


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w1, w3, w2, b1=None, b3=None, b2=None):
    """SwiGLU MLP: w2( silu(x w1) * (x w3) )."""
    h = jnp.einsum("...d,df->...f", x, w1)
    g = jnp.einsum("...d,df->...f", x, w3)
    if b1 is not None:
        h = h + b1
        g = g + b3
    h = jax.nn.silu(h) * g
    h = ax(h, "batch", None, "ff") if h.ndim == 3 else h
    out = jnp.einsum("...f,fd->...d", h, w2)
    if b2 is not None:
        out = out + b2
    return out


def init_linear(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def cross_entropy_loss(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Stable softmax XENT; logits (B, S, V) possibly vocab-sharded."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def chunked_ce_loss(
    x: jnp.ndarray,  # final hidden states (B, S, d)
    head: jnp.ndarray,  # (d, V)
    labels: jnp.ndarray,  # (B, S)
    chunk: int = 512,
) -> jnp.ndarray:
    """Cross entropy WITHOUT materializing the full (B, S, V) logits.

    Scans over sequence chunks; the chunk body is rematted so the backward
    pass recomputes each chunk's logits instead of stashing them — peak
    logits memory drops from O(S*V) to O(chunk*V).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        xc, lc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, head)
        logits = ax(logits, "batch", None, "vocab").astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + (logz - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)

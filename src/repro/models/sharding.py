"""Logical-axis sharding annotations.

Model code annotates activations with LOGICAL axis names; the launcher
installs a rules table mapping logical names -> mesh axes.  On CPU tests no
rules are installed and every annotation is a no-op, so the same model code
runs everywhere.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> dict[str, tuple[str, ...] | str | None] | None:
    return getattr(_state, "rules", None)


def current_mesh():
    return getattr(_state, "mesh", None)


@contextmanager
def logical_sharding(mesh, rules: dict[str, tuple[str, ...] | str | None]):
    """rules: logical axis name -> mesh axis (or tuple of axes, or None)."""
    old_rules = getattr(_state, "rules", None)
    old_mesh = getattr(_state, "mesh", None)
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old_rules, old_mesh


def batch_axes_in():
    """Mesh axis (or tuple) the logical 'batch' axis maps to, or None."""
    rules = current_rules() or {}
    return rules.get("batch")


def spec_for(*logical_names: str | None) -> P:
    rules = current_rules() or {}
    return P(*(rules.get(n) if n is not None else None for n in logical_names))


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def ax(x: jax.Array, *logical_names: str | None) -> jax.Array:
    """Annotate activation ``x`` (rank must match names; None = replicated).

    Axes whose dim doesn't divide the mesh axis are dropped (replicated)
    rather than sharded raggedly — a ragged constraint makes GSPMD fall
    back to full rematerialization (e.g. 2 kv heads over a 16-way model
    axis).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(*logical_names)
    cleaned = tuple(
        axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None
        for axis, dim in zip(spec, x.shape)
    )
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned))
    )


# standard rules tables -------------------------------------------------------
def single_pod_rules() -> dict[str, tuple[str, ...] | str | None]:
    return {
        "batch": "data",
        "seq": None,
        "seq_sp": "model",  # sequence parallelism for long prefill
        "d_model": None,
        "d_model_fsdp": "data",  # param d_model dim: ZeRO-3 over data
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "layers": None,
        "state": None,
    }


def multi_pod_rules(pipeline: bool = False) -> dict[str, tuple[str, ...] | str | None]:
    rules = single_pod_rules()
    if pipeline:
        rules["layers"] = "pod"  # pipeline stages over the pod axis
    else:
        rules["batch"] = ("pod", "data")  # pod axis joins data parallelism
    return rules

"""Device-resident tile arena (ISSUE 3 tentpole piece 1).

PR 2 re-packed every request batch on the host: each call concatenated the
requested users' decoded heap tiles, re-padded them to a common heap width,
and re-uploaded the result.  The arena moves that work OFF the request
path: a user's decoded tiles are fused + padded + uploaded ONCE into a
persistent device buffer, and ``pack_request_batch`` degenerates to an
int32 row-index gather (``jnp.take`` along the tree axis) — no host
concatenation, no re-padding, no re-upload for warm users.

Layout: trees from all resident users pack row-contiguously into two
device arrays at the arena's common (padded) heap width —

* ``code``  (T_resident, H) float32 — FUSED node attributes
  ``(feature * TB + threshold) * 2 + is_internal`` (the pipelined kernel's
  single-gather-per-level layout, exact below 2**24);
* ``fit``   (T_resident, H) float32 — leaf payloads (class ids or fits).

The width grows monotonically as deeper users are admitted (rare: one
``jnp.pad`` rebuild); admission appends rows; eviction compacts survivors
with one device gather.  Eviction is DECODE-COST-WEIGHTED (GreedyDual):
each run's priority is ``clock + trees * 2**depth`` at admission/access,
the minimum-priority non-pinned run is evicted first, and the clock
advances to the evicted priority — deep users (expensive to re-decode and
re-upload) outlive shallow ones at equal recency, and equal costs reduce
to plain LRU.

Every structural change (admission, eviction/compaction, width growth,
invalidation) bumps a monotonic ``epoch`` (an observability counter for
``stats()``).  Cache validity is finer-grained: each resident run carries
a per-run admission ``token`` (``run_token``), and the serving session's
``PlanCache`` validates a memoized cross-batch gather against exactly the
tokens of the users it covers — so evicting or re-admitting one user
invalidates only the packs containing that user, while compaction and
width growth (which leave gathered COPIES valid) invalidate nothing.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..kernels.tree_predict.tree_predict import (
    fuse_node_attrs,
    fused_code_limit,
    fused_threshold_base,
)
from .policy import GreedyDualClock, decode_cost

_F32_EXACT_INT = 1 << 24

Tile = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


class _Run:
    __slots__ = (
        "start", "n_trees", "cost", "priority", "last_access", "h", "depth",
        "token",
    )

    def __init__(self, start, n_trees, cost, priority, last_access, h,
                 depth, token):
        self.start = start
        self.n_trees = n_trees
        self.cost = cost
        self.priority = priority
        self.last_access = last_access
        self.h = h  # the run's OWN heap width (pre arena padding)
        self.depth = depth
        self.token = token  # admission id: per-run validity token


class TileArena:
    """Persistent padded-width device buffer of fused heap tiles, keyed by
    user run, with decode-cost-weighted (GreedyDual) eviction."""

    def __init__(
        self, n_features: int, threshold_base: int,
        capacity_trees: int = 16384,
    ) -> None:
        if fused_code_limit(n_features, threshold_base) >= _F32_EXACT_INT:
            raise ValueError(
                f"fused code word for d={n_features}, TB={threshold_base} "
                "exceeds 2**24; the arena's packed layout would corrupt"
            )
        self.n_features = n_features
        self.tb = threshold_base
        self.tb2 = 2 * threshold_base
        self.capacity_trees = capacity_trees
        self.max_depth = 0
        self.h = 0  # common padded heap width of the resident buffers
        self._code = None  # (T_resident, h) f32 device
        self._fit = None  # (T_resident, h) f32 device
        self._runs: dict[str, _Run] = {}
        self._gd = GreedyDualClock()
        self.admissions = 0
        self.evictions = 0
        self.gathers = 0
        self.compactions = 0
        self.epoch = 0  # bumped on any structural change (see module doc)
        # LAZY defragmentation (ISSUE 10): eviction/invalidation only
        # MARKS rows dead (O(1) per victim — gathers are index-based, so
        # holes are skipped naturally); the O(arena) compaction gather is
        # deferred until dead rows block an admission or cross this
        # fraction of capacity.
        self.dead_trees = 0
        self.defrag_threshold = 0.25
        # fault-injection hook: when set, called with the cold users'
        # ids at the top of admit_many, BEFORE any state mutates — see
        # runtime.chaos.TransientFaults and ForestServer's retry path
        self.admission_fault = None

    # ---------------- bookkeeping -----------------------------------------
    def __contains__(self, user_id: str) -> bool:
        return user_id in self._runs

    def run_token(self, user_id: str) -> int | None:
        """Per-run validity token: the admission id of the user's resident
        run, or ``None`` when the user is not resident.  A memoized
        cross-batch gather is valid exactly while every one of its users'
        tokens is unchanged — eviction or re-admission of one user
        invalidates only the packs containing that user (the serving
        session's partial invalidation), instead of the arena-wide
        ``epoch`` sweep."""
        run = self._runs.get(user_id)
        return None if run is None else run.token

    @property
    def resident_trees(self) -> int:
        return sum(r.n_trees for r in self._runs.values())

    @property
    def buffer_trees(self) -> int:
        """Physical device rows (live runs + not-yet-reclaimed dead rows)
        — the arena's true device footprint between compactions."""
        return 0 if self._code is None else int(self._code.shape[0])

    def stats(self) -> dict:
        """Occupancy and admission/eviction/gather counters."""
        return {
            "resident_users": len(self._runs),
            "resident_trees": self.resident_trees,
            "buffer_trees": self.buffer_trees,
            "dead_trees": self.dead_trees,
            "heap_width": self.h,
            "admissions": self.admissions,
            "evictions": self.evictions,
            "gathers": self.gathers,
            "compactions": self.compactions,
            "epoch": self.epoch,
        }

    def touch_users(self, users: Sequence[str]) -> None:
        """Record an access for resident runs WITHOUT gathering — a batch
        served from a memoized cross-batch pack must still refresh its
        users' eviction priorities."""
        for user_id in users:
            run = self._runs.get(user_id)
            if run is not None:
                self._touch(run)

    def invalidate(self, user_id: str) -> None:
        """Evict one user's resident run (delta replacement or residency
        demotion).  O(touched run): the rows are only MARKED dead — the
        compaction gather is deferred (``_maybe_compact``)."""
        run = self._runs.pop(user_id, None)
        if run is not None:
            self.dead_trees += run.n_trees
            self.epoch += 1
            self._maybe_compact()

    # ---------------- admission / eviction --------------------------------
    def _touch(self, run: _Run) -> None:
        run.priority, run.last_access = self._gd.touch(run.cost)

    def _compact(self) -> None:
        """Rebuild the device buffers with only surviving runs (one gather
        per attribute), re-basing every run's start offset and SHRINKING
        the common width/depth back to the survivors' maximum — evicting
        the one deep user must not inflate every later batch forever."""
        import jax.numpy as jnp

        self.epoch += 1
        self.compactions += 1
        self.dead_trees = 0
        if not self._runs:
            self._code = self._fit = None
            self.h = 0
            self.max_depth = 0
            return
        idx_parts, off = [], 0
        for run in self._runs.values():
            idx_parts.append(np.arange(run.start, run.start + run.n_trees))
            run.start = off
            off += run.n_trees
        idx = jnp.asarray(np.concatenate(idx_parts), jnp.int32)
        self.h = max(run.h for run in self._runs.values())
        self.max_depth = max(run.depth for run in self._runs.values())
        self._code = jnp.take(self._code, idx, axis=0)[:, : self.h]
        self._fit = jnp.take(self._fit, idx, axis=0)[:, : self.h]

    def _maybe_compact(self) -> None:
        """Reclaim dead rows once they cross ``defrag_threshold`` of
        capacity — bounding the footprint overhead of lazy eviction
        while amortizing the O(arena) gather over many retirements.
        Shape overhang compacts IMMEDIATELY: when the victim was the
        width/depth-determining run, every later batch would pay its
        padded width forever, so that (rare) case is worth the eager
        gather."""
        if not self.dead_trees:
            return
        if not self._runs:
            self._compact()
            return
        overhang = (
            max(run.h for run in self._runs.values()) < self.h
            or max(run.depth for run in self._runs.values())
            < self.max_depth
        )
        if (
            overhang
            or self.dead_trees
            >= self.defrag_threshold * self.capacity_trees
        ):
            self._compact()

    def _evict_for(self, need: int, pinned: set[str]) -> None:
        """GreedyDual: evict minimum-priority non-pinned runs until ``need``
        trees fit (ties broken oldest-access-first), advancing the clock.
        Victims' rows are marked dead, not compacted — but if the holes
        would push the PHYSICAL buffer past capacity after the append,
        one compaction reclaims them (capacity honesty: the device
        footprint bound holds at every admission)."""
        victims = []
        resident = self.resident_trees
        while resident + need > self.capacity_trees:
            candidates = [
                (r.priority, r.last_access, u)
                for u, r in self._runs.items() if u not in pinned
            ]
            if not candidates:
                break  # working set itself exceeds capacity: let it grow
            prio, _, user = min(candidates)
            run = self._runs.pop(user)
            resident -= run.n_trees
            self.dead_trees += run.n_trees
            self._gd.evicted(prio)
            victims.append(user)
            self.evictions += 1
        if victims:
            self.epoch += 1
        if self.dead_trees and self.buffer_trees + need > self.capacity_trees:
            self._compact()

    def _grow_width(self, h_new: int, max_depth: int) -> None:
        import jax.numpy as jnp

        if self._code is not None and h_new > self.h:
            pad = ((0, 0), (0, h_new - self.h))
            self._code = jnp.pad(self._code, pad)
            self._fit = jnp.pad(self._fit, pad)
        self.h = max(self.h, h_new)
        self.max_depth = max(self.max_depth, max_depth)

    def admit_many(
        self,
        items: Sequence[tuple[str, Sequence[Tile], int]],
        pinned: set[str] | None = None,
    ) -> None:
        """Fuse + pad + upload several users' decoded heap tiles in ONE
        eviction pass and ONE buffer append (a cold fleet sweep costs one
        device concatenate, not one per user).  ``items`` holds
        ``(user_id, tiles, max_depth)`` triples; already-resident users are
        just touched."""
        import jax.numpy as jnp

        if self.admission_fault is not None:
            # fault-injection hook (runtime.chaos.TransientFaults): raises
            # TransientError BEFORE any arena state mutates, modeling a
            # failed device upload — the serving retry path depends on
            # admission being all-or-nothing
            self.admission_fault([u for u, _, _ in items])
        fused: list[tuple[str, np.ndarray, np.ndarray, int]] = []
        for user_id, tiles, max_depth in items:
            if user_id in self._runs:
                self._touch(self._runs[user_id])
                continue
            feats, thrs, fits, inters = (
                [t[k] for t in tiles] for k in range(4)
            )
            feature = np.concatenate(feats)
            threshold = np.concatenate(thrs)
            fit = np.concatenate(fits).astype(np.float32)
            inter = np.concatenate(inters)
            if int(threshold.max(initial=0)) >= self.tb:
                raise ValueError(
                    f"user {user_id!r} threshold symbols exceed the "
                    f"arena's field width TB={self.tb}"
                )
            fused.append(
                (user_id, fuse_node_attrs(feature, threshold, inter,
                                          self.tb),
                 fit, max_depth)
            )
        if not fused:
            return
        if pinned is None:
            pinned = {u for u, _, _, _ in fused}
        t_new = sum(c.shape[0] for _, c, _, _ in fused)
        self._evict_for(t_new, pinned)
        for _, code, _, max_depth in fused:
            self._grow_width(code.shape[1], max_depth)

        from ..serving.pack import pad_heap_width  # canonical pad helper

        code_rows = np.concatenate(
            [pad_heap_width(c, self.h) for _, c, _, _ in fused]
        )
        fit_rows = np.concatenate(
            [pad_heap_width(f, self.h) for _, _, f, _ in fused]
        )
        start = 0 if self._code is None else int(self._code.shape[0])
        if self._code is None:
            self._code = jnp.asarray(code_rows)
            self._fit = jnp.asarray(fit_rows)
        else:
            self._code = jnp.concatenate(
                [self._code, jnp.asarray(code_rows)]
            )
            self._fit = jnp.concatenate([self._fit, jnp.asarray(fit_rows)])
        for user_id, code, _, max_depth in fused:
            t_u, h_u = code.shape
            cost = decode_cost(t_u, h_u)
            prio, tick = self._gd.touch(cost)
            self.admissions += 1
            self._runs[user_id] = _Run(
                start, t_u, cost, prio, tick, h_u, max_depth,
                token=self.admissions,
            )
            start += t_u
        self.epoch += 1

    def admit(
        self, user_id: str, tiles: Sequence[Tile], max_depth: int,
        pinned: set[str] | None = None,
    ) -> None:
        """Fuse + pad + upload one user's decoded heap tiles (the expensive
        one-time step the per-request path no longer pays)."""
        self.admit_many([(user_id, tiles, max_depth)], pinned=pinned)

    # ---------------- the hot path ----------------------------------------
    def gather(
        self, users: Sequence[str], block_trees: int = 8,
        pad_to: int | None = None,
        seg_ids: Sequence[int] | None = None,
    ):
        """Index-gather the requested users' resident runs into one packed
        (T_pad, H) pair of device arrays plus host segment ids.

        Returns ``(code, fit, tree_seg, counts)`` where ``tree_seg[r]`` is
        the position of row r's user in ``users`` (-1 for padding rows;
        override per-user ids with ``seg_ids`` — the sharded path keeps
        GLOBAL segment ids on per-shard gathers) and ``counts[s]`` is user
        s's tree count.  ``T_pad`` is padded up to a multiple of
        ``block_trees`` (or to ``pad_to``) so the pipelined kernel sees a
        handful of distinct shapes."""
        import jax.numpy as jnp

        idx_parts, seg_parts, counts = [], [], []
        for s, user_id in enumerate(users):
            run = self._runs[user_id]
            self._touch(run)
            idx_parts.append(np.arange(run.start, run.start + run.n_trees))
            seg = s if seg_ids is None else int(seg_ids[s])
            seg_parts.append(np.full(run.n_trees, seg, np.int32))
            counts.append(run.n_trees)
        idx = (
            np.concatenate(idx_parts)
            if idx_parts else np.zeros(0, np.int64)
        )
        t = len(idx)
        t_pad = max(-(-t // block_trees) * block_trees, block_trees)
        if pad_to is not None:
            if pad_to % block_trees or pad_to < t_pad:
                raise ValueError(
                    f"pad_to={pad_to} must be a multiple of block_trees "
                    f">= {t_pad}"
                )
            t_pad = pad_to
        idx = np.pad(idx, (0, t_pad - t))  # pad rows re-read row 0 ...
        tree_seg = np.full(t_pad, -1, np.int32)  # ... but never match a row
        if t:
            tree_seg[:t] = np.concatenate(seg_parts)
        didx = jnp.asarray(idx, jnp.int32)
        self.gathers += 1
        return (
            jnp.take(self._code, didx, axis=0),
            jnp.take(self._fit, didx, axis=0),
            tree_seg,
            np.asarray(counts, np.int64),
        )

"""Multi-tenant forest store runtime (store piece 3).

``ForestStore`` is the registry: one fleet ``SharedCodebook`` plus one
``UserDelta`` per user, all byte-honest.  Decoded artifacts are cached at
two levels:

* hydrated ``CompressedForest`` objects (cheap: codebook resolution only,
  no entropy decode) — a plain dict, they are small;
* decoded HEAP TILES, keyed ``(user, block_trees, tile_index)`` in a
  tree-count-bounded LRU (``TileCache``) — these are the expensive
  artifacts (full Huffman/LZW/arithmetic decode of the user's streams), so
  hot users skip entropy decode entirely on repeat requests while cold
  users cost at most one decode each before eviction.

Serving goes through ``repro.serving.ForestServer``, which packs many
users' cached tiles into one ragged segment-aware Pallas kernel launch;
the codebook LIFECYCLE (generations, drift, re-clustering, migration)
lives in ``store.lifecycle`` and this registry keeps every codebook
generation its deltas still reference.
"""
from __future__ import annotations

import io
import zlib
from collections import OrderedDict
from typing import Iterable, Sequence

import numpy as np

from ..core.forest_codec import CompressedForest
from ..core.framing import (
    IntegrityError,
    check_crc,
    expect_magic,
    read_bytes,
    read_u16,
    read_u32,
    with_crc,
    write_bytes,
    write_u16,
    write_u32,
)
from ..core.tree import Forest
from .codebook import SharedCodebook, build_shared_codebook
from .delta import UserDelta, encode_user_delta, hydrate, reconstruct_user
from .policy import GreedyDualClock, decode_cost

_MAGIC = b"RFT1"

Tile = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def make_schema_arena(
    n_features: int,
    n_bins_per_feature: np.ndarray,
    capacity_trees: int = 16384,
):
    """Device tile arena for a schema, or ``None`` when the schema's fused
    code word would overflow 2**24 (serving then falls back to
    ``engine="simple"``).  Shared by ``ForestStore`` and the single-forest
    serving session."""
    from ..kernels.tree_predict.tree_predict import fused_threshold_base
    from .arena import TileArena

    try:
        return TileArena(
            n_features,
            fused_threshold_base(int(np.max(n_bins_per_feature)) - 1),
            capacity_trees=capacity_trees,
        )
    except ValueError:
        return None


class TileCache:
    """Decoded heap-tile cache, bounded by total resident TREES (a tile of
    t trees at heap width h costs ~t * h * 13 bytes; trees are the stable
    unit across users of different depths).

    Eviction is DECODE-COST-WEIGHTED (GreedyDual, ISSUE 3 satellite; the
    policy core is shared with the device tile arena — see
    ``store.policy``): a tile's priority is ``clock + trees * 2**depth``
    at insert/access — the reconstruction cost of the entropy decode it
    saves — the minimum-priority tile goes first (ties: least recently
    used), and the clock advances to each evicted priority so long-idle
    expensive tiles age out eventually.  Equal costs reduce exactly to
    LRU.  Per-user hit/miss counters feed admission-control decisions
    (``stats()``)."""

    def __init__(self, capacity_trees: int = 4096) -> None:
        self.capacity_trees = capacity_trees
        self._tiles: OrderedDict[tuple, Tile] = OrderedDict()
        self._prio: dict[tuple, tuple[float, int]] = {}
        self._gd = GreedyDualClock()
        self._resident_trees = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._per_user: dict[str, list[int]] = {}  # user -> [hits, misses]

    def __len__(self) -> int:
        return len(self._tiles)

    def __contains__(self, key: tuple) -> bool:
        return key in self._tiles

    @staticmethod
    def _cost(tile: Tile) -> float:
        t, h = tile[0].shape
        return decode_cost(t, h)

    def _user_stat(self, key: tuple) -> list[int]:
        return self._per_user.setdefault(str(key[0]), [0, 0])

    def _touch(self, key: tuple, tile: Tile) -> None:
        self._prio[key] = self._gd.touch(self._cost(tile))
        self._tiles.move_to_end(key)

    def get(self, key: tuple) -> Tile | None:
        """The cached tile under ``key`` (refreshing its eviction
        priority), or ``None`` on a miss — both counted per user."""
        tile = self._tiles.get(key)
        if tile is None:
            self.misses += 1
            self._user_stat(key)[1] += 1
            return None
        self._touch(key, tile)
        self.hits += 1
        self._user_stat(key)[0] += 1
        return tile

    def record_decode_misses(self, user_id: str, n: int) -> None:
        """Count ``n`` tile decodes forced by a cold/partial run (the run
        probe in ``ForestStore.tiles`` bypasses per-tile ``get``)."""
        self.misses += n
        self._per_user.setdefault(user_id, [0, 0])[1] += n

    def put(self, key: tuple, tile: Tile) -> None:
        """Insert a decoded tile, evicting minimum-priority tiles until
        the resident-tree capacity holds."""
        if key in self._tiles:
            self._touch(key, tile)
            return
        self._tiles[key] = tile
        self._touch(key, tile)
        self._resident_trees += tile[0].shape[0]
        while (
            self._resident_trees > self.capacity_trees
            and len(self._tiles) > 1
        ):
            victim = min(
                (k for k in self._tiles if k != key),
                key=lambda k: self._prio[k],
            )
            prio, _ = self._prio.pop(victim)
            self._gd.evicted(prio)
            self._resident_trees -= self._tiles.pop(victim)[0].shape[0]
            self.evictions += 1

    def invalidate_user(self, user_id: str,
                        reset_stats: bool = True) -> None:
        """Drop every resident tile of one user.  ``reset_stats=True``
        (the delta-REPLACEMENT path, i.e. a ``user_version`` bump) also
        clears the user's hit/miss history — the new generation's hit
        rate must not be polluted by the old one's.  Residency DEMOTION
        passes ``reset_stats=False``: the content is unchanged, so the
        history stays meaningful across a reload."""
        stale = [k for k in self._tiles if k[0] == user_id]
        for k in stale:
            self._resident_trees -= self._tiles.pop(k)[0].shape[0]
            self._prio.pop(k, None)
        if reset_stats:
            self._per_user.pop(user_id, None)

    def stats(self) -> dict:
        """Cache occupancy and global + per-user hit/miss counters (the
        admission-control dashboard feed)."""
        per_user = {
            u: {
                "hits": h,
                "misses": m,
                "hit_rate": round(h / (h + m), 4) if h + m else 0.0,
            }
            for u, (h, m) in sorted(self._per_user.items())
        }
        return {
            "tiles": len(self._tiles),
            "resident_trees": self._resident_trees,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "per_user": per_user,
        }


class ForestStore:
    """Registry of per-user delta-encoded forests over one shared codebook.

    The shared codebook is a LIVING artifact (ISSUE 5): ``recluster`` in
    ``store.lifecycle`` installs a successor generation and migrates user
    deltas onto it one by one.  The store therefore keeps every codebook
    generation still referenced by at least one delta (``install_codebook``
    retains the superseded current; ``drop_unreferenced_codebooks`` garbage
    collects once the last delta migrates), and every decode path resolves
    a delta against the generation it was encoded for — old- and
    new-generation users serve side by side mid-migration.
    """

    def __init__(
        self, shared: SharedCodebook, tile_cache_trees: int = 4096,
        arena_capacity_trees: int = 16384,
    ) -> None:
        self.shared = shared
        # superseded codebook generations still referenced by >=1 delta
        self._retained: dict[int, SharedCodebook] = {}
        self._deltas: dict[str, UserDelta] = {}
        self._hydrated: dict[str, CompressedForest] = {}
        self._tile_counts: dict[tuple, int] = {}
        self.cache = TileCache(tile_cache_trees)
        # registry version: bumped on every registry mutation.  Serving
        # keys its memoized plans/packs on the finer-grained PER-USER
        # versions below, so migrating one user invalidates only that
        # user's cached artifacts (ROADMAP "plan-cache partial
        # invalidation").
        self.version = 0
        self._user_versions: dict[str, int] = {}
        # store-level lossy report (set by build_store(lossy=...))
        self.lossy: dict | None = None
        # crash-safe recluster journal (set by lifecycle.recluster /
        # resume_recluster); surfaced through ForestServer.stats()["health"]
        self.journal = None
        # residency budget manager (set by store.residency.attach_residency);
        # surfaced through ForestServer.stats()["residency"]
        self.residency = None
        # device-resident fused-tile arena for the pipelined serving path;
        # None when the schema's fused code word would overflow 2**24 (the
        # serving driver then falls back to engine="simple")
        self.arena = make_schema_arena(
            shared.n_features, shared.n_bins_per_feature,
            arena_capacity_trees,
        )

    # ---------------- codebook generations --------------------------------
    @property
    def generation(self) -> int:
        """Generation of the CURRENT codebook (new users encode against it)."""
        return self.shared.generation

    @property
    def generations(self) -> list[int]:
        """Every resident codebook generation, ascending (current last)."""
        return sorted(self._retained) + [self.shared.generation]

    def codebook_for(self, generation: int) -> SharedCodebook:
        """The resident codebook of ``generation`` (current or retained)."""
        if generation == self.shared.generation:
            return self.shared
        try:
            return self._retained[generation]
        except KeyError:
            raise KeyError(
                f"codebook generation {generation} is not resident "
                f"(have {self.generations})"
            ) from None

    def install_codebook(self, shared: SharedCodebook) -> None:
        """Install a successor codebook as the current generation.  The
        superseded codebook is RETAINED while any delta still references
        it (dropped automatically once the last one migrates); resident
        caches stay valid — no delta changed."""
        if shared.generation <= self.shared.generation:
            raise ValueError(
                f"successor generation {shared.generation} must exceed "
                f"current generation {self.shared.generation}"
            )
        self._retained[self.shared.generation] = self.shared
        self.shared = shared
        self.version += 1
        self.drop_unreferenced_codebooks()

    def referenced_generations(self) -> set[int]:
        """Codebook generations referenced by at least one registered delta."""
        return {d.codebook_generation for d in self._deltas.values()}

    def drop_unreferenced_codebooks(self) -> list[int]:
        """Garbage-collect retained codebooks no delta references anymore
        (the end state of a migration).  Returns the dropped generations."""
        live = self.referenced_generations()
        dropped = [g for g in self._retained if g not in live]
        for g in dropped:
            del self._retained[g]
        return dropped

    # ---------------- registry --------------------------------------------
    @property
    def user_ids(self) -> list[str]:
        return list(self._deltas)

    def __contains__(self, user_id: str) -> bool:
        return user_id in self._deltas

    def user_version(self, user_id: str) -> int:
        """Per-user registration version — the validity token serving keys
        its memoized plans and gathered packs on.  Bumped whenever the
        user's delta is replaced by content that decodes differently;
        relabel-only migrations (bit-identical artifact) keep it, so a
        warm session crossing a migration invalidates only re-encoded
        users' cached packs."""
        return self._user_versions.get(user_id, 0)

    def add_user(self, user_id: str, forest: Forest, seed: int = 0) -> UserDelta:
        """Delta-encode ``forest`` against the CURRENT shared codebook and
        register it.  Works for fleet members and late-onboarded users alike
        (the latter may carry user-local clusters)."""
        delta = encode_user_delta(forest, self.shared, seed=seed)
        self.add_delta(user_id, delta)
        return delta

    def add_delta(self, user_id: str, delta: UserDelta) -> None:
        """Register a delta (new user or re-registration), invalidating
        every cached artifact derived from the user's previous delta."""
        self.codebook_for(delta.codebook_generation)  # must be resident
        self._deltas[user_id] = delta
        self.version += 1
        self._user_versions[user_id] = self.version
        self._hydrated.pop(user_id, None)
        self._tile_counts = {
            k: v for k, v in self._tile_counts.items() if k[0] != user_id
        }
        self.cache.invalidate_user(user_id)
        if self.arena is not None:
            self.arena.invalidate(user_id)
        if self.residency is not None:
            self.residency.notify_registered(user_id, delta)

    def replace_delta_relabeled(self, user_id: str, delta: UserDelta) -> None:
        """Swap in a RELABELED delta — one whose decoded artifact is
        bit-identical to the resident one (cluster ids renamed onto a new
        codebook generation, streams untouched).  Decoded tiles, arena
        runs, and the user's serving version all survive: this is what
        lets a migration leave untouched users' warm state alone."""
        if user_id not in self._deltas:
            raise KeyError(f"unknown user {user_id!r}")
        self.codebook_for(delta.codebook_generation)  # must be resident
        self._deltas[user_id] = delta
        self.version += 1
        # drop only the cheap hydrated object: it holds a reference to the
        # old generation's fit table; tiles/arena/packs are value-identical
        self._hydrated.pop(user_id, None)
        if self.residency is not None:
            # the decoded artifact is identical but the SERIALIZED bytes
            # are not (new generation's cluster ids): the disk shard no
            # longer matches, so demotion must write back first
            self.residency.notify_registered(user_id, delta)

    def delta(self, user_id: str) -> UserDelta:
        """The registered ``UserDelta`` for one user."""
        return self._deltas[user_id]

    def n_trees(self, user_id: str) -> int:
        """Tree count of one user's forest (from the delta header — no
        decode)."""
        return self._deltas[user_id].n_trees

    def max_depth(self, user_id: str) -> int:
        """Max tree depth of one user's forest (from the delta header)."""
        return self._deltas[user_id].max_depth

    # ---------------- decode paths ----------------------------------------
    def hydrate(self, user_id: str) -> CompressedForest:
        """Resolve one user's delta into an inline ``CompressedForest``
        (cached; codebook resolution only, no entropy decode), against the
        codebook generation the delta references."""
        res = self.residency
        if res is None:
            return self._hydrate_cached(user_id)
        res.touch(user_id)
        # pin across load + cache fill: budget enforcement (which can run
        # inside the lazy load's notify) must not demote the user
        # mid-hydrate — that would strand a decoded artifact in
        # ``_hydrated`` the demotion can no longer invalidate
        with res.pin((user_id,)):
            return self._hydrate_cached(user_id)

    def _hydrate_cached(self, user_id: str) -> CompressedForest:
        comp = self._hydrated.get(user_id)
        if comp is None:
            delta = self._deltas[user_id]
            comp = hydrate(delta, self.codebook_for(delta.codebook_generation))
            self._hydrated[user_id] = comp
        return comp

    def reconstruct(self, user_id: str) -> Forest:
        """Bit-exact original forest for this user."""
        delta = self._deltas[user_id]
        return reconstruct_user(
            delta, self.codebook_for(delta.codebook_generation)
        )

    def predict(self, user_id: str, x_binned: np.ndarray) -> np.ndarray:
        """Serve one user's predictions via the decode-side reference path
        (``predict_compressed``) — the oracle the kernels are checked
        against."""
        from ..core.compressed_predict import predict_compressed

        return predict_compressed(self.hydrate(user_id), x_binned)

    def tiles(self, user_id: str, block_trees: int = 32) -> list[Tile]:
        """Decoded heap tiles for one user, LRU-cached by (user, tile) so a
        hot user's repeat requests skip entropy decode entirely."""
        if self.residency is not None:
            self.residency.touch(user_id)
        run_key = (user_id, block_trees)
        n = self._tile_counts.get(run_key)
        if n is not None:
            keys = [(user_id, block_trees, i) for i in range(n)]
            # count hits only when the WHOLE run is resident — a partially
            # evicted run falls through to a full re-decode, so probing it
            # must not inflate the hit stats
            if all(k in self.cache for k in keys):
                return [self.cache.get(k) for k in keys]  # type: ignore[misc]
        from ..serving.pack import iter_heap_tiles

        tiles = list(iter_heap_tiles(self.hydrate(user_id), block_trees))
        self.cache.record_decode_misses(user_id, len(tiles))
        self._tile_counts[run_key] = len(tiles)
        for i, t in enumerate(tiles):
            self.cache.put((user_id, block_trees, i), t)
        return tiles

    def arena_pack(
        self, users: Sequence[str], block_trees: int = 8,
        pad_to: int | None = None, seg_ids: Sequence[int] | None = None,
    ):
        """Ensure every requested user is resident in the device tile arena
        (cold users pay one decode + fuse + upload), then INDEX-GATHER their
        runs into one packed (T_pad, H) device pair — the pipelined serving
        path's replacement for per-call host packing.

        Returns ``(code, fit, tree_seg, counts, max_depth)`` where
        ``max_depth`` is the arena-wide depth matching the common heap
        width (traversing a shallower user's trees at the arena depth just
        idles at leaves — results are unchanged)."""
        self.arena_ensure(users, block_trees)
        code, fit, tree_seg, counts = self.arena.gather(
            users, block_trees, pad_to=pad_to, seg_ids=seg_ids
        )
        return code, fit, tree_seg, counts, self.arena.max_depth

    def arena_ensure(
        self, users: Sequence[str], block_trees: int = 8
    ) -> None:
        """Admit every non-resident user in ONE arena append.  Callers that
        gather in several pieces (the sharded engine) MUST ensure the whole
        working set first: admissions can grow the arena's common heap
        width, which would leave earlier gathers at a stale width."""
        if self.arena is None:
            raise ValueError(
                "store schema is incompatible with the fused tile arena"
            )
        missing = [u for u in users if u not in self.arena]
        if missing:  # one eviction pass + one buffer append for the batch
            self.arena.admit_many(
                [
                    (u, self.tiles(u, block_trees), self.max_depth(u))
                    for u in missing
                ],
                pinned=set(users),
            )

    # ---------------- drift observability ---------------------------------
    def drift_stats(self, exclude: tuple = ()) -> dict:
        """Codebook-lifecycle drift summary (generation, fallback-cluster
        fraction, fallback byte overhead) for dashboards —
        ``ForestServer.stats()`` surfaces this without reaching into store
        internals.  Memoized per (registry version, exclude set): the
        underlying ``drift_report`` re-serializes every delta, which a
        polling dashboard must not pay per call.  ``exclude`` names users
        to drop from the accounting (the serving layer passes its
        quarantined users — their deltas cannot be decoded).  Full
        report: ``store.lifecycle.drift_report``."""
        exclude = tuple(sorted(exclude))
        cached = getattr(self, "_drift_stats_cache", None)
        if cached is not None and cached[0] == (self.version, exclude):
            return cached[1]
        from .lifecycle import drift_report

        rep = drift_report(self, exclude=exclude)
        stats = {
            "codebook_generation": rep["codebook_generation"],
            "generations": rep["generations"],
            "n_users": rep["n_users"],
            "n_excluded_users": rep["n_excluded_users"],
            "fallback_user_fraction": rep["fallback_user_fraction"],
            "fallback_overhead_fraction": rep["fallback_overhead_fraction"],
        }
        self._drift_stats_cache = ((self.version, exclude), stats)
        return stats

    # ---------------- sizes + serialization -------------------------------
    def size_report(self) -> dict:
        """Byte accounting of everything the store would persist: every
        resident codebook generation (current + retained-for-migration)
        plus all user deltas."""
        shared_bytes = len(self.shared.to_bytes())
        retained_bytes = {
            g: len(cb.to_bytes()) for g, cb in sorted(self._retained.items())
        }
        per_user = {u: len(d.to_bytes()) for u, d in self._deltas.items()}
        return {
            "n_users": len(self._deltas),
            "codebook_generation": self.shared.generation,
            "shared_codebook_bytes": shared_bytes,
            "retained_codebook_bytes": retained_bytes,
            "user_delta_bytes_total": sum(per_user.values()),
            "total_bytes": (
                shared_bytes
                + sum(retained_bytes.values())
                + sum(per_user.values())
            ),
            "per_user_bytes": per_user,
            "lossy": self.lossy,
        }

    def to_bytes(self) -> bytes:
        """Serialize as one RFT1 frame (normative spec: docs/format.md):
        every resident codebook ascending by generation — the LAST is the
        current one — then the user deltas."""
        out = io.BytesIO()
        out.write(_MAGIC)
        codebooks = [self._retained[g] for g in sorted(self._retained)]
        codebooks.append(self.shared)
        write_u16(out, len(codebooks))
        for cb in codebooks:
            write_bytes(out, cb.to_bytes())
        write_u32(out, len(self._deltas))
        for user_id, delta in sorted(self._deltas.items()):
            write_bytes(out, user_id.encode("utf-8"))
            write_bytes(out, delta.to_bytes())
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(
        cls, data: bytes, tile_cache_trees: int = 4096
    ) -> "ForestStore":
        """Parse one RFT1 frame (normative spec: docs/format.md).  The
        CRC32 trailer is verified when present; corruption raises a typed
        ``core.framing.IntegrityError`` / ``TruncatedFrameError``."""
        inp = io.BytesIO(check_crc(data, "RFT1 store"))
        expect_magic(inp, _MAGIC, "RFT1 store")
        n_cb = read_u16(inp)
        if n_cb < 1:
            raise IntegrityError(
                "RFT1 store frame must carry at least one codebook"
            )
        codebooks = [
            SharedCodebook.from_bytes(read_bytes(inp)) for _ in range(n_cb)
        ]
        store = cls(codebooks[-1], tile_cache_trees=tile_cache_trees)
        for cb in codebooks[:-1]:
            store._retained[cb.generation] = cb
        n = read_u32(inp)
        for _ in range(n):
            user_id = read_bytes(inp).decode("utf-8")
            store.add_delta(user_id, UserDelta.from_bytes(read_bytes(inp)))
        store.drop_unreferenced_codebooks()
        return store


def _quantize_fleet(items, lossy):
    """Quantize every user's regression fit table onto ONE fleet-wide
    fixed-rate grid (satellite of ISSUE 4, closing the ROADMAP "regression
    fit quantization at the store level" item): the fleet fit-value table
    then holds at most 2**fit_bits learned grid points, and the report
    carries the paper's §6 distortion bound for the store stats."""
    from ..core.lossy import quantize_fits

    if any(f.meta.task != "regression" for _, f in items):
        raise ValueError(
            "lossy fit quantization applies to regression fleets"
        )
    union = np.concatenate([
        np.asarray(f.fit_values, np.float64) for _, f in items
    ])
    lo, hi = float(union.min()), float(union.max())
    step = max(hi - lo, 1e-30) / (1 << lossy.fit_bits)
    quantized, max_err = [], 0.0
    for user_id, forest in items:
        # per-user dither seed: reusing one seed would draw IDENTICAL
        # dither vectors across users, correlating quantization errors
        # and voiding the independent-error model behind the bounds
        user_seed = (lossy.seed + zlib.crc32(user_id.encode())) & 0x7FFFFFFF
        qf, err = quantize_fits(
            forest, lossy.fit_bits, dithered=lossy.dithered,
            seed=user_seed, value_range=(lo, hi),
        )
        max_err = max(max_err, err)
        quantized.append((user_id, qf))
    grid_used = np.unique(np.concatenate([
        np.asarray(f.fit_values, np.float64) for _, f in quantized
    ]))
    report = {
        "fit_bits": lossy.fit_bits,
        "dithered": lossy.dithered,
        "grid_levels": 1 << lossy.fit_bits,
        "grid_levels_used": int(grid_used.size),
        "step": step,
        # §6 closed-form bounds: |error| <= step/2 (step with dither),
        # per-value quantization variance step^2 / 12
        "max_error_bound": step * (1.0 if lossy.dithered else 0.5),
        "max_abs_error": max_err,
        "distortion_bound": step * step / 12.0,
    }
    return quantized, report


def build_store(
    forests: dict[str, Forest] | Sequence[tuple[str, Forest]],
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
    tile_cache_trees: int = 4096,
    arena_capacity_trees: int = 16384,
    lossy: "LossyConfig | None" = None,
) -> ForestStore:
    """Build a multi-tenant store from a fleet: fleet-scale Bregman
    clustering for the shared codebooks, then one delta per user.

    ``lossy`` (a ``core.lossy.LossyConfig``) turns the fleet fit table
    into a learned fixed-rate grid: every regression user's fits are
    quantized onto one fleet-wide 2**fit_bits-level grid BEFORE delta
    encoding, so the shared value table shrinks to at most ``2**fit_bits``
    entries and every existing lossless path (delta encode, hydrate,
    serve) applies unchanged — "lossy = preprocess, then lossless" (paper
    §7).  The measured max error and the §6 distortion bound land in
    ``store.lossy`` / ``size_report()``."""
    items: Iterable[tuple[str, Forest]] = (
        forests.items() if isinstance(forests, dict) else forests
    )
    items = list(items)
    lossy_report = None
    if lossy is not None:
        items, lossy_report = _quantize_fleet(items, lossy)
    shared = build_shared_codebook(
        [f for _, f in items], k_max=k_max, seed=seed,
        engine=engine, chunk_size=chunk_size,
    )
    store = ForestStore(
        shared, tile_cache_trees=tile_cache_trees,
        arena_capacity_trees=arena_capacity_trees,
    )
    for user_id, forest in items:
        store.add_user(user_id, forest, seed=seed)
    store.lossy = lossy_report
    return store

"""Codebook lifecycle: drift monitoring, online re-clustering, in-place
delta migration (ISSUE 5 tentpole).

``build_store`` freezes the fleet codebook at build time, so every user
onboarded afterwards pays for symbols the fleet never produced with
USER-LOCAL fallback clusters shipped inside their delta — duplicated
across every late user, eroding exactly the shared-dictionary win the
store exists for.  This module makes the codebook a LIVING artifact:

* ``drift_report`` — the monitor: fraction of users on fallback clusters
  and the delta bytes spent on fallback artifacts (local codebook tables,
  streams coded under them, extra fit values) vs. the fleet-codebook
  baseline, plus a recluster recommendation.
* ``recluster`` — builds a successor codebook generation and migrates
  every delta onto it, bit-exact per user:

  - ``mode="extend"`` (online): generation g+1 KEEPS every generation-g
    cluster verbatim and appends clusters Bregman-fit (``core.bregman``
    chunked engine) to the pooled fallback models, with the regression
    fleet value table growing append-only.  The remap is the identity, so
    users without fallbacks migrate by RELABELING — new generation stamp,
    byte-identical streams, warm caches (decoded tiles, arena runs,
    serving packs) all preserved.
  - ``mode="full"`` (rebuild): generation g+1 re-runs fleet-scale
    clustering over the union of every user's reconstructed forest.
    Unchanged clusters are matched into a remap table; users whose
    references all survive still relabel, everyone else re-encodes.

* ``migrate_user`` / ``migrate_users`` — incremental migration: old and
  new generations coexist (the store retains a superseded codebook until
  its last delta migrates), so a serving session can cross a migration
  mid-flight, mixing generations in one batch.

Every migration path verifies bit-exact reconstruction against the
pre-migration forest before registering the new delta, and picks the
SMALLER of the re-encoded and relabeled candidates, so a recluster can
only shrink a user's delta bytes.
"""
from __future__ import annotations

import dataclasses
import io
import os
import struct
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from ..core.framing import (
    IntegrityError,
    atomic_write_bytes,
    check_crc,
    expect_magic,
    read_arr,
    read_bytes,
    read_struct,
    read_u16,
    read_u32,
    with_crc,
    write_arr,
    write_bytes,
    write_u16,
    write_u32,
)
from ..core.stats import (
    alpha_fits,
    alpha_splits,
    alpha_vars,
    extract_records,
    fit_counts,
    split_counts,
    var_name_counts,
)
from .codebook import (
    SharedCodebook,
    SharedComponent,
    build_shared_codebook,
    cluster_codebooks,
    fit_value_ids,
)
from .delta import DeltaComponent, UserDelta, encode_user_delta
from .runtime import ForestStore

_REMAP_MAGIC = b"RFM1"


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------

def _delta_components(delta: UserDelta) -> list[DeltaComponent]:
    return [delta.vars_dc, *delta.splits_dc.values(), delta.fits_dc]


def _arr_bytes(a: np.ndarray) -> int:
    """Exact on-disk size of one ARR record (docs/format.md)."""
    buf = io.BytesIO()
    write_arr(buf, np.asarray(a))
    return buf.tell()


def user_fallback_report(store: ForestStore, user_id: str) -> dict:
    """Fallback accounting for one user's delta: how many user-local
    clusters it ships, and how many delta bytes those cost (local codebook
    tables + residual streams coded under them + extra fit values) — the
    spend the fleet codebook was supposed to amortize."""
    delta = store.delta(user_id)
    shared = store.codebook_for(delta.codebook_generation)
    pairs = [
        (delta.vars_dc, shared.vars_comp),
        *(
            (dc, shared.splits_comp.get(v))
            for v, dc in delta.splits_dc.items()
        ),
        (delta.fits_dc, shared.fits_comp),
    ]
    n_local = 0
    table_bytes = 0
    stream_bytes = 0
    for dc, comp in pairs:
        s = comp.n_clusters if comp is not None else 0
        n_local += dc.n_local
        tables = (
            dc.local_lengths if dc.coder == "huffman" else dc.local_freqs
        )
        table_bytes += sum(_arr_bytes(t) for t in tables)
        for ref, stream in zip(dc.refs, dc.streams):
            if int(ref) >= s:
                stream_bytes += len(stream)
    extra_bytes = 8 * int(delta.extra_fit_values.size)
    fallback_bytes = table_bytes + stream_bytes + extra_bytes
    return {
        "n_local_clusters": n_local,
        "n_extra_fit_values": int(delta.extra_fit_values.size),
        "local_table_bytes": table_bytes,
        "local_stream_bytes": stream_bytes,
        "extra_fit_value_bytes": extra_bytes,
        "fallback_bytes": fallback_bytes,
        "uses_fallback": bool(
            n_local > 0 or delta.extra_fit_values.size > 0
        ),
        "codebook_generation": delta.codebook_generation,
    }


def drift_report(
    store: ForestStore,
    recluster_threshold: float = 0.2,
    exclude: Sequence[str] = (),
) -> dict:
    """The codebook drift monitor: how far the fleet has moved from the
    codebook it was clustered for.

    Reports the fraction of users carrying user-local fallback clusters,
    the delta bytes those fallbacks cost against the fleet-codebook
    baseline (``fallback_overhead_fraction`` of all delta bytes), and
    ``recommend_recluster`` once the fallback user fraction crosses
    ``recluster_threshold``.

    ``exclude`` names users to leave out of the accounting entirely —
    the serving layer passes its quarantined users here, since a delta
    that fails integrity checks cannot be decoded for fallback
    accounting (they are counted in ``n_excluded_users``, not treated as
    fallback users).

    The whole report is memoized on ``store.version`` (plus the
    threshold/exclude arguments), and the per-user fallback accounting —
    the expensive part: a full delta decode + re-serialize per user — is
    memoized per user on ``(user_version, codebook_generation)``.  The
    per-user key matters: a relabel migration rewrites the delta WITHOUT
    bumping the user's registry version (relabeled bytes decode
    identically), but it does change ``codebook_generation``, which the
    report must see.  An unchanged fleet therefore polls for free, and a
    mid-migration fleet recomputes only the users that moved — what lets
    the scheduler's ``LifecycleDriver`` poll aggressively."""
    memo_key = (store.version, recluster_threshold, tuple(sorted(exclude)))
    memo = getattr(store, "_drift_report_cache", None)
    if memo is not None and memo[0] == memo_key:
        return memo[1]
    user_cache = getattr(store, "_fallback_report_cache", None)
    if user_cache is None:
        user_cache = store._fallback_report_cache = {}
    excluded = {u for u in exclude if u in store.user_ids}
    users = [u for u in store.user_ids if u not in excluded]
    per_user = {}
    delta_bytes = {}
    for u in users:
        key = (
            store.user_version(u), store.delta(u).codebook_generation
        )
        hit = user_cache.get(u)
        if hit is None or hit[0] != key:
            hit = (
                key,
                user_fallback_report(store, u),
                len(store.delta(u).to_bytes()),
            )
            user_cache[u] = hit
        per_user[u] = hit[1]
        delta_bytes[u] = hit[2]
    for u in list(user_cache):
        if u not in store.user_ids:
            del user_cache[u]
    n_fallback = sum(1 for r in per_user.values() if r["uses_fallback"])
    fallback_bytes = sum(r["fallback_bytes"] for r in per_user.values())
    total_delta_bytes = sum(delta_bytes.values())
    current = store.generation
    pending = sum(
        1 for r in per_user.values()
        if r["codebook_generation"] != current
    )
    frac = n_fallback / len(users) if users else 0.0
    report = {
        "n_users": len(users),
        "n_excluded_users": len(excluded),
        "codebook_generation": current,
        "generations": store.generations,
        "n_pending_migration": pending,
        "n_fallback_users": n_fallback,
        "fallback_user_fraction": frac,
        "fallback_bytes": fallback_bytes,
        "delta_bytes_total": total_delta_bytes,
        "fallback_overhead_fraction": (
            fallback_bytes / total_delta_bytes if total_delta_bytes else 0.0
        ),
        "recluster_threshold": recluster_threshold,
        "recommend_recluster": frac >= recluster_threshold and n_fallback > 0,
        "per_user": per_user,
    }
    store._drift_report_cache = (memo_key, report)
    return report


# ---------------------------------------------------------------------------
# remap table
# ---------------------------------------------------------------------------

@dataclass
class RemapTable:
    """Cluster-id remap between two codebook generations.

    ``vars_map[k]`` / ``splits_map[v][k]`` / ``fits_map[k]`` give the
    new-generation cluster id whose codebook is BYTE-IDENTICAL to old
    cluster ``k`` (so streams coded under k decode unchanged under the
    mapped id), or -1 when no identical twin exists.  ``extend``-mode
    reclustering yields the identity map by construction; ``full`` mode
    matches twins by table equality.

    ``fit_table_prefix`` records whether the new generation's regression
    fleet value table extends the old one append-only — the condition for
    relabeling a regression user's fit streams without re-encoding.

    Serializes as one RFM1 frame (normative spec: docs/format.md).
    """

    old_generation: int
    new_generation: int
    vars_map: np.ndarray  # (K_old_vars,) int32; -1 = no identical twin
    splits_map: dict[int, np.ndarray] = field(default_factory=dict)
    fits_map: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int32)
    )
    fit_table_prefix: bool = True

    @property
    def is_identity(self) -> bool:
        """True when every old cluster maps to itself (extend mode)."""
        maps = [self.vars_map, self.fits_map, *self.splits_map.values()]
        return all(np.array_equal(m, np.arange(len(m))) for m in maps)

    def to_bytes(self) -> bytes:
        """Serialize as one RFM1 frame (normative spec: docs/format.md)."""
        out = io.BytesIO()
        out.write(_REMAP_MAGIC)
        write_u16(out, self.old_generation)
        write_u16(out, self.new_generation)
        out.write(struct.pack("<B", 1 if self.fit_table_prefix else 0))
        write_arr(out, self.vars_map.astype(np.int32))
        write_u16(out, len(self.splits_map))
        for v, m in sorted(self.splits_map.items()):
            write_u16(out, v)
            write_arr(out, m.astype(np.int32))
        write_arr(out, self.fits_map.astype(np.int32))
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "RemapTable":
        """Parse one RFM1 frame (normative spec: docs/format.md).  The
        CRC32 trailer is verified when present; corruption raises a typed
        ``core.framing.IntegrityError`` / ``TruncatedFrameError``."""
        inp = io.BytesIO(check_crc(data, "RFM1 remap table"))
        expect_magic(inp, _REMAP_MAGIC, "RFM1 remap table")
        old_gen = read_u16(inp)
        new_gen = read_u16(inp)
        (prefix,) = read_struct(inp, "<B", "RFM1 fit-table-prefix flag")
        vars_map = read_arr(inp).astype(np.int32)
        splits_map = {}
        for _ in range(read_u16(inp)):
            v = read_u16(inp)
            splits_map[v] = read_arr(inp).astype(np.int32)
        fits_map = read_arr(inp).astype(np.int32)
        return cls(
            old_generation=old_gen,
            new_generation=new_gen,
            vars_map=vars_map,
            splits_map=splits_map,
            fits_map=fits_map,
            fit_table_prefix=bool(prefix),
        )


def _tables_equal(a: np.ndarray, b: np.ndarray) -> bool:
    """Codebook-table equality modulo trailing uncodable symbols (length 0
    / frequency 0 tails encode the same canonical code)."""
    a = np.trim_zeros(np.asarray(a), "b")
    b = np.trim_zeros(np.asarray(b), "b")
    return np.array_equal(a, b)


def _component_remap(
    old: SharedComponent | None, new: SharedComponent | None
) -> np.ndarray:
    """(K_old,) map of old cluster ids onto byte-identical new clusters
    (-1 where none exists)."""
    if old is None or old.n_clusters == 0:
        return np.zeros(0, np.int32)
    k_old = old.n_clusters
    out = np.full(k_old, -1, np.int32)
    if new is None or new.coder != old.coder:
        return out
    old_tabs = old.codebook_lengths if old.coder == "huffman" else old.freqs
    new_tabs = new.codebook_lengths if new.coder == "huffman" else new.freqs
    for i, ot in enumerate(old_tabs):
        for j, nt in enumerate(new_tabs):
            if _tables_equal(ot, nt):
                out[i] = j
                break
    return out


def build_remap(
    old: SharedCodebook, new: SharedCodebook
) -> RemapTable:
    """Match every old cluster to a byte-identical new cluster (per
    component) and record regression fit-table compatibility."""
    n_old = len(old.fleet_fit_values)
    prefix = len(new.fleet_fit_values) >= n_old and np.array_equal(
        new.fleet_fit_values[:n_old], old.fleet_fit_values
    )
    return RemapTable(
        old_generation=old.generation,
        new_generation=new.generation,
        vars_map=_component_remap(old.vars_comp, new.vars_comp),
        splits_map={
            v: _component_remap(c, new.splits_comp.get(v))
            for v, c in old.splits_comp.items()
        },
        fits_map=_component_remap(old.fits_comp, new.fits_comp),
        fit_table_prefix=prefix,
    )


# ---------------------------------------------------------------------------
# relabeling (migration without re-encoding)
# ---------------------------------------------------------------------------

def _relabel_component(
    dc: DeltaComponent, comp_map: np.ndarray, s_old: int, s_new: int
) -> DeltaComponent | None:
    """Rename one component's cluster references onto the new generation:
    shared refs go through the remap (fail on any missing twin), local
    refs re-base from ``s_old + j`` to ``s_new + j``.  Streams, local
    tables, and symbol counts are untouched."""

    def rename(arr: np.ndarray) -> np.ndarray | None:
        out = arr.astype(np.int32).copy()
        shared = (arr >= 0) & (arr < s_old)
        local = arr >= s_old
        if shared.any():
            mapped = comp_map[arr[shared]] if len(comp_map) else np.full(
                int(shared.sum()), -1, np.int32
            )
            if (mapped < 0).any():
                return None
            out[shared] = mapped
        out[local] = s_new + (arr[local] - s_old)
        return out

    kid = rename(np.asarray(dc.kid_to_ref))
    refs = rename(np.asarray(dc.refs))
    if kid is None or refs is None:
        return None
    return DeltaComponent(
        coder=dc.coder,
        kid_to_ref=kid.astype(np.int16),
        local_lengths=list(dc.local_lengths),
        local_freqs=list(dc.local_freqs),
        refs=refs.astype(np.int16),
        n_symbols=list(dc.n_symbols),
        streams=list(dc.streams),
    )


def relabel_delta(
    delta: UserDelta,
    old: SharedCodebook,
    new: SharedCodebook,
    remap: RemapTable,
) -> UserDelta | None:
    """Migrate a delta to the new generation by RENAMING cluster ids only
    — every stream byte, local table, and fit map is carried verbatim, so
    the decoded artifact is bit-identical and warm caches stay valid.

    Returns ``None`` when renaming cannot be lossless: a referenced shared
    cluster has no byte-identical twin in the new generation, or (for
    regression) the fit streams' symbol ids would shift — the new fleet
    value table must extend the old append-only, and a user carrying
    extra values needs the extra-id base ``len(fleet)`` unchanged."""
    if old.task == "regression":
        if not remap.fit_table_prefix:
            return None
        if delta.extra_fit_values.size and len(new.fleet_fit_values) != len(
            old.fleet_fit_values
        ):
            # extra symbol ids are based at len(fleet): growing the table
            # would re-point them at other users' onboarded values
            return None
    vars_dc = _relabel_component(
        delta.vars_dc, remap.vars_map,
        old.vars_comp.n_clusters, new.vars_comp.n_clusters,
    )
    if vars_dc is None:
        return None
    splits_dc: dict[int, DeltaComponent] = {}
    for v, dc in delta.splits_dc.items():
        s_old = (
            old.splits_comp[v].n_clusters if v in old.splits_comp else 0
        )
        s_new = (
            new.splits_comp[v].n_clusters if v in new.splits_comp else 0
        )
        comp_map = remap.splits_map.get(v, np.zeros(0, np.int32))
        rdc = _relabel_component(dc, comp_map, s_old, s_new)
        if rdc is None:
            return None
        splits_dc[v] = rdc
    fits_dc = _relabel_component(
        delta.fits_dc, remap.fits_map,
        old.fits_comp.n_clusters, new.fits_comp.n_clusters,
    )
    if fits_dc is None:
        return None
    return dataclasses.replace(
        delta,
        codebook_generation=new.generation,
        vars_dc=vars_dc,
        splits_dc=splits_dc,
        fits_dc=fits_dc,
    )


# ---------------------------------------------------------------------------
# successor codebook construction
# ---------------------------------------------------------------------------

def _uncodable_rows(counts: np.ndarray, comp: SharedComponent) -> np.ndarray:
    """Mask of count rows NO cluster of ``comp`` can code (a row is
    codable by a cluster iff every symbol it emits has a codeword) — the
    exact condition that forces a user-local fallback at encode time."""
    if comp is None or comp.n_clusters == 0:
        return np.ones(len(counts), bool)
    cost = comp.cost_table()  # (K, B_comp)
    if counts.shape[1] > cost.shape[1]:
        pad = np.full(
            (cost.shape[0], counts.shape[1] - cost.shape[1]), np.inf
        )
        cost = np.concatenate([cost, pad], axis=1)
    emits = counts > 0  # (U, B)
    uncodable_by = emits[:, None, :] & ~np.isfinite(cost)[None, :, :]
    return uncodable_by.any(-1).all(-1)


def _extend_component(
    old: SharedComponent | None,
    rows: list[np.ndarray],
    alphabet: int,
    alpha_bits: float,
    coder: str,
    k_max: int,
    seed: int,
    engine: str,
    chunk_size: int,
) -> SharedComponent:
    """Generation g+1 of one component: generation-g cluster tables kept
    VERBATIM (identity remap), plus clusters Bregman-fit to the pooled
    rows generation g cannot code."""
    new = SharedComponent(coder, alphabet)
    if old is not None:
        new.codebook_lengths = list(old.codebook_lengths)
        new.freqs = list(old.freqs)
    pool = [r for r in rows if len(r)]
    if pool:
        stacked = np.concatenate(pool).astype(np.float64)
        uncod = _uncodable_rows(stacked, old)
        if uncod.any():
            _, lengths, freqs = cluster_codebooks(
                stacked[uncod], alpha_bits, coder, k_max, seed,
                engine, chunk_size,
            )
            new.codebook_lengths.extend(lengths)
            new.freqs.extend(freqs)
    return new


def extend_codebook(
    store: ForestStore,
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
) -> tuple[SharedCodebook, RemapTable]:
    """Build the ONLINE successor codebook: keep every current cluster
    verbatim and append clusters fit to the fallback models (the models
    the frozen codebook cannot code), with the regression fleet value
    table growing append-only.  The remap is the identity, so clean users
    relabel instead of re-encoding."""
    fallback_users = [
        u for u in store.user_ids
        if user_fallback_report(store, u)["uses_fallback"]
    ]
    forests = [store.reconstruct(u) for u in fallback_users]
    return extend_codebook_from_forests(
        store.shared, forests, k_max=k_max, seed=seed,
        engine=engine, chunk_size=chunk_size,
    )


def extend_codebook_from_forests(
    old: SharedCodebook,
    forests: Sequence,
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
) -> tuple[SharedCodebook, RemapTable]:
    """``extend_codebook`` taking the uncodable forests DIRECTLY — the
    streaming-build entry point (``store.streaming``): each wave extends
    the fleet codebook with exactly the wave's uncodable models without a
    registry holding the whole fleet in memory.  Generation-g clusters
    are kept verbatim (identity remap), appended clusters are Bregman-fit
    to the pooled uncodable rows, and the regression fleet value table
    grows append-only."""
    d = old.n_features
    forests = list(forests)
    recs = [extract_records(f) for f in forests]
    t_max = max(
        [old.t_max]
        + [int(r.depth.max()) + 1 if len(r.depth) else 1 for r in recs]
    )
    n_train = max(
        [old.n_train_obs] + [f.meta.n_train_obs for f in forests]
    )

    # ---- regression: grow the fleet value table append-only --------------
    if old.task == "regression":
        extras: list[np.ndarray] = []
        for f in forests:
            hit, _ = fit_value_ids(old.fleet_fit_values, f.fit_values)
            extras.append(np.asarray(f.fit_values, np.float64)[~hit])
        new_vals = (
            np.unique(np.concatenate(extras)) if extras else np.zeros(0)
        )
        fleet_values = np.concatenate([old.fleet_fit_values, new_vals])
        n_fit_syms = len(fleet_values)
        fits_coder = "huffman"
    else:
        fleet_values = old.fleet_fit_values
        n_fit_syms = old.n_classes
        fits_coder = old.fits_comp.coder

    # ---- per-component uncodable-model pools -----------------------------
    vars_rows, fits_rows = [], []
    splits_rows: dict[int, list[np.ndarray]] = {}
    for f, r in zip(forests, recs):
        u_t_max = int(r.depth.max()) + 1 if len(r.depth) else 1
        vc = var_name_counts(r, d, u_t_max)
        vars_rows.append(vc[vc.sum(-1) > 0])
        for v, cnts in split_counts(
            r, d, u_t_max, old.n_bins_per_feature
        ).items():
            splits_rows.setdefault(v, []).append(cnts[cnts.sum(-1) > 0])
        if old.task == "regression":
            _, ids = fit_value_ids(fleet_values, f.fit_values)
            syms = ids[r.fit.astype(np.int64)]
        else:
            syms = r.fit.astype(np.int64)
        rf = type(r)(
            tree_id=r.tree_id, depth=r.depth, father_var=r.father_var,
            var=r.var, split=r.split, fit=syms, is_leaf=r.is_leaf,
        )
        fc = fit_counts(rf, d, u_t_max, n_fit_syms)
        fits_rows.append(fc[fc.sum(-1) > 0])

    vars_comp = _extend_component(
        old.vars_comp, vars_rows, d, alpha_vars(d), "huffman",
        k_max, seed, engine, chunk_size,
    )
    splits_comp = dict(old.splits_comp)
    for v, rows in splits_rows.items():
        a = alpha_splits(
            not bool(old.categorical[v]), n_train,
            int(old.n_bins_per_feature[v]),
        )
        splits_comp[v] = _extend_component(
            old.splits_comp.get(v), rows, int(old.n_bins_per_feature[v]),
            a, "huffman", k_max, seed, engine, chunk_size,
        )
    fits_comp = _extend_component(
        old.fits_comp, fits_rows, n_fit_syms,
        alpha_fits(old.task, n_fit_syms), fits_coder,
        k_max, seed, engine, chunk_size,
    )

    new = SharedCodebook(
        n_features=d,
        task=old.task,
        n_classes=old.n_classes,
        t_max=t_max,
        n_train_obs=n_train,
        n_bins_per_feature=old.n_bins_per_feature,
        categorical=old.categorical,
        vars_comp=vars_comp,
        splits_comp=splits_comp,
        fits_comp=fits_comp,
        fleet_fit_values=fleet_values,
        generation=old.generation + 1,
    )
    return new, build_remap(old, new)


def rebuild_codebook(
    store: ForestStore,
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
) -> tuple[SharedCodebook, RemapTable]:
    """Build the FULL-REBUILD successor codebook: fleet-scale Bregman
    clustering from scratch over every user's reconstructed forest.
    Clusters that happen to survive byte-identically land in the remap;
    everything else forces a re-encode at migration."""
    old = store.shared
    forests = [store.reconstruct(u) for u in store.user_ids]
    if not forests:
        # nothing to cluster: the successor is the current codebook,
        # renamed — installing it is a no-op generation bump
        new = dataclasses.replace(old, generation=old.generation + 1)
        return new, build_remap(old, new)
    new = build_shared_codebook(
        forests, k_max=k_max, seed=seed, engine=engine,
        chunk_size=chunk_size, generation=old.generation + 1,
    )
    return new, build_remap(old, new)


# ---------------------------------------------------------------------------
# migration
# ---------------------------------------------------------------------------

def migrate_user(
    store: ForestStore,
    user_id: str,
    remap: RemapTable,
    seed: int = 0,
    verify: bool = True,
) -> dict:
    """Migrate one user's delta onto the current codebook generation.

    Builds up to two candidates — a RELABELED delta (cluster ids renamed,
    streams verbatim, warm caches preserved) and, when the user carries
    fallback artifacts or cannot relabel, a RE-ENCODED delta against the
    new generation — and registers the smaller one.  Reconstruction is
    verified bit-exact against the pre-migration forest before anything
    is replaced.  Returns a per-user migration record."""
    delta = store.delta(user_id)
    new = store.shared
    if delta.codebook_generation == new.generation:
        n = len(delta.to_bytes())
        return {"status": "current", "bytes_before": n, "bytes": n}
    if delta.codebook_generation != remap.old_generation:
        raise ValueError(
            f"user {user_id!r} is on generation "
            f"{delta.codebook_generation}; remap covers "
            f"{remap.old_generation} -> {remap.new_generation}"
        )
    old = store.codebook_for(delta.codebook_generation)
    bytes_before = len(delta.to_bytes())

    relabeled = relabel_delta(delta, old, new, remap)
    uses_fallback = user_fallback_report(store, user_id)["uses_fallback"]
    # the full entropy decode is only paid when actually needed: to build
    # the re-encode candidate, or to verify — a clean relabel with
    # verify=False migrates without decoding at all
    original = None
    if relabeled is None or uses_fallback or verify:
        original = store.reconstruct(user_id)
    reencoded = None
    if relabeled is None or uses_fallback:
        reencoded = encode_user_delta(original, new, seed=seed)

    candidates: list[tuple[int, str, UserDelta]] = []
    if relabeled is not None:
        candidates.append((len(relabeled.to_bytes()), "relabeled", relabeled))
    if reencoded is not None:
        candidates.append((len(reencoded.to_bytes()), "reencoded", reencoded))
    # ties favour the relabeled candidate: it keeps warm caches alive
    n_bytes, status, chosen = min(candidates, key=lambda c: (c[0], c[1] != "relabeled"))

    if verify:
        from .delta import reconstruct_user

        got = reconstruct_user(chosen, new)
        if not got.equals(original):
            raise AssertionError(
                f"migration of {user_id!r} is not bit-exact "
                f"({status} candidate)"
            )
    if status == "relabeled":
        store.replace_delta_relabeled(user_id, chosen)
    else:
        store.add_delta(user_id, chosen)
    return {
        "status": status,
        "bytes_before": bytes_before,
        "bytes": n_bytes,
    }


def migrate_users(
    store: ForestStore,
    users: Sequence[str],
    remap: RemapTable,
    seed: int = 0,
    verify: bool = True,
) -> dict[str, dict]:
    """Migrate several users (see ``migrate_user``), garbage-collecting
    codebook generations whose last delta migrated away."""
    records = {
        u: migrate_user(store, u, remap, seed=seed, verify=verify)
        for u in users
    }
    store.drop_unreferenced_codebooks()
    return records


# ---------------------------------------------------------------------------
# crash-safe migration journal (ISSUE 6 tentpole)
# ---------------------------------------------------------------------------

_JOURNAL_MAGIC = b"RFJ1"

#: journal state machine: ``idle`` (nothing logged) -> ``built``
#: (successor codebook + remap constructed and serialized into the
#: journal) -> ``installed`` (codebook installed in the store; per-user
#: migration in flight) -> ``committed`` (every user migrated; GC safe).
_JOURNAL_STATES = ("idle", "built", "installed", "committed")


@dataclass
class MigrationJournal:
    """Write-ahead journal making ``recluster`` crash-safe.

    Every state transition of a recluster run is logged BEFORE the store
    mutation it describes takes effect, so a crash at any point leaves
    enough information to finish (roll forward) or undo (roll back) the
    run via ``resume_recluster``:

    * ``log_built`` serializes the successor codebook and remap table
      into the journal — a crash after build never repeats the expensive
      fleet-scale clustering.
    * ``log_migrate_intent`` records a user's PRE-migration delta bytes
      before their delta is replaced — a crash mid-migration rolls the
      user back to those exact bytes, then re-migrates.
    * ``log_migrate_commit`` marks the user durably migrated.
    * ``log_committed`` marks the whole run complete; only after this may
      superseded codebook generations be garbage-collected.

    With ``path`` set, every transition atomically rewrites the journal
    file (write-to-temp + ``os.replace``), so the journal survives
    process crashes, not just injected ones.  Serializes as one RFJ1
    frame with a CRC32 trailer (docs/format.md §8).
    """

    state: str = "idle"
    mode: str = ""
    old_generation: int = 0
    new_generation: int = 0
    codebook_bytes: bytes = b""
    remap_bytes: bytes = b""
    #: user -> {"intent": pre-migration delta bytes, "committed": bool,
    #:          "status": migrate_user status once committed}
    entries: dict[str, dict] = field(default_factory=dict)
    path: str | None = None

    # -- state transitions -------------------------------------------------

    def log_built(
        self, mode: str, codebook: SharedCodebook, remap: RemapTable
    ) -> None:
        self.mode = mode
        self.old_generation = remap.old_generation
        self.new_generation = remap.new_generation
        self.codebook_bytes = codebook.to_bytes()
        self.remap_bytes = remap.to_bytes()
        self.state = "built"
        self._persist()

    def log_installed(self) -> None:
        self.state = "installed"
        self._persist()

    def log_migrate_intent(self, user_id: str, delta_bytes: bytes) -> None:
        e = self.entries.get(user_id)
        if e is not None and e["committed"]:
            return  # already durably migrated — keep the commit record
        self.entries[user_id] = {
            "intent": delta_bytes, "committed": False, "status": "",
        }
        self._persist()

    def log_migrate_commit(self, user_id: str, status: str) -> None:
        self.entries[user_id]["committed"] = True
        self.entries[user_id]["status"] = status
        self._persist()

    def log_committed(self) -> None:
        self.state = "committed"
        self._persist()

    @property
    def uncommitted_users(self) -> list[str]:
        """Users whose migration intent was logged but never committed —
        the ones ``resume_recluster`` rolls back before re-migrating."""
        return sorted(
            u for u, e in self.entries.items() if not e["committed"]
        )

    def summary(self) -> dict:
        """Compact journal status for ``ForestServer.stats()["health"]``."""
        return {
            "state": self.state,
            "mode": self.mode,
            "old_generation": self.old_generation,
            "new_generation": self.new_generation,
            "n_entries": len(self.entries),
            "n_committed": sum(
                1 for e in self.entries.values() if e["committed"]
            ),
            "uncommitted_users": self.uncommitted_users,
        }

    # -- persistence -------------------------------------------------------

    def _persist(self) -> None:
        if self.path is None:
            return
        # Shared atomic-write helper (ISSUE 8 bugfix): the old inline
        # version fsynced the file but never the containing directory, so
        # a power loss right after os.replace could forget the rename and
        # resurrect a stale journal.
        atomic_write_bytes(self.path, self.to_bytes())

    @classmethod
    def load(cls, path: str) -> "MigrationJournal":
        """Load a persisted journal; the loaded journal keeps persisting
        to the same path."""
        with open(path, "rb") as f:
            j = cls.from_bytes(f.read())
        j.path = path
        return j

    def to_bytes(self) -> bytes:
        """Serialize as one RFJ1 frame (normative spec: docs/format.md)."""
        out = io.BytesIO()
        out.write(_JOURNAL_MAGIC)
        out.write(struct.pack("<B", _JOURNAL_STATES.index(self.state)))
        write_bytes(out, self.mode.encode("utf-8"))
        write_u16(out, self.old_generation)
        write_u16(out, self.new_generation)
        write_bytes(out, self.codebook_bytes)
        write_bytes(out, self.remap_bytes)
        write_u32(out, len(self.entries))
        for u, e in sorted(self.entries.items()):
            write_bytes(out, u.encode("utf-8"))
            out.write(struct.pack("<B", 1 if e["committed"] else 0))
            write_bytes(out, e["status"].encode("utf-8"))
            write_bytes(out, e["intent"])
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "MigrationJournal":
        """Parse one RFJ1 frame (normative spec: docs/format.md)."""
        inp = io.BytesIO(check_crc(data, "RFJ1 migration journal"))
        expect_magic(inp, _JOURNAL_MAGIC, "RFJ1 migration journal")
        (state_i,) = read_struct(inp, "<B", "RFJ1 state")
        if state_i >= len(_JOURNAL_STATES):
            raise IntegrityError(
                f"RFJ1 journal has unknown state code {state_i}"
            )
        mode = read_bytes(inp).decode("utf-8")
        old_gen = read_u16(inp)
        new_gen = read_u16(inp)
        codebook_bytes = read_bytes(inp)
        remap_bytes = read_bytes(inp)
        entries: dict[str, dict] = {}
        for _ in range(read_u32(inp)):
            u = read_bytes(inp).decode("utf-8")
            (committed,) = read_struct(inp, "<B", "RFJ1 entry flag")
            status = read_bytes(inp).decode("utf-8")
            intent = read_bytes(inp)
            entries[u] = {
                "intent": intent,
                "committed": bool(committed),
                "status": status,
            }
        return cls(
            state=_JOURNAL_STATES[state_i],
            mode=mode,
            old_generation=old_gen,
            new_generation=new_gen,
            codebook_bytes=codebook_bytes,
            remap_bytes=remap_bytes,
            entries=entries,
        )


# ---------------------------------------------------------------------------
# the lifecycle operation
# ---------------------------------------------------------------------------

@dataclass
class ReclusterResult:
    """What one ``recluster`` run did, for dashboards and benchmarks."""

    mode: str
    old_generation: int
    new_generation: int
    n_users: int
    n_relabeled: int
    n_reencoded: int
    n_pending: int  # users left on the old generation (migrate=False)
    bytes_before: int
    bytes_after: int
    verified_bit_exact: bool
    wall_time_s: float
    remap: RemapTable
    per_user: dict[str, dict]


def _migrate_journaled(
    store: ForestStore,
    remap: RemapTable,
    journal: MigrationJournal,
    step,
    seed: int,
    verify: bool,
) -> dict[str, dict]:
    """The journaled per-user migration loop shared by ``recluster`` and
    ``resume_recluster``: intent is logged BEFORE each user's delta is
    replaced, commit AFTER — and superseded-generation GC happens only
    once the whole run is journal-committed (never mid-flight, unlike
    ``migrate_users``)."""
    per_user: dict[str, dict] = {}
    for u in store.user_ids:
        already = journal.entries.get(u)
        if already is not None and already["committed"]:
            # durably migrated by a previous (crashed) attempt
            n = len(store.delta(u).to_bytes())
            per_user[u] = {
                "status": already["status"] or "current",
                "bytes_before": n,
                "bytes": n,
            }
            continue
        journal.log_migrate_intent(u, store.delta(u).to_bytes())
        step(f"migrate:{u}")
        per_user[u] = migrate_user(store, u, remap, seed=seed, verify=verify)
        step(f"migrated:{u}")
        journal.log_migrate_commit(u, per_user[u]["status"])
    step("commit")
    journal.log_committed()
    step("gc")
    store.drop_unreferenced_codebooks()
    return per_user


def _recluster_result(
    store: ForestStore,
    mode: str,
    remap: RemapTable,
    per_user: dict[str, dict],
    bytes_before: int,
    verified: bool,
    elapsed_s: float,
) -> ReclusterResult:
    statuses = [r["status"] for r in per_user.values()]
    n_pending = sum(
        1 for u in store.user_ids
        if store.delta(u).codebook_generation != remap.new_generation
    )
    return ReclusterResult(
        mode=mode,
        old_generation=remap.old_generation,
        new_generation=remap.new_generation,
        n_users=len(store.user_ids),
        n_relabeled=statuses.count("relabeled"),
        n_reencoded=statuses.count("reencoded"),
        n_pending=n_pending,
        bytes_before=bytes_before,
        bytes_after=store.size_report()["total_bytes"],
        verified_bit_exact=verified,
        wall_time_s=elapsed_s,
        remap=remap,
        per_user=per_user,
    )


def recluster(
    store: ForestStore,
    mode: str = "extend",
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
    migrate: bool = True,
    verify: bool = True,
    journal: MigrationJournal | None = None,
    on_step=None,
    timer: Callable[[], float] = time.perf_counter,
) -> ReclusterResult:
    """Re-run fleet-scale clustering and migrate the store onto the
    successor codebook generation, bit-exactly.

    ``mode="extend"`` keeps every current cluster and appends clusters fit
    to the fallback models (identity remap: clean users relabel, warm
    caches survive); ``mode="full"`` rebuilds the codebook from the whole
    user union (maximal compression, most re-encoding).  With
    ``migrate=False`` only the successor codebook is installed — call
    ``migrate_users`` to move deltas over incrementally; the old
    generation stays resident (and serialized) until its last delta
    migrates.

    Crash safety (ISSUE 6): every phase is logged to ``journal`` (a fresh
    in-memory ``MigrationJournal`` when not given) before the store
    mutation it describes, and superseded codebook generations are
    garbage-collected strictly AFTER the journal commits — a crash at any
    point leaves the old generation resident and ``resume_recluster``
    able to roll the run forward (or roll uncommitted per-user
    migrations back) to a bit-exact store.  ``on_step(name)`` is called
    at each phase boundary (``build``, ``install``, ``migrate:<user>``,
    ``migrated:<user>``, ``commit``, ``gc``) — the fault-injection
    harness (``runtime.chaos.CrashSchedule``) hooks in here."""
    if mode not in ("extend", "full"):
        raise ValueError(f"unknown recluster mode {mode!r}")
    pending = {
        u for u in store.user_ids
        if store.delta(u).codebook_generation != store.generation
    }
    if pending:
        # the remap this run produces covers current -> current+1 only;
        # users still on an older generation would be stranded behind it
        raise ValueError(
            f"{len(pending)} user(s) still reference generation(s) "
            f"{sorted(store.generations)[:-1]}; finish the pending "
            "migration (lifecycle.migrate_users) before re-clustering "
            "again"
        )
    step = on_step if on_step is not None else (lambda name: None)
    if journal is None:
        journal = MigrationJournal()
    store.journal = journal
    t0 = timer()
    bytes_before = store.size_report()["total_bytes"]
    build = extend_codebook if mode == "extend" else rebuild_codebook
    step("build")
    new, remap = build(
        store, k_max=k_max, seed=seed, engine=engine, chunk_size=chunk_size
    )
    journal.log_built(mode, new, remap)
    step("install")
    store.install_codebook(new)
    journal.log_installed()
    per_user: dict[str, dict] = {}
    if migrate:
        per_user = _migrate_journaled(
            store, remap, journal, step, seed, verify
        )
    return _recluster_result(
        store, mode, remap, per_user, bytes_before,
        bool(verify and migrate), timer() - t0,
    )


def resume_recluster(
    store: ForestStore,
    journal: MigrationJournal,
    seed: int = 0,
    verify: bool = True,
    on_step=None,
    timer: Callable[[], float] = time.perf_counter,
) -> ReclusterResult:
    """Finish (or undo) a recluster run that crashed mid-flight, from its
    journal.  Idempotent: safe to call again after a crash DURING
    resumption, and a no-op on an already-committed journal.

    * state ``committed`` — the run finished; re-run the (idempotent)
      superseded-generation GC and return.
    * state ``built`` — the successor codebook and remap were journaled
      but never installed: deserialize them from the journal (the
      expensive clustering is NOT repeated), install, and migrate.
    * state ``installed`` — migration was in flight: every user whose
      intent was logged but never committed is ROLLED BACK to the exact
      pre-migration delta bytes recorded in the journal (the old
      codebook generation is guaranteed resident, because GC is deferred
      until commit), then migration re-runs; already-committed users are
      skipped via their journal record.
    * state ``idle`` — nothing was logged before the crash; the run never
      mutated the store, so there is nothing to resume (re-run
      ``recluster``, passing the same journal).
    """
    step = on_step if on_step is not None else (lambda name: None)
    store.journal = journal
    t0 = timer()
    bytes_before = store.size_report()["total_bytes"]
    if journal.state == "idle":
        raise ValueError(
            "journal is empty — the crashed run never mutated the store; "
            "re-run recluster() instead of resuming"
        )
    remap = RemapTable.from_bytes(journal.remap_bytes)
    if journal.state == "committed":
        step("gc")
        store.drop_unreferenced_codebooks()
        per_user = {
            u: {"status": e["status"] or "current"}
            for u, e in journal.entries.items()
        }
        for u, r in per_user.items():
            if u in store.user_ids:
                n = len(store.delta(u).to_bytes())
                r["bytes_before"] = n
                r["bytes"] = n
        return _recluster_result(
            store, journal.mode, remap, per_user, bytes_before, False,
            timer() - t0,
        )
    if journal.state == "built":
        # crashed between build and install — roll the install forward
        # from the journaled codebook bytes
        if store.generation < journal.new_generation:
            step("install")
            store.install_codebook(
                SharedCodebook.from_bytes(journal.codebook_bytes)
            )
        journal.log_installed()
    # state == "installed": roll back every uncommitted migration to the
    # exact pre-migration bytes, then re-migrate
    for u in journal.uncommitted_users:
        if u not in store.user_ids:
            continue
        intent = journal.entries[u]["intent"]
        if store.delta(u).to_bytes() != intent:
            step(f"rollback:{u}")
            store.add_delta(u, UserDelta.from_bytes(intent))
    per_user = _migrate_journaled(store, remap, journal, step, seed, verify)
    return _recluster_result(
        store, journal.mode, remap, per_user, bytes_before, verify,
        timer() - t0,
    )

"""repro.store — multi-tenant compressed forest store.

The paper's subscriber scenario at fleet scale: one fleet-level shared
codebook (Bregman clustering over the UNION of all users' empirical
models), per-user delta encoding that references shared clusters and ships
only residual streams, an LRU-cached decode runtime, a device-resident
tile arena for the pipelined serving path, and a codebook LIFECYCLE
(``store.lifecycle``): versioned codebook generations, a drift monitor,
and online re-clustering that migrates user deltas bit-exactly onto a
successor codebook.

Durability (``store.durable``): the fleet's on-disk tier — parity-
protected slab files indexed by an epoch-versioned RFN1 manifest, atomic
commits, crash recovery, parity repair of any single corrupt-or-missing
shard, background scrubbing, and lazy per-user loading.

Serving goes through ``repro.serving.ForestServer``; the on-disk formats
(RFS1/RFD1/RFT1/RFM1/RFN1) are specified byte-for-byte in
``docs/format.md`` and the subsystem architecture in
``docs/architecture.md``.
"""

from ..core.framing import UnrepairableError, atomic_write_bytes
from .arena import TileArena
from .codebook import SharedCodebook, SharedComponent, build_shared_codebook
from .delta import UserDelta, encode_user_delta, hydrate, reconstruct_user
from .durable import DurableStore, Scrubber, attach_auto_repair, xor_parity
from .fleet import make_drifted_fleet, make_request_batch, make_synthetic_fleet
from .lifecycle import (
    MigrationJournal,
    ReclusterResult,
    RemapTable,
    drift_report,
    extend_codebook_from_forests,
    migrate_user,
    migrate_users,
    recluster,
    resume_recluster,
)
from .residency import Prefetcher, ResidencyManager, attach_residency
from .runtime import ForestStore, TileCache, build_store
from .streaming import build_store_streaming

__all__ = [
    "DurableStore",
    "ForestStore",
    "MigrationJournal",
    "Prefetcher",
    "ReclusterResult",
    "RemapTable",
    "ResidencyManager",
    "Scrubber",
    "SharedCodebook",
    "SharedComponent",
    "TileArena",
    "TileCache",
    "UnrepairableError",
    "UserDelta",
    "atomic_write_bytes",
    "attach_auto_repair",
    "attach_residency",
    "build_shared_codebook",
    "build_store",
    "build_store_streaming",
    "drift_report",
    "extend_codebook_from_forests",
    "encode_user_delta",
    "hydrate",
    "make_drifted_fleet",
    "make_request_batch",
    "make_synthetic_fleet",
    "migrate_user",
    "migrate_users",
    "recluster",
    "reconstruct_user",
    "resume_recluster",
    "xor_parity",
]

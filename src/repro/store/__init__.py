"""repro.store — multi-tenant compressed forest store.

The paper's subscriber scenario at fleet scale: one fleet-level shared
codebook (Bregman clustering over the UNION of all users' empirical
models), per-user delta encoding that references shared clusters and ships
only residual streams, an LRU-cached decode runtime, and ragged
multi-tenant batched serving through the segment-aware Pallas kernel
(``repro.launch.serve_store``).
"""

from .arena import TileArena
from .codebook import SharedCodebook, SharedComponent, build_shared_codebook
from .delta import UserDelta, encode_user_delta, hydrate, reconstruct_user
from .fleet import make_request_batch, make_synthetic_fleet
from .runtime import ForestStore, TileCache, build_store

__all__ = [
    "ForestStore",
    "SharedCodebook",
    "SharedComponent",
    "TileArena",
    "TileCache",
    "UserDelta",
    "build_shared_codebook",
    "build_store",
    "encode_user_delta",
    "hydrate",
    "make_request_batch",
    "make_synthetic_fleet",
    "reconstruct_user",
]

"""Synthetic subscriber fleets for store benchmarks, tests, and demos.

Real fleets are redundant ACROSS users: every subscriber's forest is grown
by the same training pipeline on behaviourally similar data, so the
per-(depth, father-variable) empirical models of different users are close
— which is exactly what the fleet-level Bregman clustering exploits.  This
generator reproduces that structure without training: a fleet-wide
prototype (per-depth variable preferences, split-value profile, fit skew)
is perturbed per user, and trees are sampled from the perturbed model.

Regression fit values are drawn from a shared fleet pool (quantized fits,
as a deployment would do — see ``core.lossy.quantize_fits``), so the
fleet-union value table stays compact.
"""
from __future__ import annotations

import numpy as np

from ..core.tree import Forest, ForestMeta, Tree


def _sample_tree(
    rng: np.random.Generator,
    d: int,
    n_bins: int,
    max_depth: int,
    p_split_by_depth: np.ndarray,
    var_pref_by_depth: np.ndarray,  # (max_depth+1, d) probability rows
    split_profile: np.ndarray,  # (n_bins,) probability row
    fit_profile: np.ndarray,  # (n_fit_syms,) probability row
) -> Tree:
    feature, thresh, left, right, fit = [], [], [], [], []

    def build(depth: int) -> int:
        i = len(feature)
        feature.append(-1)
        thresh.append(-1)
        left.append(-1)
        right.append(-1)
        fit.append(int(rng.choice(len(fit_profile), p=fit_profile)))
        if depth < max_depth and rng.random() < p_split_by_depth[depth]:
            feature[i] = int(rng.choice(d, p=var_pref_by_depth[depth]))
            thresh[i] = int(rng.choice(n_bins, p=split_profile))
            left[i] = build(depth + 1)
            right[i] = build(depth + 1)
        return i

    build(0)
    return Tree(
        np.array(feature), np.array(thresh), np.array(left),
        np.array(right), np.array(fit, dtype=np.int64),
    )


def _gen_users(
    rng: np.random.Generator,
    meta: ForestMeta,
    n_users: int,
    name_offset: int,
    n_trees: tuple[int, int],
    max_depth: int,
    p_split: np.ndarray,
    var_pref: np.ndarray,
    split_profile: np.ndarray,
    fit_profile: np.ndarray,
    fleet_pool: np.ndarray,
    n_user_fit_values: int,
    user_jitter: float,
) -> dict[str, Forest]:
    """Per-user sampling loop shared by the synthetic and drifted fleet
    generators: perturb the prototype per user, sample ragged tree counts,
    and (regression) quantize each user onto a subset of the fleet pool."""
    d = meta.n_features
    n_bins = int(meta.n_bins_per_feature[0])
    fleet: dict[str, Forest] = {}
    for u in range(name_offset, name_offset + n_users):
        urng = np.random.default_rng(rng.integers(1 << 31))

        def jitter(p: np.ndarray) -> np.ndarray:
            q = p * np.exp(urng.normal(0, user_jitter, p.shape))
            return q / q.sum(-1, keepdims=True)

        u_var = np.stack([jitter(row) for row in var_pref])
        u_split = jitter(split_profile)
        u_fit = jitter(fit_profile)
        t_count = int(urng.integers(n_trees[0], n_trees[1] + 1))
        trees = [
            _sample_tree(
                urng, d, n_bins, max_depth, p_split, u_var, u_split, u_fit
            )
            for _ in range(t_count)
        ]
        if meta.task == "regression":
            # each user quantizes onto a subset of the fleet pool
            fit_values = np.sort(
                urng.choice(fleet_pool, n_user_fit_values, replace=False)
            )
        else:
            fit_values = np.zeros(0)
        fleet[f"user{u:05d}"] = Forest(
            trees=trees, meta=meta, fit_values=fit_values
        )
    return fleet


def make_synthetic_fleet(
    n_users: int,
    task: str = "classification",
    n_trees: tuple[int, int] = (8, 16),
    d: int = 8,
    n_bins: int = 16,
    max_depth: int = 6,
    n_classes: int = 2,
    n_fleet_fit_values: int = 64,
    n_user_fit_values: int = 24,
    user_jitter: float = 0.25,
    seed: int = 0,
) -> dict[str, Forest]:
    """Generate ``n_users`` forests sharing one schema and one (perturbed)
    fleet prototype.  Tree counts are ragged in ``n_trees=(lo, hi)``."""
    rng = np.random.default_rng(seed)
    n_fit_syms = n_classes if task == "classification" else n_user_fit_values
    # fleet prototype: skewed, depth-dependent — gives the clustering
    # something real to find
    var_pref = rng.dirichlet(np.full(d, 0.5), size=max_depth + 1)
    split_profile = rng.dirichlet(np.full(n_bins, 0.7))
    fit_profile = rng.dirichlet(np.full(n_fit_syms, 0.8))
    p_split = np.clip(
        np.linspace(0.95, 0.35, max_depth + 1) + rng.normal(0, 0.05, max_depth + 1),
        0.1, 1.0,
    )
    fleet_pool = (
        np.sort(rng.normal(size=n_fleet_fit_values))
        if task == "regression"
        else np.zeros(0)
    )

    meta = ForestMeta(
        n_features=d,
        task=task,
        n_classes=n_classes,
        n_bins_per_feature=np.full(d, n_bins, np.int32),
        n_train_obs=1000,
        categorical=np.zeros(d, dtype=bool),
    )
    return _gen_users(
        rng, meta, n_users, 0, n_trees, max_depth, p_split, var_pref,
        split_profile, fit_profile, fleet_pool, n_user_fit_values,
        user_jitter,
    )


def make_drifted_fleet(
    n_users: int,
    late_fraction: float = 0.3,
    task: str = "classification",
    n_trees: tuple[int, int] = (8, 16),
    d: int = 8,
    n_bins: int = 16,
    max_depth: int = 6,
    n_classes: int = 2,
    n_drift_features: int = 2,
    n_fleet_fit_values: int = 64,
    n_user_fit_values: int = 24,
    user_jitter: float = 0.25,
    seed: int = 0,
) -> tuple[dict[str, Forest], dict[str, Forest]]:
    """Generate a DRIFTED fleet for codebook-lifecycle scenarios: an
    initial population whose trees never touch the last
    ``n_drift_features`` features, and a late-onboarded population (the
    trailing ``late_fraction`` of users) that splits on them heavily — and
    (regression) carries fit values outside the initial fleet pool.

    A codebook built from the initial population alone therefore CANNOT
    code the late users' models (their symbols have zero fleet
    probability), forcing the user-local fallback path that
    ``store.lifecycle.drift_report`` monitors and ``recluster`` repairs.

    Returns ``(initial, late)`` — two disjoint ``{user_id: Forest}`` dicts
    sharing one schema and naming sequence.
    """
    if not 0.0 <= late_fraction <= 1.0:
        raise ValueError(f"late_fraction={late_fraction} not in [0, 1]")
    rng = np.random.default_rng(seed)
    n_fit_syms = n_classes if task == "classification" else n_user_fit_values
    var_pref = rng.dirichlet(np.full(d, 0.5), size=max_depth + 1)
    split_profile = rng.dirichlet(np.full(n_bins, 0.7))
    fit_profile = rng.dirichlet(np.full(n_fit_syms, 0.8))
    p_split = np.clip(
        np.linspace(0.95, 0.35, max_depth + 1) + rng.normal(0, 0.05, max_depth + 1),
        0.1, 1.0,
    )
    fleet_pool = (
        np.sort(rng.normal(size=n_fleet_fit_values))
        if task == "regression"
        else np.zeros(0)
    )
    # late users draw fits from a SHIFTED pool: none of its values exist in
    # the initial pool, so every late regression user onboards extras
    late_pool = (
        np.sort(rng.normal(loc=5.0, size=n_fleet_fit_values))
        if task == "regression"
        else np.zeros(0)
    )

    # initial population: zero preference mass on the drift features
    init_pref = var_pref.copy()
    init_pref[:, d - n_drift_features:] = 0.0
    init_pref /= init_pref.sum(-1, keepdims=True)
    # late population: strong preference for the drift features
    late_pref = var_pref.copy()
    late_pref[:, d - n_drift_features:] += 2.0 / max(n_drift_features, 1)
    late_pref /= late_pref.sum(-1, keepdims=True)

    meta = ForestMeta(
        n_features=d,
        task=task,
        n_classes=n_classes,
        n_bins_per_feature=np.full(d, n_bins, np.int32),
        n_train_obs=1000,
        categorical=np.zeros(d, dtype=bool),
    )
    n_late = int(round(n_users * late_fraction))
    n_initial = n_users - n_late
    initial = _gen_users(
        rng, meta, n_initial, 0, n_trees, max_depth, p_split, init_pref,
        split_profile, fit_profile, fleet_pool, n_user_fit_values,
        user_jitter,
    )
    late = _gen_users(
        rng, meta, n_late, n_initial, n_trees, max_depth, p_split,
        late_pref, split_profile, fit_profile, late_pool,
        n_user_fit_values, user_jitter,
    )
    return initial, late


def make_request_batch(
    store, n_requests: int, rows_per_request: int, seed: int = 0
) -> list[tuple[str, np.ndarray]]:
    """Random mixed-user request batch against a store — the workload the
    serving demos and benchmarks share (one helper so they all measure the
    same request shape)."""
    rng = np.random.default_rng(seed)
    d = store.shared.n_features
    n_bins = int(store.shared.n_bins_per_feature[0])
    users = store.user_ids
    return [
        (
            users[int(rng.integers(len(users)))],
            rng.integers(0, n_bins, (rows_per_request, d)).astype(np.int32),
        )
        for _ in range(n_requests)
    ]

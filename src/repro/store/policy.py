"""Shared eviction policy for the store's decode caches (ISSUE 3).

Both the host tile cache (``TileCache``) and the device tile arena
(``TileArena``) evict by GreedyDual with a decode-cost weight: an entry's
priority is ``clock + cost`` at insert/access, the minimum-priority entry
is evicted first (ties broken least-recently-used), and the clock advances
to each evicted priority so long-idle expensive entries age out.  Equal
costs reduce exactly to LRU.  One implementation here keeps the two caches'
policies from drifting apart.
"""
from __future__ import annotations


def decode_cost(n_trees: int, heap_width: int) -> float:
    """Reconstruction cost proxy of a resident run: trees * 2**depth (a
    heap of width h holds 2**(depth+1) - 1 slots)."""
    return n_trees * (heap_width + 1) / 2


class GreedyDualClock:
    """The policy core: hands out ``(priority, last_access)`` keys and
    tracks the aging clock.  Containers keep their own entry maps and call
    ``touch`` on insert/access, ``evicted`` with each victim's priority,
    and pick victims as ``min()`` over the issued keys."""

    def __init__(self) -> None:
        self.clock = 0.0
        self._tick = 0

    def touch(self, cost: float) -> tuple[float, int]:
        """Priority key for an inserted/accessed entry of ``cost``."""
        self._tick += 1
        return (self.clock + cost, self._tick)

    def evicted(self, priority: float) -> None:
        """Advance the aging clock to an evicted entry's priority."""
        self.clock = priority

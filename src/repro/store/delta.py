"""Per-user delta encoding against the fleet codebook (store piece 2).

A ``UserDelta`` is a user's forest compressed AGAINST the shared fleet
codebooks: it stores the structure stream (per-user Zaks + LZW, as in the
inline codec), a per-component kid→cluster map whose entries reference the
SHARED cluster codebooks by id, and the residual symbol streams — but no
codebooks of its own.  Dictionary bytes, the dominant cost for small
subscriber forests, are paid once per fleet instead of once per user.

Cluster choice is byte-exact greedy: each of the user's models picks the
shared cluster minimizing the ACTUAL coded bits of its symbols (Huffman
code lengths / arithmetic -log2 q), restricted to clusters that can code
every symbol the model emits.  Models no shared cluster can code (possible
only for users onboarded after the codebook was frozen, with symbols the
fleet never produced) fall back to USER-LOCAL clusters whose codebooks ship
inside the delta — lossless onboarding without a fleet rebuild.

``hydrate`` resolves a delta back into a plain inline ``CompressedForest``
(codebook ownership is pluggable in ``core.forest_codec``), so every
existing consumer — ``decompress_forest``, ``predict_compressed``, the
Pallas serving drivers — works on store-resident forests unchanged.
Reconstruction is bit-exact, including regression fit-value tables, which
round-trip through the fleet-union table plus a per-user int32 map.
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass, field

import numpy as np

from ..core.arithmetic import ArithmeticCode
from ..core.forest_codec import (
    ClusteredComponent,
    ComponentCodec,
    CompressedForest,
    decompress_forest,
    emit_streams,
)
from ..core.huffman import HuffmanCode
from ..core.lz import lzw_encode_bits
from ..core.stats import (
    alpha_fits,
    alpha_splits,
    alpha_vars,
    extract_records,
    fit_counts,
    key_id,
    split_counts,
    var_name_counts,
)
from ..core.framing import (
    check_crc,
    expect_magic,
    read_arr,
    read_bytes,
    read_struct,
    with_crc,
    write_arr,
    write_bytes,
)
from ..core.tree import Forest
from ..core.zaks import zaks_encode
from .codebook import (
    SharedCodebook,
    SharedComponent,
    cluster_codebooks,
    fit_value_ids,
)

_MAGIC = b"RFD1"


@dataclass
class DeltaComponent:
    """One component of a user delta: shared-or-local cluster references plus
    the user's residual streams.

    ``kid_to_ref`` entries: -1 for unused keys, ``0..S-1`` reference the
    shared codebook's clusters, ``S + j`` references user-local cluster j
    (codebooks stored inline below)."""

    coder: str  # "huffman" | "arithmetic"
    kid_to_ref: np.ndarray  # (n_user_keys,) int16
    local_lengths: list[np.ndarray] = field(default_factory=list)
    local_freqs: list[np.ndarray] = field(default_factory=list)
    refs: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int16))
    n_symbols: list[int] = field(default_factory=list)
    streams: list[bytes] = field(default_factory=list)

    @property
    def n_local(self) -> int:
        if self.coder == "huffman":
            return len(self.local_lengths)
        return len(self.local_freqs)


@dataclass
class UserDelta:
    """A user's forest, delta-encoded against a ``SharedCodebook``.

    ``codebook_generation`` names the generation of the shared codebook
    every shared cluster reference resolves against — decoding a delta
    against any other generation is a framing error.  The store keeps a
    superseded codebook alive until the last delta referencing it has
    been migrated (``store.lifecycle``)."""

    codebook_generation: int
    n_trees: int
    max_depth: int
    n_train_obs: int
    zaks_payload: bytes
    zaks_total_bits: int
    zaks_lengths: np.ndarray
    vars_dc: DeltaComponent
    splits_dc: dict[int, DeltaComponent]
    fits_dc: DeltaComponent
    # regression: local fit id -> fleet id (>= 0) or extra id (-(i+1));
    # ``extra_fit_values`` holds values the fleet table lacks (late onboard)
    fit_map: np.ndarray
    extra_fit_values: np.ndarray

    # ---------------- serialization ---------------------------------------
    def to_bytes(self) -> bytes:
        """Serialize as one RFD1 frame (normative spec: docs/format.md)."""
        out = io.BytesIO()
        out.write(_MAGIC)
        out.write(
            struct.pack(
                "<HIHII",
                self.codebook_generation,
                self.n_trees, self.max_depth, self.n_train_obs,
                self.zaks_total_bits,
            )
        )
        write_arr(out, self.zaks_lengths.astype(np.int32))
        write_bytes(out, self.zaks_payload)
        _write_delta_component(out, self.vars_dc)
        out.write(struct.pack("<H", len(self.splits_dc)))
        for v, c in sorted(self.splits_dc.items()):
            out.write(struct.pack("<H", v))
            _write_delta_component(out, c)
        _write_delta_component(out, self.fits_dc)
        write_arr(out, self.fit_map.astype(np.int32))
        write_arr(out, self.extra_fit_values.astype(np.float64))
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "UserDelta":
        """Parse one RFD1 frame (normative spec: docs/format.md).  The
        CRC32 trailer is verified when present; corruption raises a typed
        ``core.framing.IntegrityError`` / ``TruncatedFrameError``."""
        inp = io.BytesIO(check_crc(data, "RFD1 user delta"))
        expect_magic(inp, _MAGIC, "RFD1 user delta")
        gen, n_trees, max_depth, n_obs, zbits = read_struct(
            inp, "<HIHII", "RFD1 header"
        )
        zaks_lengths = read_arr(inp).astype(np.int32)
        zaks_payload = read_bytes(inp)
        vars_dc = _read_delta_component(inp)
        (ns,) = read_struct(inp, "<H", "RFD1 split-component count")
        splits_dc = {}
        for _ in range(ns):
            (v,) = read_struct(inp, "<H", "RFD1 split variable id")
            splits_dc[v] = _read_delta_component(inp)
        fits_dc = _read_delta_component(inp)
        fit_map = read_arr(inp).astype(np.int64)
        extra = read_arr(inp).astype(np.float64)
        return cls(
            codebook_generation=gen,
            n_trees=n_trees, max_depth=max_depth, n_train_obs=n_obs,
            zaks_payload=zaks_payload, zaks_total_bits=zbits,
            zaks_lengths=zaks_lengths, vars_dc=vars_dc,
            splits_dc=splits_dc, fits_dc=fits_dc,
            fit_map=fit_map, extra_fit_values=extra,
        )


def _write_delta_component(out: io.BytesIO, c: DeltaComponent) -> None:
    out.write(struct.pack("<B", 1 if c.coder == "arithmetic" else 0))
    write_arr(out, c.kid_to_ref.astype(np.int16))
    out.write(struct.pack("<H", c.n_local))
    for j in range(c.n_local):
        if c.coder == "huffman":
            write_arr(out, np.asarray(c.local_lengths[j], np.uint8))
        else:
            write_arr(out, np.asarray(c.local_freqs[j], np.int64))
    out.write(struct.pack("<H", len(c.streams)))
    for ref, n, s in zip(c.refs, c.n_symbols, c.streams):
        out.write(struct.pack("<hI", int(ref), int(n)))
        write_bytes(out, s)


def _read_delta_component(inp: io.BytesIO) -> DeltaComponent:
    (is_arith,) = read_struct(inp, "<B", "RFD1 component coder tag")
    coder = "arithmetic" if is_arith else "huffman"
    kid_to_ref = read_arr(inp).astype(np.int16)
    (nl,) = read_struct(inp, "<H", "RFD1 local-cluster count")
    local_lengths, local_freqs = [], []
    for _ in range(nl):
        tab = read_arr(inp)
        if is_arith:
            local_freqs.append(tab.astype(np.int64))
        else:
            local_lengths.append(tab.astype(np.int32))
    (nstr,) = read_struct(inp, "<H", "RFD1 stream count")
    refs, n_symbols, streams = [], [], []
    for _ in range(nstr):
        ref, n = read_struct(inp, "<hI", "RFD1 stream header")
        refs.append(ref)
        n_symbols.append(n)
        streams.append(read_bytes(inp))
    return DeltaComponent(
        coder=coder, kid_to_ref=kid_to_ref,
        local_lengths=local_lengths, local_freqs=local_freqs,
        refs=np.asarray(refs, np.int16), n_symbols=n_symbols,
        streams=streams,
    )


# --------------------------------------------------------------------------
# encoding
# --------------------------------------------------------------------------
# every referenced cluster costs one stream frame in the delta (int16 ref +
# uint32 n_symbols + uint32 length prefix + ~half a byte of bit padding)
_STREAM_OVERHEAD_BITS = 8 * (2 + 4 + 4) + 4


def _consolidate_refs(bits: np.ndarray, assign: np.ndarray) -> np.ndarray:
    """Facility-location greedy over shared-cluster references.

    ``bits[u, s]`` is the coded size of model u under cluster s (inf where
    uncodable); ``assign`` starts at the per-model argmin.  Each referenced
    cluster costs ``_STREAM_OVERHEAD_BITS`` of per-user framing, so we
    repeatedly close the cluster whose members' cheapest-alternative penalty
    is smaller than the frame it frees, until no closure pays."""
    while True:
        open_refs = np.unique(assign)
        if len(open_refs) <= 1:
            return assign
        best_saving, best_close, best_moved = 0.0, None, None
        for c in open_refs:
            members = np.flatnonzero(assign == c)
            alt = bits[np.ix_(members, open_refs[open_refs != c])]
            j = np.argmin(alt, axis=1)
            alt_cost = alt[np.arange(len(members)), j]
            if not np.isfinite(alt_cost).all():
                continue  # some member is codable only by c
            penalty = float(
                (alt_cost - bits[members, assign[members]]).sum()
            )
            saving = _STREAM_OVERHEAD_BITS - penalty
            if saving > best_saving:
                best_saving = saving
                best_close = c
                best_moved = (members, open_refs[open_refs != c][j])
        if best_close is None:
            return assign
        members, targets = best_moved
        assign = assign.copy()
        assign[members] = targets


def _assign_against_shared(
    counts: np.ndarray,
    shared: SharedComponent,
    alpha_bits: float,
    coder: str,
    k_max_local: int,
    seed: int,
) -> DeltaComponent:
    """Pick shared clusters for every used model key: per-model cheapest
    CODABLE cluster, then facility-location consolidation (a referenced
    cluster costs a stream frame in the delta); models the shared codebook
    cannot code at all go to user-local clusters."""
    n_keys, alphabet = counts.shape
    s = shared.n_clusters
    kid_to_ref = np.full(n_keys, -1, dtype=np.int16)
    used = np.flatnonzero(counts.sum(-1) > 0)
    dc = DeltaComponent(coder, kid_to_ref)
    if not len(used):
        return dc
    cost = shared.cost_table()  # (S, B_shared)
    if s and alphabet > shared.alphabet:  # late-onboard alphabet growth
        pad = np.full((s, alphabet - shared.alphabet), np.inf)
        cost = np.concatenate([cost, pad], axis=1)
    local_rows = []
    if s:
        rows = counts[used].astype(np.float64)  # (U, B)
        # bits[u, s] = coded size of model u under cluster s; inf where the
        # cluster lacks a codeword for a symbol the model emits
        finite_cost = np.where(np.isfinite(cost), cost, 0.0)
        bits = rows @ finite_cost.T
        uncodable = (rows[:, None, :] > 0) & ~np.isfinite(cost)[None, :, :]
        bits[uncodable.any(-1)] = np.inf
        codable_any = np.isfinite(bits).any(-1)
        assign = np.where(codable_any, np.argmin(bits, axis=1), -1)
        cod = np.flatnonzero(codable_any)
        if len(cod):
            assign[cod] = _consolidate_refs(bits[cod], assign[cod])
        for u, kid in enumerate(used):
            if assign[u] >= 0:
                kid_to_ref[kid] = assign[u]
            else:
                kid_to_ref[kid] = s + len(local_rows)  # placeholder
                local_rows.append(kid)
    else:
        for kid in used:
            kid_to_ref[kid] = s + len(local_rows)
            local_rows.append(kid)
    if local_rows:
        # cluster the leftover models into a small set of local codebooks
        compact, dc.local_lengths, dc.local_freqs = cluster_codebooks(
            counts[local_rows].astype(np.float64), alpha_bits, coder,
            k_max_local, seed,
        )
        for kid, c in zip(local_rows, compact):
            kid_to_ref[kid] = s + int(c)
    return dc


def _delta_codec(
    dc: DeltaComponent, shared: SharedComponent
) -> ComponentCodec:
    """ComponentCodec whose coder list spans shared ids then local ids,
    instantiating only the clusters this user actually references."""
    s = shared.n_clusters
    coders: list = [None] * (s + dc.n_local)
    for ref in np.unique(dc.kid_to_ref[dc.kid_to_ref >= 0]):
        ref = int(ref)
        if ref < s:
            coders[ref] = shared.coder_for(ref)
        elif dc.coder == "huffman":
            coders[ref] = HuffmanCode(dc.local_lengths[ref - s])
        else:
            coders[ref] = ArithmeticCode(dc.local_freqs[ref - s])
    return ComponentCodec(dc.kid_to_ref, coders)


def _keep_nonempty(dc: DeltaComponent, streams, n_symbols) -> None:
    refs = [c for c, n in enumerate(n_symbols) if n > 0]
    dc.refs = np.asarray(refs, np.int16)
    dc.n_symbols = [n_symbols[c] for c in refs]
    dc.streams = [streams[c] for c in refs]


def encode_user_delta(
    forest: Forest,
    shared: SharedCodebook,
    k_max_local: int = 4,
    seed: int = 0,
) -> UserDelta:
    """Delta-encode one user's forest against the fleet codebook."""
    meta = forest.meta
    d = meta.n_features
    if d != shared.n_features or meta.task != shared.task:
        raise ValueError("forest schema does not match the shared codebook")
    rec = extract_records(forest)
    t_max = int(rec.depth.max()) + 1 if len(rec.depth) else 1

    # ---- structure (stays per-user, as in the inline codec) --------------
    zaks_list = [zaks_encode(t) for t in forest.trees]
    zaks_lengths = np.array([len(z) for z in zaks_list], dtype=np.int32)
    zaks_all = (
        np.concatenate(zaks_list) if zaks_list else np.zeros(0, np.uint8)
    )
    zaks_payload = lzw_encode_bits(zaks_all)

    # ---- fit symbols: remap into the fleet (+extra) alphabet -------------
    if meta.task == "classification":
        fit_map = np.zeros(0, np.int64)
        extra_values = np.zeros(0, np.float64)
        n_fit_syms = meta.n_classes
        fit_syms = rec.fit.astype(np.int64)
    else:
        fleet = shared.fleet_fit_values
        vals = np.asarray(forest.fit_values, np.float64)
        # the fleet table is only append-ordered across generations, so the
        # lookup goes through the argsort view, not a raw searchsorted
        hit, ids = fit_value_ids(fleet, vals)
        extra_values = vals[~hit]
        fit_map = np.where(
            hit, ids, -(np.cumsum(~hit) - 1) - 1
        ).astype(np.int64)
        ext_ids = np.where(hit, ids, len(fleet) + np.cumsum(~hit) - 1)
        n_fit_syms = len(fleet) + len(extra_values)
        fit_syms = ext_ids[rec.fit.astype(np.int64)]
    rec_f = type(rec)(
        tree_id=rec.tree_id, depth=rec.depth, father_var=rec.father_var,
        var=rec.var, split=rec.split, fit=fit_syms, is_leaf=rec.is_leaf,
    )

    # ---- per-component shared-cluster assignment + local fallback --------
    vars_dc = _assign_against_shared(
        var_name_counts(rec, d, t_max), shared.vars_comp,
        alpha_vars(d), "huffman", k_max_local, seed,
    )
    splits_dc: dict[int, DeltaComponent] = {}
    for v, cnts in split_counts(rec, d, t_max, meta.n_bins_per_feature).items():
        sh = shared.splits_comp.get(
            v, SharedComponent("huffman", cnts.shape[1])
        )
        a = alpha_splits(
            not bool(meta.categorical[v]), meta.n_train_obs,
            int(meta.n_bins_per_feature[v]),
        )
        splits_dc[v] = _assign_against_shared(
            cnts, sh, a, "huffman", k_max_local, seed
        )
    fits_coder = shared.fits_comp.coder
    fits_dc = _assign_against_shared(
        fit_counts(rec_f, d, t_max, n_fit_syms), shared.fits_comp,
        alpha_fits(meta.task, n_fit_syms), fits_coder, k_max_local, seed,
    )

    # ---- emit residual streams in global preorder ------------------------
    vs, vn, ss, sn, fs, fn = emit_streams(
        rec, d,
        _delta_codec(vars_dc, shared.vars_comp),
        {
            v: _delta_codec(
                dc,
                shared.splits_comp.get(
                    v,
                    SharedComponent(
                        "huffman", int(meta.n_bins_per_feature[v])
                    ),
                ),
            )
            for v, dc in splits_dc.items()
        },
        _delta_codec(fits_dc, shared.fits_comp),
        fit_syms,
    )
    _keep_nonempty(vars_dc, vs, vn)
    for v, dc in splits_dc.items():
        _keep_nonempty(dc, ss[v], sn[v])
    _keep_nonempty(fits_dc, fs, fn)

    return UserDelta(
        codebook_generation=shared.generation,
        n_trees=forest.n_trees,
        max_depth=t_max - 1,
        n_train_obs=meta.n_train_obs,
        zaks_payload=zaks_payload,
        zaks_total_bits=int(zaks_lengths.sum()),
        zaks_lengths=zaks_lengths,
        vars_dc=vars_dc,
        splits_dc=splits_dc,
        fits_dc=fits_dc,
        fit_map=fit_map,
        extra_fit_values=extra_values,
    )


# --------------------------------------------------------------------------
# hydration + reconstruction
# --------------------------------------------------------------------------
def _hydrate_component(
    dc: DeltaComponent, shared: SharedComponent
) -> ClusteredComponent:
    """Materialize a delta component as an inline ``ClusteredComponent``:
    referenced shared codebooks are copied in, cluster ids compacted to
    stream order."""
    s = shared.n_clusters
    ref_pos = {int(r): i for i, r in enumerate(dc.refs)}
    kid_map = np.full(len(dc.kid_to_ref), -1, dtype=np.int16)
    for kid, ref in enumerate(dc.kid_to_ref):
        if ref >= 0:
            kid_map[kid] = ref_pos[int(ref)]
    lengths, freqs = [], []
    for r in dc.refs:
        r = int(r)
        if dc.coder == "huffman":
            src = (
                shared.codebook_lengths[r] if r < s
                else dc.local_lengths[r - s]
            )
            lengths.append(np.asarray(src, np.int32))
            freqs.append(np.zeros(0, np.int64))
        else:
            src = shared.freqs[r] if r < s else dc.local_freqs[r - s]
            freqs.append(np.asarray(src, np.int64))
            lengths.append(np.zeros(0, np.int32))
    return ClusteredComponent(
        kid_map, lengths, list(dc.streams), list(dc.n_symbols),
        dc.coder, freqs,
    )


def hydrate(delta: UserDelta, shared: SharedCodebook) -> CompressedForest:
    """Resolve a user delta into a plain inline ``CompressedForest`` (every
    existing decode/predict/serve path applies).  Regression node fits come
    out as FLEET ids with ``fit_values`` set to the fleet(+extra) table —
    numerically identical predictions; use ``reconstruct_user`` for the
    bit-exact original forest."""
    if delta.codebook_generation != shared.generation:
        raise ValueError(
            f"delta references codebook generation "
            f"{delta.codebook_generation}, got generation "
            f"{shared.generation}"
        )
    meta = shared.user_meta(delta.n_train_obs)
    if shared.task == "regression":
        fit_values = np.concatenate(
            [shared.fleet_fit_values, delta.extra_fit_values]
        )
    else:
        fit_values = np.zeros(0, np.float64)
    splits_comp = {
        v: _hydrate_component(
            dc,
            shared.splits_comp.get(
                v,
                SharedComponent(
                    "huffman", int(shared.n_bins_per_feature[v])
                ),
            ),
        )
        for v, dc in delta.splits_dc.items()
    }
    return CompressedForest(
        meta=meta,
        n_trees=delta.n_trees,
        zaks_payload=delta.zaks_payload,
        zaks_total_bits=delta.zaks_total_bits,
        zaks_lengths=delta.zaks_lengths,
        vars_comp=_hydrate_component(delta.vars_dc, shared.vars_comp),
        splits_comp=splits_comp,
        fits_comp=_hydrate_component(delta.fits_dc, shared.fits_comp),
        fit_values=fit_values,
        max_depth=delta.max_depth,
    )


def reconstruct_user(delta: UserDelta, shared: SharedCodebook) -> Forest:
    """Bit-exact reconstruction of the user's original forest, including the
    user-local fit-value table and node-fit indices."""
    forest = decompress_forest(hydrate(delta, shared))
    if shared.task != "regression":
        return forest
    n_fleet = len(shared.fleet_fit_values)
    ext_ids = np.where(
        delta.fit_map >= 0, delta.fit_map, n_fleet + (-delta.fit_map - 1)
    )
    n_ext = n_fleet + len(delta.extra_fit_values)
    inv = np.full(n_ext, -1, dtype=np.int64)
    inv[ext_ids] = np.arange(len(ext_ids))
    for t in forest.trees:
        t.node_fit = inv[t.node_fit.astype(np.int64)]
    ext_table = np.concatenate(
        [shared.fleet_fit_values, delta.extra_fit_values]
    )
    forest.fit_values = ext_table[ext_ids]
    return forest

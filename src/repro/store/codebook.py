"""Fleet-level shared codebooks (multi-tenant store, piece 1).

The paper's subscriber scenario puts ONE user-specific forest on a
storage-constrained device; at fleet scale the empirical models of
different users' forests are highly redundant.  This module pools the
``stats.extract_records`` model counts across a whole fleet of forests and
runs the same KL K-means / objective-(6) machinery of ``core.bregman`` on
the UNION of all users' models — M is then #users x #model-keys and easily
reaches 1e5+, which is what the chunked assignment engine is for.

The result is a ``SharedCodebook``: per component (variable names, split
values per variable, fits) a set of cluster codebooks built from the pooled
member counts, stored ONCE for the fleet.  Per-user deltas
(``store.delta``) then reference these codebooks by cluster id and carry
only residual streams.

Regression fits are pooled through a fleet-level value table: the union of
every user's distinct 64-bit fit values, stored once; per-user deltas keep
an int32 map from their local fit ids into the fleet table (4 bytes/line
instead of 8) and reconstruct their exact local table from it.
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..core.arithmetic import ArithmeticCode
from ..core.bregman import cluster_models
from ..core.huffman import HuffmanCode
from ..core.stats import (
    alpha_fits,
    alpha_splits,
    alpha_vars,
    extract_records,
    fit_counts,
    split_counts,
    var_name_counts,
)
from ..core.tree import Forest, ForestMeta
from ..core.framing import (
    check_crc,
    expect_magic,
    read_arr,
    read_struct,
    with_crc,
    write_arr,
)

_MAGIC = b"RFS1"


@dataclass
class SharedComponent:
    """One component's fleet-level cluster codebooks.

    ``coder == "huffman"``: ``codebook_lengths[k]`` is the canonical code
    length table of cluster k (built from the pooled member counts).
    ``coder == "arithmetic"``: ``freqs[k]`` is the pooled count table the
    static arithmetic coder is constructed from on both ends.
    """

    coder: str  # "huffman" | "arithmetic"
    alphabet: int
    codebook_lengths: list[np.ndarray] = field(default_factory=list)
    freqs: list[np.ndarray] = field(default_factory=list)

    @property
    def n_clusters(self) -> int:
        if self.coder == "huffman":
            return len(self.codebook_lengths)
        return len(self.freqs)

    def coder_for(self, k: int):
        """Instantiate cluster ``k``'s entropy coder (HuffmanCode from its
        canonical lengths, or ArithmeticCode from its pooled counts)."""
        if self.coder == "huffman":
            return HuffmanCode(self.codebook_lengths[k])
        return ArithmeticCode(self.freqs[k])

    def cost_table(self) -> np.ndarray:
        """(K, B) expected bits per symbol occurrence under each cluster's
        code; +inf where the cluster cannot code the symbol at all.  Deltas
        pick, per model, the cluster minimizing ACTUAL coded bits — the
        store-side analogue of the KL assignment (up to Huffman integer
        rounding), and exactly the quantity billed on disk.

        Clusters appended by an ``extend``-mode recluster may carry tables
        shorter than the component's (grown) alphabet — symbols past a
        cluster's table end are simply uncodable by it (+inf)."""
        k = self.n_clusters
        cost = np.full((k, self.alphabet), np.inf)
        for c in range(k):
            if self.coder == "huffman":
                ln = np.asarray(self.codebook_lengths[c], dtype=np.float64)
                cost[c, : len(ln)][ln > 0] = ln[ln > 0]
            else:
                f = np.asarray(self.freqs[c], dtype=np.float64)
                tot = f.sum()
                cost[c, : len(f)][f > 0] = -np.log2(f[f > 0] / tot)
        return cost


@dataclass
class SharedCodebook:
    """Fleet-wide schema + shared cluster codebooks for every component.

    ``generation`` is the codebook's lifecycle version (v1, v2, ...): the
    store's re-clustering operation (``store.lifecycle.recluster``) builds
    a successor codebook with ``generation + 1`` and migrates user deltas
    onto it; every ``UserDelta`` records the generation it references, so
    old and new codebooks can coexist mid-migration.

    ``fleet_fit_values`` (regression) is the fleet-union value table.  It
    is SORTED within each generation's contribution but only
    APPEND-ORDERED across generations: an ``extend``-mode recluster
    appends newly-onboarded values after the previous generation's block,
    so existing deltas' fit-symbol ids stay valid without re-encoding.
    """

    n_features: int
    task: str  # "classification" | "regression"
    n_classes: int
    t_max: int  # fleet max depth + 1 (model-key table height)
    n_train_obs: int  # fleet max (alpha bookkeeping only)
    n_bins_per_feature: np.ndarray  # (d,) int32
    categorical: np.ndarray  # (d,) bool
    vars_comp: SharedComponent
    splits_comp: dict[int, SharedComponent]
    fits_comp: SharedComponent
    fleet_fit_values: np.ndarray  # regression: append-ordered union table
    generation: int = 1  # codebook lifecycle version (v1, v2, ...)

    def user_meta(self, n_train_obs: int) -> ForestMeta:
        """The fleet schema as one user's ``ForestMeta`` (the per-user
        ``n_train_obs`` is the only field the fleet does not fix)."""
        return ForestMeta(
            n_features=self.n_features,
            task=self.task,
            n_classes=self.n_classes,
            n_bins_per_feature=self.n_bins_per_feature,
            n_train_obs=n_train_obs,
            categorical=self.categorical,
        )

    # ---------------- serialization ---------------------------------------
    def to_bytes(self) -> bytes:
        """Serialize as one RFS1 frame (normative spec: docs/format.md)."""
        out = io.BytesIO()
        out.write(_MAGIC)
        out.write(
            struct.pack(
                "<HIBHHI",
                self.generation,
                self.n_features,
                1 if self.task == "regression" else 0,
                self.n_classes,
                self.t_max,
                self.n_train_obs,
            )
        )
        write_arr(out, self.n_bins_per_feature.astype(np.int32))
        write_arr(out, self.categorical.astype(np.uint8))
        _write_component(out, self.vars_comp)
        out.write(struct.pack("<H", len(self.splits_comp)))
        for v, c in sorted(self.splits_comp.items()):
            out.write(struct.pack("<H", v))
            _write_component(out, c)
        _write_component(out, self.fits_comp)
        write_arr(out, self.fleet_fit_values.astype(np.float64))
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "SharedCodebook":
        """Parse one RFS1 frame (normative spec: docs/format.md).  The
        CRC32 trailer is verified when present; corruption raises a typed
        ``core.framing.IntegrityError`` / ``TruncatedFrameError``."""
        inp = io.BytesIO(check_crc(data, "RFS1 shared codebook"))
        expect_magic(inp, _MAGIC, "RFS1 shared codebook")
        gen, d, is_reg, n_classes, t_max, n_obs = read_struct(
            inp, "<HIBHHI", "RFS1 header"
        )
        n_bins = read_arr(inp).astype(np.int32)
        categorical = read_arr(inp).astype(bool)
        vars_comp = _read_component(inp)
        (ns,) = read_struct(inp, "<H", "RFS1 split-component count")
        splits_comp = {}
        for _ in range(ns):
            (v,) = read_struct(inp, "<H", "RFS1 split variable id")
            splits_comp[v] = _read_component(inp)
        fits_comp = _read_component(inp)
        fleet_fit_values = read_arr(inp).astype(np.float64)
        return cls(
            n_features=d,
            task="regression" if is_reg else "classification",
            n_classes=n_classes,
            t_max=t_max,
            n_train_obs=n_obs,
            n_bins_per_feature=n_bins,
            categorical=categorical,
            vars_comp=vars_comp,
            splits_comp=splits_comp,
            fits_comp=fits_comp,
            fleet_fit_values=fleet_fit_values,
            generation=gen,
        )


def _write_component(out: io.BytesIO, c: SharedComponent) -> None:
    out.write(
        struct.pack(
            "<BHI",
            1 if c.coder == "arithmetic" else 0,
            c.n_clusters,
            c.alphabet,
        )
    )
    for k in range(c.n_clusters):
        if c.coder == "huffman":
            write_arr(out, np.asarray(c.codebook_lengths[k], np.uint8))
        else:
            write_arr(out, np.asarray(c.freqs[k], np.int64))


def _read_component(inp: io.BytesIO) -> SharedComponent:
    is_arith, nk, alphabet = read_struct(
        inp, "<BHI", "RFS1 component header"
    )
    comp = SharedComponent(
        "arithmetic" if is_arith else "huffman", alphabet
    )
    for _ in range(nk):
        tab = read_arr(inp)
        if is_arith:
            comp.freqs.append(tab.astype(np.int64))
        else:
            comp.codebook_lengths.append(tab.astype(np.int32))
    return comp


def fit_value_ids(
    table: np.ndarray, vals: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Look up ``vals`` in a fleet fit-value ``table`` that is only
    APPEND-ORDERED (sorted per generation block, not globally — see
    ``SharedCodebook``).  Returns ``(hit, ids)``: ``hit[i]`` is True when
    ``vals[i]`` exists in the table and ``ids[i]`` is then its table
    position (first occurrence); ``ids`` is undefined where ``hit`` is
    False.  O((T+V) log T) via an argsort view."""
    vals = np.asarray(vals, np.float64)
    if not len(table) or not len(vals):
        return np.zeros(len(vals), bool), np.zeros(len(vals), np.int64)
    order = np.argsort(table, kind="stable")  # stable: first occurrence wins
    sorted_table = table[order]
    pos = np.searchsorted(sorted_table, vals)
    pos_c = np.minimum(pos, len(table) - 1)
    hit = (sorted_table[pos_c] == vals) & (pos < len(table))
    return hit, order[pos_c].astype(np.int64)


def _validate_fleet_schema(forests: Sequence[Forest]) -> ForestMeta:
    if not forests:
        raise ValueError("cannot build a shared codebook from an empty fleet")
    m0 = forests[0].meta
    for f in forests[1:]:
        m = f.meta
        if (
            m.n_features != m0.n_features
            or m.task != m0.task
            or m.n_classes != m0.n_classes
            or not np.array_equal(m.n_bins_per_feature, m0.n_bins_per_feature)
            or not np.array_equal(m.categorical, m0.categorical)
        ):
            raise ValueError(
                "fleet forests must share one schema "
                "(n_features/task/n_classes/bins/categorical)"
            )
    return m0


def cluster_codebooks(
    rows: np.ndarray,
    alpha_bits: float,
    coder: str,
    k_max: int,
    seed: int,
    engine: str = "chunked",
    chunk_size: int = 65536,
) -> tuple[np.ndarray, list[np.ndarray], list[np.ndarray]]:
    """Cluster count rows under objective (6) and build one codebook per
    used cluster from the pooled member counts.  Shared by the fleet
    builder and the per-user local-cluster fallback (``store.delta``).

    Returns (compact assignments (M,), huffman lengths per cluster,
    arithmetic freq tables per cluster) — one of the two lists is empty,
    per ``coder``."""
    res = cluster_models(
        rows, alpha_bits, k_max=k_max, seed=seed,
        engine=engine, chunk_size=chunk_size,
    )
    uniq, compact = np.unique(res.assignments, return_inverse=True)
    lengths: list[np.ndarray] = []
    freqs: list[np.ndarray] = []
    for c in range(len(uniq)):
        pooled = rows[compact == c].sum(0)
        if coder == "huffman":
            lengths.append(HuffmanCode.from_freqs(pooled).lengths)
        else:
            freqs.append(pooled.astype(np.int64))
    return compact, lengths, freqs


def _pool_and_cluster(
    per_user_counts: list[np.ndarray],
    alpha_bits: float,
    coder: str,
    k_max: int,
    seed: int,
    engine: str,
    chunk_size: int,
) -> SharedComponent:
    """Stack every user's USED model rows, cluster the union, and build one
    codebook per cluster from the pooled member counts."""
    alphabet = per_user_counts[0].shape[1]
    used_rows = [c[c.sum(-1) > 0] for c in per_user_counts]
    stacked = (
        np.concatenate([r for r in used_rows if len(r)])
        if any(len(r) for r in used_rows)
        else np.zeros((0, alphabet))
    )
    comp = SharedComponent(coder, alphabet)
    if not len(stacked):
        return comp
    _, comp.codebook_lengths, comp.freqs = cluster_codebooks(
        stacked, alpha_bits, coder, k_max, seed, engine, chunk_size
    )
    return comp


def build_shared_codebook(
    forests: Sequence[Forest],
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
    generation: int = 1,
) -> SharedCodebook:
    """Pool model counts across a fleet of forests and build the shared
    cluster codebooks (fleet-scale Bregman clustering, objective (6) over
    the union of all users' models).  ``generation`` stamps the codebook's
    lifecycle version (a ``full``-mode recluster passes the successor
    generation; fresh builds are v1)."""
    meta = _validate_fleet_schema(forests)
    d = meta.n_features
    recs = [extract_records(f) for f in forests]
    t_max = max(
        (int(r.depth.max()) + 1 if len(r.depth) else 1) for r in recs
    )
    n_train = max(f.meta.n_train_obs for f in forests)

    # ---- fits alphabet: classes, or the fleet-union value table ----------
    if meta.task == "classification":
        fleet_values = np.zeros(0, np.float64)
        n_fit_syms = meta.n_classes
        fit_syms = [r.fit.astype(np.int64) for r in recs]
        fits_coder = "arithmetic" if meta.n_classes == 2 else "huffman"
    else:
        fleet_values = np.unique(
            np.concatenate(
                [np.asarray(f.fit_values, np.float64) for f in forests]
            )
        )
        n_fit_syms = len(fleet_values)
        fit_syms = []
        for f, r in zip(forests, recs):
            fmap = np.searchsorted(fleet_values, f.fit_values)
            fit_syms.append(fmap[r.fit.astype(np.int64)])
        fits_coder = "huffman"

    vars_comp = _pool_and_cluster(
        [var_name_counts(r, d, t_max) for r in recs],
        alpha_vars(d), "huffman", k_max, seed, engine, chunk_size,
    )

    splits_comp: dict[int, SharedComponent] = {}
    per_var: dict[int, list[np.ndarray]] = {}
    for r in recs:
        for v, cnts in split_counts(r, d, t_max, meta.n_bins_per_feature).items():
            per_var.setdefault(v, []).append(cnts)
    for v, counts_list in sorted(per_var.items()):
        a = alpha_splits(
            not bool(meta.categorical[v]), n_train,
            int(meta.n_bins_per_feature[v]),
        )
        splits_comp[v] = _pool_and_cluster(
            counts_list, a, "huffman", k_max, seed, engine, chunk_size,
        )

    fits_counts_list = []
    for r, syms in zip(recs, fit_syms):
        rf = type(r)(
            tree_id=r.tree_id, depth=r.depth, father_var=r.father_var,
            var=r.var, split=r.split, fit=syms, is_leaf=r.is_leaf,
        )
        fits_counts_list.append(fit_counts(rf, d, t_max, n_fit_syms))
    fits_comp = _pool_and_cluster(
        fits_counts_list, alpha_fits(meta.task, n_fit_syms), fits_coder,
        k_max, seed, engine, chunk_size,
    )

    return SharedCodebook(
        n_features=d,
        task=meta.task,
        n_classes=meta.n_classes,
        t_max=t_max,
        n_train_obs=n_train,
        n_bins_per_feature=np.asarray(meta.n_bins_per_feature, np.int32),
        categorical=np.asarray(meta.categorical, bool),
        vars_comp=vars_comp,
        splits_comp=splits_comp,
        fits_comp=fits_comp,
        fleet_fit_values=fleet_values,
        generation=generation,
    )

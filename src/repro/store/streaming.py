"""Streaming (wave-bounded) store construction (ISSUE 10 tentpole).

``build_store`` holds the whole fleet in memory: every forest, every
delta, plus the clustering working set.  At 10^5 users that is exactly
the high-water mark the residency budget exists to avoid — so
construction must be bounded too.  ``build_store_streaming`` folds users
into the fleet codebook in WAVES:

* wave 0 builds the initial shared codebook from its own forests
  (fleet-scale Bregman clustering, the same chunked assignment engine
  ``core.bregman`` uses for minibatch construction) and commits the
  codebook + the wave's RFD1 delta shards to a ``DurableStore``;
* every later wave encodes against the CURRENT codebook; if any of its
  models are uncodable (fallback), the codebook is EXTENDED for exactly
  those models — generation-g clusters verbatim, appended clusters
  Bregman-fit to the wave's uncodable rows, regression value table
  growing append-only (``lifecycle.extend_codebook_from_forests``, the
  same append-only contract as ``recluster(mode="extend")``) — and the
  fallback users re-encode clean against the new generation;
* each wave lands as ONE durable commit (an atomic epoch bump): a crash
  mid-wave recovers to the previous wave's epoch, never a torn fleet.

Memory never holds more than one wave of forests + deltas + the current
codebook.  Users committed in earlier waves stay on the generation they
were encoded for — mixed-generation serving handles that natively, and
``lifecycle.migrate_users`` consolidates lazily once the fleet is live.
"""
from __future__ import annotations

import itertools
from typing import Callable, Iterable

from .codebook import build_shared_codebook
from .delta import UserDelta, encode_user_delta
from .durable import DurableStore
from .lifecycle import extend_codebook_from_forests


def _uses_fallback(delta: UserDelta) -> bool:
    """True when a delta ships user-local clusters or extra fit values —
    the models the current codebook cannot code cleanly."""
    comps = [delta.vars_dc, *delta.splits_dc.values(), delta.fits_dc]
    return (
        any(dc.n_local for dc in comps)
        or delta.extra_fit_values.size > 0
    )


def build_store_streaming(
    forests: "Iterable[tuple[str, object]] | dict",
    path: str,
    wave_users: int = 256,
    k_max: int = 16,
    seed: int = 0,
    engine: str = "chunked",
    chunk_size: int = 65536,
    slab_shards: int = 8,
    extend: bool = True,
    on_wave: Callable[[dict], None] | None = None,
    on_step: Callable[[str], None] | None = None,
) -> DurableStore:
    """Build a durable fleet from an ITERABLE of ``(user_id, forest)``
    pairs in waves of ``wave_users``, never holding more than one wave
    in memory (see module docstring).  ``extend=False`` pins the wave-0
    codebook (fallback users then keep their user-local clusters, as a
    frozen codebook would force).  ``on_wave`` receives one summary dict
    per committed wave.  Returns the ``DurableStore``; serve it with
    ``load_store()`` (+ ``residency.attach_residency`` for a bounded
    host tier)."""
    if wave_users < 1:
        raise ValueError(f"wave_users must be positive, got {wave_users}")
    items = forests.items() if isinstance(forests, dict) else forests
    it = iter(items)
    durable: DurableStore | None = None
    codebook = None
    wave_idx = 0
    while True:
        wave = list(itertools.islice(it, wave_users))
        if not wave:
            break
        if codebook is None:
            codebook = build_shared_codebook(
                [f for _, f in wave], k_max=k_max, seed=seed,
                engine=engine, chunk_size=chunk_size,
            )
            durable = DurableStore.create(path, slab_shards=slab_shards)
            durable.put_codebook(codebook)
        deltas = [
            (u, encode_user_delta(f, codebook, seed=seed)) for u, f in wave
        ]
        extended = False
        if extend:
            fb = [i for i, (_, d) in enumerate(deltas)
                  if _uses_fallback(d)]
            if fb:
                codebook, _ = extend_codebook_from_forests(
                    codebook, [wave[i][1] for i in fb],
                    k_max=k_max, seed=seed,
                    engine=engine, chunk_size=chunk_size,
                )
                durable.put_codebook(codebook)
                extended = True
                for i in fb:
                    u, f = wave[i]
                    deltas[i] = (
                        u, encode_user_delta(f, codebook, seed=seed)
                    )
        for u, d in deltas:
            durable.put_delta(u, d)
        # one atomic epoch per wave; on_step feeds the chaos harness
        epoch = durable.commit(on_step=on_step)
        if on_wave is not None:
            on_wave({
                "wave": wave_idx,
                "users": len(wave),
                "generation": codebook.generation,
                "extended": extended,
                "epoch": epoch,
            })
        wave_idx += 1
    if durable is None:
        raise ValueError(
            "streaming build needs at least one (user_id, forest) pair"
        )
    return durable

"""Durable self-healing shard store (ISSUE 8 tentpole).

The on-disk tier of the fleet: per-user RFD1 delta shards and per-
generation codebook shards packed into immutable **slab files**, indexed
by a versioned **RFN1 manifest** (per-shard offset, length, CRC32,
codebook generation, user id), with one **XOR parity shard** per slab so
any single corrupt-or-missing shard reconstructs bit-exact.  Normative
byte spec: ``docs/format.md`` §10; design: ``docs/architecture.md``.

Durability model
----------------
* **Every file write is atomic + durable** (``core.framing.
  atomic_write_bytes``: temp file + fsync + rename + directory fsync).
  Slab, parity, and manifest files are written WHOLE and never appended
  or patched in place — the only mutation the format knows is "replace a
  complete file".
* **Commits are manifest swaps.** A commit writes the new slabs and
  their parity files first, then a successor manifest with a strictly
  larger epoch, then garbage-collects.  A crash at ANY step leaves
  either the old manifest (pre-state) or the new one (post-state) as the
  highest readable epoch; ``DurableStore.open`` picks the highest
  manifest that passes its CRC trailer and deletes newer torn ones plus
  any orphaned slab files — rollback is deletion, never parsing of
  partial state.
* **Single faults repair, double faults raise.**  Parity is the XOR of a
  slab's shard payloads zero-padded to the longest.  One bad shard in a
  group reconstructs bit-exact (verified against the manifest CRC32 and
  healed on disk); a second fault in the same group — including a lost
  parity file when a data shard is also bad — raises a typed
  ``UnrepairableError``.  Detected-but-unrepairable NEVER degrades into
  a silent wrong forest.

Residency (first rung of the disk -> host RAM -> HBM ladder):
``load_store`` materializes a ``ForestStore`` whose per-user deltas stay
ON DISK until first touched — a ``_LazyShard`` placeholder carries the
manifest's generation stamp (so ``referenced_generations`` stays cheap)
and loads + self-replaces on first real access.

Background repair: ``Scrubber`` walks shard and parity CRCs with a
bounded per-tick budget and repairs what it finds; ``sched.
LifecycleDriver`` schedules ticks in low-load gaps.  Serving repair:
``attach_auto_repair`` gives ``ForestServer.serve_safe`` a quarantine ->
parity-repair -> verify -> release path.

Single-writer: one process owns a store directory at a time (matching
the journal's model); readers of a crashed writer recover via ``open``.
"""
from __future__ import annotations

import dataclasses
import io
import os
import re
import struct
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import numpy as np

from ..core.framing import (
    FramingError,
    IntegrityError,
    UnrepairableError,
    atomic_write_bytes,
    check_crc,
    expect_magic,
    fsync_dir,
    read_bytes,
    read_struct,
    read_u16,
    read_u32,
    with_crc,
    write_bytes,
    write_u16,
    write_u32,
)
from ..runtime.guards import guarded_by
from .codebook import SharedCodebook
from .delta import UserDelta
from .runtime import ForestStore

MANIFEST_MAGIC = b"RFN1"

#: shard kinds (u8 on the wire)
KIND_CODEBOOK = 0
KIND_DELTA = 1

_KIND_NAMES = {KIND_CODEBOOK: "codebook", KIND_DELTA: "delta"}

#: every file name this module may create or delete — GC touches nothing
#: else in the directory (a recluster journal can share it safely)
_OWNED_RE = re.compile(
    r"^(manifest-\d{8}\.rfn|slab-\d{8}\.rfb|parity-\d{8}\.rfb)(\.tmp)?$"
)
_MANIFEST_RE = re.compile(r"^manifest-(\d{8})\.rfn$")


def _crc(data: bytes) -> int:
    return zlib.crc32(data) & 0xFFFFFFFF


def _manifest_name(epoch: int) -> str:
    return f"manifest-{epoch:08d}.rfn"


def _slab_name(slab_id: int) -> str:
    return f"slab-{slab_id:08d}.rfb"


def _parity_name(slab_id: int) -> str:
    return f"parity-{slab_id:08d}.rfb"


def xor_parity(payloads: list[bytes]) -> bytes:
    """XOR of ``payloads`` zero-padded to the longest — the parity shard
    of one slab group.  With every sibling and the parity intact, any
    single payload is recoverable as ``parity XOR (all siblings)``."""
    if not payloads:
        return b""
    length = max(len(p) for p in payloads)
    acc = np.zeros(length, dtype=np.uint8)
    for p in payloads:
        a = np.frombuffer(p, dtype=np.uint8)
        acc[: len(a)] ^= a
    return acc.tobytes()


# ---------------------------------------------------------------------------
# RFN1 manifest
# ---------------------------------------------------------------------------

@dataclass
class ShardEntry:
    """One shard's index record: where its bytes live inside its slab and
    what they must hash to.  ``name`` is the user id for delta shards and
    ``""`` for codebook shards (identified by ``generation``).  Dead
    entries (``live=False``, superseded or tombstoned) keep their bytes
    in the slab until compaction — parity covers dead shards too, so a
    live sibling stays repairable."""

    shard_id: int
    kind: int
    name: str
    generation: int
    offset: int
    length: int
    crc: int
    live: bool = True

    @property
    def key(self) -> tuple:
        """Logical identity: one live shard per key per manifest."""
        if self.kind == KIND_DELTA:
            return (KIND_DELTA, self.name)
        return (KIND_CODEBOOK, self.generation)

    def describe(self) -> str:
        what = _KIND_NAMES.get(self.kind, f"kind{self.kind}")
        who = self.name if self.kind == KIND_DELTA else f"gen{self.generation}"
        return f"shard {self.shard_id} ({what} {who})"


@dataclass
class SlabEntry:
    """One slab file = the concatenation of its shards' payloads in
    offset order, plus a sibling parity file of ``parity_len`` bytes
    (the longest shard's length) whose CRC32 is pinned here."""

    slab_id: int
    parity_len: int
    parity_crc: int
    shards: list = field(default_factory=list)


@dataclass
class Manifest:
    """The RFN1 frame: the complete, CRC-sealed index of one fleet state.

    ``epoch`` is strictly monotonic across commits; recovery picks the
    highest epoch whose frame passes its CRC trailer.  ``slab_shards`` is
    the parity-group width k (shards per slab at write time);
    ``next_shard_id`` / ``next_slab_id`` are the allocators, persisted so
    ids never recycle within a manifest lineage."""

    epoch: int
    slab_shards: int
    next_shard_id: int
    next_slab_id: int
    slabs: list = field(default_factory=list)

    def entries(self) -> Iterator[tuple["SlabEntry", "ShardEntry"]]:
        """Yield ``(slab, shard_entry)`` over every shard, dead or live."""
        for slab in self.slabs:
            for e in slab.shards:
                yield slab, e

    def live_entries(self) -> Iterator[tuple["SlabEntry", "ShardEntry"]]:
        for slab, e in self.entries():
            if e.live:
                yield slab, e

    def live_bytes(self) -> int:
        return sum(e.length for _, e in self.live_entries())

    def dead_bytes(self) -> int:
        return sum(e.length for _, e in self.entries() if not e.live)

    def to_bytes(self) -> bytes:
        out = io.BytesIO()
        out.write(MANIFEST_MAGIC)
        write_u32(out, self.epoch)
        write_u16(out, self.slab_shards)
        write_u32(out, self.next_shard_id)
        write_u32(out, self.next_slab_id)
        write_u32(out, len(self.slabs))
        for slab in self.slabs:
            write_u32(out, slab.slab_id)
            write_u32(out, slab.parity_len)
            write_u32(out, slab.parity_crc)
            write_u16(out, len(slab.shards))
            for e in slab.shards:
                write_u32(out, e.shard_id)
                # packed "<BB" to mirror the reader's read_struct exactly
                # (byte-identical to the old bytes([...]) idiom)
                out.write(struct.pack("<BB", e.kind, 1 if e.live else 0))
                write_u16(out, e.generation)
                write_bytes(out, e.name.encode("utf-8"))
                write_u32(out, e.offset)
                write_u32(out, e.length)
                write_u32(out, e.crc)
        return with_crc(out.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "Manifest":
        payload = check_crc(data, "RFN1 manifest")
        if payload == data:
            # manifests are born with trailers — a missing one means the
            # file lost its tail, not a legacy frame
            raise IntegrityError("RFN1 manifest: missing CRC trailer")
        inp = io.BytesIO(payload)
        expect_magic(inp, MANIFEST_MAGIC, "RFN1 manifest")
        epoch = read_u32(inp)
        slab_shards = read_u16(inp)
        next_shard_id = read_u32(inp)
        next_slab_id = read_u32(inp)
        n_slabs = read_u32(inp)
        slabs = []
        for _ in range(n_slabs):
            slab_id = read_u32(inp)
            parity_len = read_u32(inp)
            parity_crc = read_u32(inp)
            n_shards = read_u16(inp)
            shards = []
            for _ in range(n_shards):
                shard_id = read_u32(inp)
                kind, live = read_struct(inp, "<BB", "RFN1 shard flags")
                if kind not in _KIND_NAMES:
                    raise IntegrityError(f"RFN1 manifest: bad shard kind {kind}")
                generation = read_u16(inp)
                name = read_bytes(inp).decode("utf-8")
                offset = read_u32(inp)
                length = read_u32(inp)
                crc = read_u32(inp)
                shards.append(ShardEntry(
                    shard_id, kind, name, generation,
                    offset, length, crc, bool(live),
                ))
            slabs.append(SlabEntry(slab_id, parity_len, parity_crc, shards))
        return cls(epoch, slab_shards, next_shard_id, next_slab_id, slabs)


# ---------------------------------------------------------------------------
# lazy residency
# ---------------------------------------------------------------------------

class _LazyShard:
    """Disk-resident stand-in for a ``UserDelta``: carries the manifest's
    generation stamp (so ``ForestStore.referenced_generations`` — which
    scans raw dict values — never touches disk) and loads + self-replaces
    in the owning map on first real attribute access.  ``to_bytes`` short-
    circuits to the raw shard bytes, so ``size_report`` / ``sync`` on a
    cold store stream bytes without decoding anything."""

    __slots__ = ("_durable", "_map", "_user", "_shard_id",
                 "codebook_generation", "_real")

    def __init__(self, durable: "DurableStore", owner_map: dict,
                 user_id: str, shard_id: int, generation: int) -> None:
        self._durable = durable
        self._map = owner_map
        self._user = user_id
        self._shard_id = shard_id
        self.codebook_generation = generation
        self._real = None

    def _load(self) -> UserDelta:
        if self._real is None:
            res = self._durable.residency
            t0 = res.clock_now() if res is not None else 0.0
            data = self._durable.read_shard(self._shard_id)
            real = UserDelta.from_bytes(data)
            self._real = real
            dict.__setitem__(self._map, self._user, real)
            if res is not None:
                # serve-path cold load: account the resident bytes and
                # let the budget react (residency.ResidencyManager)
                res.notify_loaded(self._user, len(data),
                                  res.clock_now() - t0)
        return self._real

    def to_bytes(self) -> bytes:
        if self._real is not None:
            return self._real.to_bytes()
        return self._durable.read_shard(self._shard_id)

    def __getattr__(self, name: str) -> Any:
        # only fires for names not in __slots__: proxy through the loaded
        # delta (corrupt shards raise typed IntegrityError right here —
        # exactly where serve_safe's probe expects decode faults)
        return getattr(self._load(), name)


class _LazyDeltaMap(dict):
    """The ``ForestStore._deltas`` dict of a lazily-loaded store.

    ``__getitem__`` MATERIALIZES: every path that takes a delta out of
    the registry (``store.delta``, migration's ``dataclasses.replace``,
    decode paths) gets a real ``UserDelta``.  Raw-value scans
    (``values()`` / ``items()``) still see placeholders — by design, so
    generation scans and byte-level sync stay out-of-core."""

    def __init__(self, durable: "DurableStore") -> None:
        super().__init__()
        self._durable = durable

    def __getitem__(self, key: str) -> UserDelta:
        v = super().__getitem__(key)
        if isinstance(v, _LazyShard):
            v = v._load()
        return v

    def n_loaded(self) -> int:
        return sum(1 for v in super().values()
                   if not isinstance(v, _LazyShard))


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class DurableStore:
    """Atomic, parity-protected on-disk fleet (see module docstring).

    Mutation protocol: stage (``put_codebook`` / ``put_delta`` /
    ``remove_user`` / ``sync``) then ``commit`` — staged state lives in
    memory only until the commit's manifest swap lands, so a crash
    mid-commit loses nothing but the staging (retryable) and can never
    tear the on-disk fleet.  ``read_shard(repair=False)`` raises a typed
    ``IntegrityError`` on any mismatch (feeding quarantine);
    ``repair=True`` additionally attempts parity reconstruction.

    ``read_fault`` / ``write_fault`` are chaos hooks
    (``runtime.chaos.DiskFaults``): the first maps ``(shard_id, bytes) ->
    bytes`` on every shard read, the second sees ``(path, nbytes)``
    before every file write and may raise ``OSError`` (ENOSPC).
    """

    def __init__(self, path: str, manifest: Manifest,
                 recovery: Manifest | None = None,
                 read_fault: Callable | None = None,
                 write_fault: Callable | None = None) -> None:
        self.path = str(path)
        self.manifest = manifest
        # previous manifest: its files survive GC until the NEXT commit,
        # so recovery always has a complete fallback epoch on disk
        self._recovery = recovery
        self.read_fault = read_fault
        self.write_fault = write_fault
        self._pending: dict[tuple, tuple] = {}   # key -> (kind, name, gen, bytes)
        self._tombstones: set[tuple] = set()
        self._index = None                        # shard_id -> (slab, entry)
        self.n_commits = 0
        self.n_repairs = 0
        self.n_parity_rebuilds = 0
        # residency budget manager (store.residency.attach_residency):
        # the _LazyShard load path reports cold loads through it
        self.residency = None

    # ---------------- lifecycle -------------------------------------------

    @classmethod
    def create(cls, path: str, store: ForestStore | None = None,
               slab_shards: int = 8,
               read_fault: Callable | None = None,
               write_fault: Callable | None = None) -> "DurableStore":
        """Initialize a fresh store directory (epoch 0 = empty manifest,
        written first so a kill at any later create step recovers to a
        valid empty store), optionally seeding it from an in-memory
        ``ForestStore`` (one commit -> epoch 1)."""
        if slab_shards < 1:
            raise ValueError("slab_shards must be >= 1")
        os.makedirs(path, exist_ok=True)
        if any(_MANIFEST_RE.match(f) for f in os.listdir(path)):
            raise ValueError(
                f"{path!r} already holds a durable store — use open()"
            )
        manifest = Manifest(epoch=0, slab_shards=slab_shards,
                            next_shard_id=1, next_slab_id=1, slabs=[])
        d = cls(path, manifest, None, read_fault, write_fault)
        d._write_file(_manifest_name(0), manifest.to_bytes())
        if store is not None:
            d.sync(store)
        return d

    @classmethod
    def open(cls, path: str,
             read_fault: Callable | None = None,
             write_fault: Callable | None = None) -> "DurableStore":
        """Recover the store: highest-epoch manifest passing its CRC wins;
        torn or corrupt newer manifests and orphaned slab files from an
        interrupted commit are rolled back (deleted).  Raises a typed
        ``IntegrityError`` when no manifest is readable."""
        try:
            names = os.listdir(path)
        except OSError as exc:
            raise IntegrityError(f"cannot open durable store: {exc}") from exc
        candidates = sorted(
            (int(m.group(1)), f)
            for f in names if (m := _MANIFEST_RE.match(f))
        )
        chosen = None
        older: list[tuple[int, str]] = []
        errors: list[str] = []
        for epoch, fname in reversed(candidates):
            if chosen is not None:
                older.append((epoch, fname))
                continue
            try:
                with open(os.path.join(path, fname), "rb") as f:
                    chosen = Manifest.from_bytes(f.read())
            except (OSError, FramingError) as exc:
                errors.append(f"{fname}: {type(exc).__name__}: {exc}")
        if chosen is None:
            detail = f" ({'; '.join(errors)})" if errors else ""
            raise IntegrityError(
                f"no readable RFN1 manifest in {path!r}{detail}"
            )
        recovery = None
        for _, fname in older:          # newest readable older epoch
            try:
                with open(os.path.join(path, fname), "rb") as f:
                    recovery = Manifest.from_bytes(f.read())
                break
            except (OSError, FramingError):
                continue
        d = cls(path, chosen, recovery, read_fault, write_fault)
        d._gc()
        return d

    # ---------------- staging ---------------------------------------------

    def put_codebook(self, codebook: SharedCodebook) -> None:
        """Stage one codebook generation for the next commit."""
        self._stage(KIND_CODEBOOK, "", codebook.generation,
                    codebook.to_bytes())

    def put_delta(self, user_id: str, delta: Any) -> None:
        """Stage one user's delta (accepts a ``UserDelta`` or a lazy
        placeholder — anything with ``to_bytes`` + ``codebook_generation``)."""
        self._stage(KIND_DELTA, user_id, delta.codebook_generation,
                    delta.to_bytes())

    def put_delta_bytes(self, user_id: str, data: bytes,
                        generation: int) -> None:
        """Stage pre-serialized RFD1 bytes (the out-of-core path: no
        decode needed to move a user between stores)."""
        self._stage(KIND_DELTA, user_id, generation, bytes(data))

    def remove_user(self, user_id: str) -> None:
        """Stage a tombstone: the user's shard goes dead at next commit
        (bytes reclaimed at compaction)."""
        key = (KIND_DELTA, user_id)
        self._pending.pop(key, None)
        self._tombstones.add(key)

    def remove_codebook(self, generation: int) -> None:
        """Stage a codebook tombstone (only for generations no delta
        references — mirrors ``drop_unreferenced_codebooks``)."""
        key = (KIND_CODEBOOK, generation)
        self._pending.pop(key, None)
        self._tombstones.add(key)

    def _stage(self, kind: int, name: str, generation: int,
               data: bytes) -> None:
        key = (kind, name) if kind == KIND_DELTA else (kind, generation)
        self._tombstones.discard(key)
        self._pending[key] = (kind, name, generation, data)

    def sync(self, store: ForestStore, on_step: Callable | None = None) -> dict:
        """Make the on-disk fleet mirror ``store``: stage every codebook
        and delta whose bytes differ from the live shard (byte-level
        compare via length+CRC — lazy placeholders stream without
        decoding), tombstone what the store no longer holds, and commit
        if anything changed.  Returns staging counts + the new epoch."""
        report = {"codebooks": 0, "deltas": 0, "removed": 0,
                  "unchanged": 0, "epoch": self.manifest.epoch}
        live = {e.key: e for _, e in self.manifest.live_entries()}
        want: set[tuple] = set()
        for gen in store.generations:
            data = store.codebook_for(gen).to_bytes()
            key = (KIND_CODEBOOK, gen)
            want.add(key)
            e = live.get(key)
            if e is not None and e.length == len(data) and e.crc == _crc(data):
                report["unchanged"] += 1
            else:
                self._stage(KIND_CODEBOOK, "", gen, data)
                report["codebooks"] += 1
        for user_id, d in store._deltas.items():
            data = d.to_bytes()
            gen = d.codebook_generation
            key = (KIND_DELTA, user_id)
            want.add(key)
            e = live.get(key)
            if (e is not None and e.length == len(data)
                    and e.crc == _crc(data) and e.generation == gen):
                report["unchanged"] += 1
            else:
                self._stage(KIND_DELTA, user_id, gen, data)
                report["deltas"] += 1
        for key in live:
            if key not in want and key not in self._pending:
                self._tombstones.add(key)
                report["removed"] += 1
        if self._pending or self._tombstones:
            report["epoch"] = self.commit(on_step=on_step)
        return report

    # ---------------- commit / compact ------------------------------------

    def commit(self, on_step: Callable | None = None) -> int:
        """Apply staged puts/tombstones as one atomic epoch bump.

        Write order (each step name fed to ``on_step`` BEFORE its write,
        for crash-schedule injection): ``slab:<id>`` and ``parity:<id>``
        per new slab, then ``manifest``, then ``gc``.  Until the manifest
        lands, disk state is the old epoch plus unreferenced new files —
        ``open`` rolls those back.  After it lands, the commit is final;
        GC is pure cleanup."""
        pending = [self._pending[k] for k in sorted(self._pending,
                                                    key=lambda k: (k[0], str(k[1])))]
        return self._commit(pending, set(self._tombstones), on_step,
                            replace=False)

    def compact(self, on_step: Callable | None = None) -> dict:
        """Rewrite every LIVE shard into fresh dense slabs (reclaiming
        dead bytes), repairing any single-fault shard it reads along the
        way.  Same crash-safety as ``commit``: one manifest swap, old
        slabs garbage-collected after.  Staged-but-uncommitted changes
        are committed first."""
        if self._pending or self._tombstones:
            self.commit()
        before = self.stats()
        live = []
        for _, e in self.manifest.live_entries():
            live.append((e.kind, e.name, e.generation,
                         self.read_shard(e.shard_id, repair=True)))
        live.sort(key=lambda t: (t[0], t[2] if t[0] == KIND_CODEBOOK else 0,
                                 t[1]))
        epoch = self._commit(live, set(), on_step, replace=True)
        after = self.stats()
        return {
            "epoch": epoch,
            "slabs_before": before["n_slabs"],
            "slabs_after": after["n_slabs"],
            "bytes_before": before["live_bytes"] + before["dead_bytes"],
            "bytes_after": after["live_bytes"] + after["dead_bytes"],
            "dead_bytes_reclaimed": before["dead_bytes"],
            "live_shards": after["live_shards"],
        }

    def _commit(self, pending: list, tombstones: set,
                on_step: Callable | None, replace: bool) -> int:
        step = on_step if on_step is not None else (lambda name: None)
        man = self.manifest
        dead_keys = set(tombstones)
        for kind, name, gen, _ in pending:
            dead_keys.add((kind, name) if kind == KIND_DELTA
                          else (kind, gen))
        next_sid = man.next_shard_id
        next_slab = man.next_slab_id
        k = man.slab_shards
        new_slabs = []
        for i in range(0, len(pending), k):
            chunk = pending[i:i + k]
            entries, payloads, off = [], [], 0
            for kind, name, gen, data in chunk:
                entries.append(ShardEntry(next_sid, kind, name, gen,
                                          off, len(data), _crc(data), True))
                next_sid += 1
                payloads.append(data)
                off += len(data)
            parity = xor_parity(payloads)
            slab_id = next_slab
            next_slab += 1
            step(f"slab:{slab_id}")
            self._write_file(_slab_name(slab_id), b"".join(payloads))
            step(f"parity:{slab_id}")
            self._write_file(_parity_name(slab_id), parity)
            new_slabs.append(SlabEntry(slab_id, len(parity), _crc(parity),
                                       entries))
        if replace:
            old_slabs = []
        else:
            old_slabs = [
                SlabEntry(s.slab_id, s.parity_len, s.parity_crc, [
                    dataclasses.replace(
                        e, live=e.live and e.key not in dead_keys)
                    for e in s.shards
                ])
                for s in man.slabs
            ]
        new_man = Manifest(man.epoch + 1, man.slab_shards,
                           next_sid, next_slab, old_slabs + new_slabs)
        step("manifest")
        self._write_file(_manifest_name(new_man.epoch), new_man.to_bytes())
        # the swap: everything before this line was invisible to recovery
        self._recovery = man
        self.manifest = new_man
        self._pending = {}
        self._tombstones = set()
        self._index = None
        self.n_commits += 1
        step("gc")
        self._gc()
        return new_man.epoch

    def _write_file(self, name: str, data: bytes) -> None:
        path = os.path.join(self.path, name)
        if self.write_fault is not None:
            self.write_fault(path, len(data))
        atomic_write_bytes(path, data)

    def _gc(self) -> list[str]:
        """Delete every file this module owns that neither the current
        nor the recovery manifest references.  Unknown files (journals,
        anything not matching our name patterns) are never touched."""
        keep = set()
        for man in (self.manifest, self._recovery):
            if man is None:
                continue
            keep.add(_manifest_name(man.epoch))
            for slab in man.slabs:
                keep.add(_slab_name(slab.slab_id))
                keep.add(_parity_name(slab.slab_id))
        removed = []
        try:
            names = os.listdir(self.path)
        except OSError:
            return removed
        for fname in sorted(names):
            if not _OWNED_RE.match(fname):
                continue
            if fname.endswith(".tmp") or fname not in keep:
                try:
                    os.remove(os.path.join(self.path, fname))
                    removed.append(fname)
                except OSError:
                    pass
        if removed:
            fsync_dir(self.path)
        return removed

    # ---------------- reads, repair ---------------------------------------

    def _build_index(self) -> None:
        by_id, by_user, by_slab = {}, {}, {}
        for slab in self.manifest.slabs:
            by_slab[slab.slab_id] = slab
            for e in slab.shards:
                by_id[e.shard_id] = (slab, e)
                if e.live and e.kind == KIND_DELTA:
                    by_user[e.name] = e
        self._index = (by_id, by_user, by_slab)

    def _locate(self, shard_id: int) -> tuple["SlabEntry", "ShardEntry"]:
        if self._index is None:
            self._build_index()
        try:
            return self._index[0][shard_id]
        except KeyError:
            raise KeyError(f"unknown shard id {shard_id}") from None

    def _slab(self, slab_id: int) -> SlabEntry:
        if self._index is None:
            self._build_index()
        try:
            return self._index[2][slab_id]
        except KeyError:
            raise KeyError(f"unknown slab id {slab_id}") from None

    def shard_for_user(self, user_id: str) -> "ShardEntry | None":
        """The live delta ``ShardEntry`` for ``user_id``, or ``None``."""
        if self._index is None:
            self._build_index()
        return self._index[1].get(user_id)

    def codebook_entries(self) -> list:
        """Live codebook shard entries, ascending generation."""
        return sorted((e for _, e in self.manifest.live_entries()
                       if e.kind == KIND_CODEBOOK),
                      key=lambda e: e.generation)

    def delta_entries(self) -> list:
        """Live delta shard entries, sorted by user id."""
        return sorted((e for _, e in self.manifest.live_entries()
                       if e.kind == KIND_DELTA),
                      key=lambda e: e.name)

    def shard_location(self, shard_id: int) -> tuple[str, int, int]:
        """``(slab_path, offset, length)`` of one shard's bytes — how
        tests and benches aim ``DiskFaults`` at a specific shard."""
        slab, e = self._locate(shard_id)
        return (os.path.join(self.path, _slab_name(slab.slab_id)),
                e.offset, e.length)

    def parity_location(self, slab_id: int) -> str:
        return os.path.join(self.path, _parity_name(slab_id))

    def read_shard(self, shard_id: int, repair: bool = False) -> bytes:
        """Read + CRC-verify one shard's bytes.  On any fault (missing or
        truncated slab file, CRC mismatch): raise a typed
        ``IntegrityError`` when ``repair=False`` — the serving layer's
        quarantine signal — or attempt parity reconstruction when
        ``repair=True`` (which raises ``UnrepairableError`` on a double
        fault and heals the slab file on success)."""
        slab, e = self._locate(shard_id)
        try:
            return self._read_verified(slab, e)
        except IntegrityError:
            if not repair:
                raise
        return self.repair_shard(shard_id)

    def _read_verified(self, slab: SlabEntry, e: ShardEntry) -> bytes:
        path = os.path.join(self.path, _slab_name(slab.slab_id))
        try:
            with open(path, "rb") as f:
                f.seek(e.offset)
                data = f.read(e.length)
        except OSError as exc:
            raise IntegrityError(
                f"{e.describe()}: slab file unreadable: {exc}"
            ) from exc
        if len(data) != e.length:
            raise IntegrityError(
                f"{e.describe()}: slab truncated (wanted {e.length} bytes "
                f"at offset {e.offset}, got {len(data)})"
            )
        if self.read_fault is not None:
            data = self.read_fault(e.shard_id, data)
        if _crc(data) != e.crc:
            raise IntegrityError(f"{e.describe()}: CRC mismatch")
        return data

    def _read_parity(self, slab: SlabEntry) -> bytes:
        path = os.path.join(self.path, _parity_name(slab.slab_id))
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError as exc:
            raise IntegrityError(
                f"parity {slab.slab_id}: unreadable: {exc}"
            ) from exc
        if len(data) != slab.parity_len:
            raise IntegrityError(
                f"parity {slab.slab_id}: wrong length ({len(data)} != "
                f"{slab.parity_len})"
            )
        if _crc(data) != slab.parity_crc:
            raise IntegrityError(f"parity {slab.slab_id}: CRC mismatch")
        return data

    def repair_shard(self, shard_id: int) -> bytes:
        """Reconstruct one shard from its slab siblings + parity, verify
        bit-exactness against the manifest CRC32, and heal the slab file
        on disk.  Raises ``UnrepairableError`` when any sibling or the
        parity shard is ALSO damaged (double fault) — detected corruption
        never silently degrades."""
        slab, victim = self._locate(shard_id)
        faults: list[str] = []
        siblings: dict[int, bytes] = {}
        for e in slab.shards:
            if e.shard_id == victim.shard_id:
                continue
            try:
                siblings[e.shard_id] = self._read_verified(slab, e)
            except IntegrityError as exc:
                faults.append(str(exc))
        parity = None
        try:
            parity = self._read_parity(slab)
        except IntegrityError as exc:
            faults.append(str(exc))
        if faults:
            raise UnrepairableError(
                f"slab {slab.slab_id}: cannot reconstruct "
                f"{victim.describe()} — XOR repair needs every sibling and "
                f"the parity shard intact, but: {'; '.join(faults)}"
            )
        acc = np.frombuffer(parity, dtype=np.uint8).copy()
        for data in siblings.values():
            a = np.frombuffer(data, dtype=np.uint8)
            acc[: len(a)] ^= a
        recon = acc[: victim.length].tobytes()
        if _crc(recon) != victim.crc:
            raise UnrepairableError(
                f"slab {slab.slab_id}: reconstruction of "
                f"{victim.describe()} fails its manifest CRC — more than "
                f"one region of the group is corrupt"
            )
        ordered = sorted(slab.shards, key=lambda e: e.offset)
        blob = b"".join(
            recon if e.shard_id == victim.shard_id else siblings[e.shard_id]
            for e in ordered
        )
        self._write_file(_slab_name(slab.slab_id), blob)
        self.n_repairs += 1
        return recon

    def rebuild_parity(self, slab_id: int) -> bytes:
        """Recompute + rewrite one slab's parity file from its (verified)
        data shards — the repair path for a lost or corrupted parity
        shard.  Raises ``IntegrityError`` if any data shard is itself bad
        (repair that shard first; if BOTH are bad the group is
        unrepairable)."""
        slab = self._slab(slab_id)
        payloads = [self._read_verified(slab, e)
                    for e in sorted(slab.shards, key=lambda e: e.offset)]
        parity = xor_parity(payloads)
        if len(parity) != slab.parity_len or _crc(parity) != slab.parity_crc:
            raise IntegrityError(
                f"parity {slab_id}: recomputed parity disagrees with the "
                f"manifest — slab data is inconsistent"
            )
        self._write_file(_parity_name(slab_id), parity)
        self.n_parity_rebuilds += 1
        return parity

    # ---------------- loading ---------------------------------------------

    def load_store(self, tile_cache_trees: int = 4096,
                   arena_capacity_trees: int = 16384,
                   lazy: bool = True) -> ForestStore:
        """Materialize a ``ForestStore`` from the committed fleet.

        Codebooks load (and self-repair) eagerly — they are shared and
        load-bearing.  With ``lazy=True`` (default) per-user deltas stay
        on disk behind ``_LazyShard`` placeholders: open cost is the
        manifest + codebooks, independent of fleet size, and a corrupt
        delta surfaces as a typed error at FIRST ACCESS, where
        ``serve_safe``'s quarantine + auto-repair path handles it."""
        cb_entries = self.codebook_entries()
        if not cb_entries:
            raise IntegrityError(
                "durable store holds no live codebook shard"
            )
        codebooks = [
            SharedCodebook.from_bytes(self.read_shard(e.shard_id, repair=True))
            for e in cb_entries
        ]
        store = ForestStore(codebooks[-1], tile_cache_trees,
                            arena_capacity_trees)
        for cb in codebooks[:-1]:
            store._retained[cb.generation] = cb
        gens = {cb.generation for cb in codebooks}
        lazy_map = _LazyDeltaMap(self)
        store._deltas = lazy_map
        for e in self.delta_entries():
            if e.generation not in gens:
                raise IntegrityError(
                    f"{e.describe()} references codebook generation "
                    f"{e.generation}, but no such codebook shard is live"
                )
            if lazy:
                dict.__setitem__(
                    lazy_map, e.name,
                    _LazyShard(self, lazy_map, e.name, e.shard_id,
                               e.generation),
                )
            else:
                store.add_delta(
                    e.name,
                    UserDelta.from_bytes(self.read_shard(e.shard_id,
                                                         repair=True)),
                )
        return store

    # ---------------- introspection ---------------------------------------

    def stats(self) -> dict:
        man = self.manifest
        live = list(man.live_entries())
        return {
            "path": self.path,
            "epoch": man.epoch,
            "slab_shards": man.slab_shards,
            "n_slabs": len(man.slabs),
            "live_shards": len(live),
            "dead_shards": sum(1 for _, e in man.entries() if not e.live),
            "live_bytes": man.live_bytes(),
            "dead_bytes": man.dead_bytes(),
            "n_users": sum(1 for _, e in live if e.kind == KIND_DELTA),
            "n_codebooks": sum(1 for _, e in live if e.kind == KIND_CODEBOOK),
            "pending": len(self._pending),
            "tombstones": len(self._tombstones),
            "n_commits": self.n_commits,
            "n_repairs": self.n_repairs,
            "n_parity_rebuilds": self.n_parity_rebuilds,
        }


# ---------------------------------------------------------------------------
# background scrubbing
# ---------------------------------------------------------------------------

@guarded_by(
    "_lock",
    "_items", "_cursor", "passes", "shards_scanned", "parities_scanned",
    "repairs", "parity_rebuilds", "bytes_scanned", "unrepairable",
    holds=("_refill", "_scan"),
)
class Scrubber:
    """Incremental CRC scrubber with parity repair.

    Walks every shard AND every parity file of the manifest (dead shards
    included — parity covers them, so a live sibling's repairability
    depends on their bytes too) a bounded number of items per ``tick``;
    on a verification failure it repairs from parity (``repair_shard``) /
    recomputes parity (``rebuild_parity``), and records a typed
    unrepairable fault — never ignores one.  A completed walk starts the
    next pass from the then-current manifest, so compactions mid-pass
    simply retire stale queue items (skipped via their vanished ids).

    ``sched.LifecycleDriver`` calls ``tick`` in low-load gaps; tests and
    benches call ``scrub_all``.  ``tick``/``scrub_all`` run on the pump
    thread while ``stats`` may be read from any thread, so the walk
    state and counters are guarded by ``_lock`` (ISSUE 9)."""

    def __init__(self, durable: DurableStore,
                 shards_per_tick: int = 64) -> None:
        self.durable = durable
        self.shards_per_tick = shards_per_tick
        self._lock = threading.Lock()
        self._items: list = []
        self._cursor = 0
        self.passes = 0
        self.shards_scanned = 0
        self.parities_scanned = 0
        self.repairs = 0
        self.parity_rebuilds = 0
        self.bytes_scanned = 0
        self.unrepairable: list = []

    def _refill(self) -> None:
        items = []
        for slab in self.durable.manifest.slabs:
            for e in slab.shards:
                items.append(("shard", slab.slab_id, e.shard_id))
            items.append(("parity", slab.slab_id, None))
        self._items = items
        self._cursor = 0

    def tick(self, budget: int | None = None) -> dict:
        """Scan up to ``budget`` items (default ``shards_per_tick``);
        returns this tick's counts."""
        budget = self.shards_per_tick if budget is None else budget
        out = {"scanned": 0, "repaired": 0, "parity_rebuilt": 0,
               "unrepairable": 0}
        with self._lock:
            while budget > 0:
                if self._cursor >= len(self._items):
                    self._refill()
                    if self._items:
                        self.passes += 1
                    else:
                        break
                item = self._items[self._cursor]
                self._cursor += 1
                budget -= 1
                self._scan(item, out)
        return out

    def scrub_all(self) -> dict:
        """One complete pass over the current manifest, in one call."""
        out = {"scanned": 0, "repaired": 0, "parity_rebuilt": 0,
               "unrepairable": 0}
        with self._lock:
            self._refill()
            if self._items:
                self.passes += 1
            while self._cursor < len(self._items):
                item = self._items[self._cursor]
                self._cursor += 1
                self._scan(item, out)
        return out

    def _scan(self, item: tuple, out: dict) -> None:
        # caller holds self._lock (declared via guarded_by holds=)
        kind, slab_id, shard_id = item
        try:
            if kind == "shard":
                data = self.durable.read_shard(shard_id)
                self.shards_scanned += 1
                self.bytes_scanned += len(data)
            else:
                slab = self.durable._slab(slab_id)
                parity = self.durable._read_parity(slab)
                self.parities_scanned += 1
                self.bytes_scanned += len(parity)
            out["scanned"] += 1
        except KeyError:
            # shard/slab vanished (compaction mid-pass): stale item
            return
        except IntegrityError:
            out["scanned"] += 1
            try:
                if kind == "shard":
                    self.shards_scanned += 1
                    self.durable.repair_shard(shard_id)
                    self.repairs += 1
                    out["repaired"] += 1
                else:
                    self.parities_scanned += 1
                    self.durable.rebuild_parity(slab_id)
                    self.parity_rebuilds += 1
                    out["parity_rebuilt"] += 1
            except (UnrepairableError, IntegrityError) as exc:
                self.unrepairable.append(
                    (f"{kind}:{shard_id if kind == 'shard' else slab_id}",
                     str(exc))
                )
                out["unrepairable"] += 1

    def stats(self) -> dict:
        with self._lock:
            return {
                "passes": self.passes,
                "queue_position": self._cursor,
                "queue_length": len(self._items),
                "shards_scanned": self.shards_scanned,
                "parities_scanned": self.parities_scanned,
                "repairs": self.repairs,
                "parity_rebuilds": self.parity_rebuilds,
                "bytes_scanned": self.bytes_scanned,
                "unrepairable": list(self.unrepairable),
            }


# ---------------------------------------------------------------------------
# serving integration: quarantine -> parity repair -> verify -> release
# ---------------------------------------------------------------------------

def attach_auto_repair(
    server: Any, durable: DurableStore
) -> Callable[[str], bool]:
    """Wire a ``ForestServer``'s quarantine to the durable store's parity
    repair: when ``serve_safe`` quarantines (or is about to quarantine) a
    user, the repairer re-reads the user's shard with ``repair=True``
    (bit-exact by manifest CRC), re-parses the RFD1 frame, and
    re-registers the delta — bumping the user's version so the existing
    quarantine refresh releases them; the probe then re-verifies the
    decode end-to-end before serving.  An ``UnrepairableError`` (double
    fault) propagates into the server's repair-failure accounting and the
    user STAYS quarantined — never served wrong.  Returns the repairer
    (also installed on the server)."""
    store = server.store

    def repair(user_id: str) -> bool:
        entry = durable.shard_for_user(user_id)
        if entry is None:
            return False
        data = durable.read_shard(entry.shard_id, repair=True)
        delta = UserDelta.from_bytes(data)
        store.add_delta(user_id, delta)
        return True

    server.attach_repairer(repair)
    return repair

"""Tiered residency for the multi-tenant store (ISSUE 10 tentpole).

PR 8 made the fleet durable (``store.durable``): deltas live on disk
behind ``_LazyShard`` placeholders and materialize on first touch.  But
residency only ratcheted UP — once decoded, a user stayed in host memory
forever, so a long-tailed trace eventually materializes the whole fleet.
This module makes host memory a BUDGET, not a high-water mark:

* ``ResidencyManager`` byte-accounts every resident decoded delta and,
  when the configured budget is exceeded, DEMOTES the coldest unpinned
  users back to ``_LazyShard`` placeholders (GreedyDual priority, the
  same aging policy as ``TileCache`` / ``TileArena`` — ``store.policy``),
  dropping the user's hydrated object, decoded tiles, and arena run so
  every cached artifact derived from the resident delta goes with it.
  The user's serving version is NOT bumped: the durable tier holds the
  byte-identical shard, so a later touch reloads bit-exactly and every
  memoized plan stays valid.
* A DIRTY user (re-registered or relabeled since the last durable sync)
  is never demoted over its disk copy silently: with ``writeback=True``
  (default) the manager stages + commits the resident bytes first (the
  commit is the usual atomic epoch bump), otherwise the user is skipped
  and the budget may be exceeded (counted, never hidden).
* ``Prefetcher`` warms demoted users AHEAD of the serve path: the
  scheduler's plan stage names every user batch ``k+1`` needs while
  batch ``k`` executes, so the prefetcher reads + parses their shards
  (background thread under a wall clock; inline under ``VirtualClock``
  for determinism) and STAGES the parsed deltas with the manager.
  Staged deltas are absorbed into the store on the serving thread
  (``ForestServer.execute`` / first touch) — the prefetch thread never
  mutates the tile cache or the device arena, so no serving structure
  is ever raced.

Clocks are INJECTED (``clock=`` is any ``() -> float``): this module
never reads wall time itself, keeping the store determinism-clean
(repro-lint DET001) and letting ``VirtualClock`` drive the cold-load
latency accounting in tests.
"""
from __future__ import annotations

import contextlib
import queue as _queue
import threading
import zlib
from collections import deque
from typing import Callable, Iterable, Sequence

import numpy as np

from ..runtime.guards import guarded_by
from .delta import UserDelta, hydrate
from .durable import DurableStore, _LazyDeltaMap, _LazyShard
from .policy import GreedyDualClock

_COLD_WINDOW = 4096  # cold-load latency samples kept for p50/p99


def _percentile(samples, q: float) -> float:
    if not samples:
        return 0.0
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))


@guarded_by(
    "_lock",
    "_resident_bytes", "_total_bytes", "_prio", "_gd", "_pins", "_dirty",
    "_staged", "_warming", "_prefetched", "_cold_ms", "_warm_ms",
    "demotions", "writebacks", "reloads", "over_budget_events",
    "dirty_skips", "prefetch_requested", "prefetch_staged",
    "prefetch_hits", "prefetch_errors",
    holds=("_enforce", "_absorb_one", "_demote_one", "_account",
           "_demotable", "_writeback_commit"),
)
class ResidencyManager:
    """Byte-accounted residency budget over one ``(ForestStore,
    DurableStore)`` pair.

    The manager owns the host tier of the residency ladder::

        disk (RFD1 shard) <-> demoted (_LazyShard) <-> resident (UserDelta)

    and triggers the derived-artifact drops (tiles, arena run, hydrated
    object) that keep the device tier coherent on demotion.  All
    accounting state is guarded by ``_lock``: the prefetch thread stages
    parsed deltas and the serving thread absorbs/demotes concurrently.

    Attach via ``attach_residency`` — it also converts the store's delta
    map to a ``_LazyDeltaMap`` (re-materialization on touch) and seeds
    the accounting from the current residency state."""

    def __init__(
        self,
        store,
        durable: DurableStore,
        budget_bytes: int,
        clock: Callable[[], float] | None = None,
        writeback: bool = True,
        on_step: Callable[[str], None] | None = None,
    ) -> None:
        if budget_bytes < 1:
            raise ValueError(
                f"budget_bytes must be positive, got {budget_bytes}"
            )
        self.store = store
        self.durable = durable
        self.budget_bytes = int(budget_bytes)
        self.writeback = bool(writeback)
        self.on_step = on_step
        self._clock = clock
        self._lock = threading.Lock()
        self._gd = GreedyDualClock()
        self._resident_bytes: dict[str, int] = {}
        self._total_bytes = 0
        self._prio: dict[str, tuple[float, int]] = {}
        self._pins: dict[str, int] = {}
        self._dirty: set[str] = set()
        self._staged: dict[str, tuple[UserDelta, int]] = {}
        self._warming: set[str] = set()
        self._prefetched: set[str] = set()
        self._cold_ms: deque = deque(maxlen=_COLD_WINDOW)
        self._warm_ms: deque = deque(maxlen=_COLD_WINDOW)
        self.demotions = 0
        self.writebacks = 0
        self.reloads = 0
        self.over_budget_events = 0
        self.dirty_skips = 0
        self.prefetch_requested = 0
        self.prefetch_staged = 0
        self.prefetch_hits = 0
        self.prefetch_errors = 0

    # ---------------- clock (injected; DET001-clean) -----------------------
    def clock_now(self) -> float:
        """Injected-clock read; 0.0 when no clock was provided (latency
        accounting then degrades to counters only)."""
        return 0.0 if self._clock is None else float(self._clock())

    # ---------------- raw registry access ----------------------------------
    def _raw(self, user_id: str):
        """The registry value WITHOUT materializing: ``dict.get`` bypasses
        ``_LazyDeltaMap.__getitem__``, so placeholders stay placeholders."""
        return dict.get(self.store._deltas, user_id)

    def is_resident(self, user_id: str) -> bool:
        """True when the user's decoded delta is in host memory."""
        return not isinstance(self._raw(user_id), _LazyShard)

    # ---------------- serve-path notifications -----------------------------
    def touch(self, user_id: str) -> None:
        """Serve-path access: refresh the user's eviction priority, absorb
        a staged prefetch for this user, and account a prefetch hit when
        the prefetcher made this touch warm."""
        with self._lock:
            staged = self._staged.pop(user_id, None)
            if staged is not None and isinstance(self._raw(user_id),
                                                 _LazyShard):
                self._absorb_one(user_id, *staged)
            if user_id in self._prefetched:
                self._prefetched.discard(user_id)
                self.prefetch_hits += 1
            nbytes = self._resident_bytes.get(user_id)
            if nbytes is not None:
                self._prio[user_id] = self._gd.touch(float(nbytes))

    def notify_loaded(self, user_id: str, nbytes: int,
                      elapsed_s: float) -> None:
        """A ``_LazyShard`` materialized on the serve path (cold load):
        account the resident bytes, record the latency, and enforce the
        budget.  The loaded bytes ARE the disk bytes, so the user is
        clean by construction."""
        with self._lock:
            self._account(user_id, int(nbytes))
            self._dirty.discard(user_id)
            self.reloads += 1
            self._cold_ms.append(elapsed_s * 1000.0)
            self._enforce()

    def notify_registered(self, user_id: str, delta: UserDelta) -> None:
        """``add_delta`` / ``replace_delta_relabeled`` installed new
        resident content: account it and mark the user DIRTY — its disk
        shard (if any) no longer byte-matches, so demotion must write
        back first."""
        nbytes = len(delta.to_bytes())
        with self._lock:
            self._account(user_id, nbytes)
            self._dirty.add(user_id)
            self._staged.pop(user_id, None)
            self._prefetched.discard(user_id)
            self._enforce()

    def _account(self, user_id: str, nbytes: int) -> None:
        # caller holds self._lock (guarded_by holds=)
        self._total_bytes += nbytes - self._resident_bytes.get(user_id, 0)
        self._resident_bytes[user_id] = nbytes
        self._prio[user_id] = self._gd.touch(float(nbytes))

    def seed_resident(self, user_id: str, nbytes: int,
                      dirty: bool) -> None:
        """Account one already-resident user (``attach_residency``)."""
        with self._lock:
            self._account(user_id, nbytes)
            if dirty:
                self._dirty.add(user_id)

    def forget(self, user_id: str) -> None:
        """Drop a removed user from the accounting entirely."""
        with self._lock:
            self._total_bytes -= self._resident_bytes.pop(user_id, 0)
            self._prio.pop(user_id, None)
            self._dirty.discard(user_id)
            self._staged.pop(user_id, None)
            self._prefetched.discard(user_id)

    # ---------------- pinning ----------------------------------------------
    @contextlib.contextmanager
    def pin(self, user_ids: Sequence[str]):
        """Hold the named users resident for the duration (the serve path
        pins a plan's users across pack build + execute: demoting a user
        between ``arena_ensure`` and ``gather`` would drop the run the
        gather is about to index).  Budget enforcement runs at unpin, so
        a batch whose working set exceeds the budget completes and the
        overflow is reclaimed immediately after."""
        users = list(dict.fromkeys(user_ids))
        with self._lock:
            for u in users:
                self._pins[u] = self._pins.get(u, 0) + 1
        try:
            yield
        finally:
            with self._lock:
                for u in users:
                    n = self._pins.get(u, 0) - 1
                    if n <= 0:
                        self._pins.pop(u, None)
                    else:
                        self._pins[u] = n
                self._enforce()

    # ---------------- demotion ---------------------------------------------
    def accounted_bytes(self) -> int:
        """Total bytes of resident decoded deltas the manager accounts."""
        with self._lock:
            return self._total_bytes

    def demote(self, user_id: str) -> bool:
        """Explicitly demote one user to its ``_LazyShard`` placeholder.
        Returns False (and changes nothing) when the user is pinned,
        already demoted, or dirty with writeback disabled."""
        with self._lock:
            if user_id in self._pins:
                return False
            state = self._demotable(user_id)
            if state is None:
                return False
            if state == "dirty":
                if not self.writeback:
                    self.dirty_skips += 1
                    return False
                self._writeback_commit([user_id])
            return self._demote_one(user_id)

    def _demotable(self, user_id: str) -> str | None:
        # caller holds self._lock (guarded_by holds=).  "clean" when the
        # live shard byte-matches the resident delta, "dirty" when a
        # writeback is needed first, None when not demotable at all.
        raw = self._raw(user_id)
        if raw is None or isinstance(raw, _LazyShard):
            return None
        if user_id in self._dirty:
            return "dirty"
        if self.durable.shard_for_user(user_id) is None:
            return "dirty"  # never synced: treat as writeback-needed
        return "clean"

    def _demote_one(self, user_id: str) -> bool:
        # caller holds self._lock (guarded_by holds=)
        entry = self.durable.shard_for_user(user_id)
        if entry is None:
            return False
        store = self.store
        placeholder = _LazyShard(self.durable, store._deltas, user_id,
                                 entry.shard_id, entry.generation)
        dict.__setitem__(store._deltas, user_id, placeholder)
        store._hydrated.pop(user_id, None)
        store._tile_counts = {
            k: v for k, v in store._tile_counts.items() if k[0] != user_id
        }
        # decoded tiles go, but the user's hit-rate history survives — a
        # demotion is not a content change (cf. the user_version rule)
        store.cache.invalidate_user(user_id, reset_stats=False)
        if store.arena is not None:
            store.arena.invalidate(user_id)
        prio = self._prio.pop(user_id, None)
        if prio is not None:
            self._gd.evicted(prio[0])
        self._total_bytes -= self._resident_bytes.pop(user_id, 0)
        self._dirty.discard(user_id)
        self.demotions += 1
        return True

    def _writeback_commit(self, user_ids) -> None:
        # caller holds self._lock; stages every named user's resident
        # bytes and lands them in ONE atomic epoch bump.
        for u in user_ids:
            self.durable.put_delta(u, self.store._deltas[u])
        self.durable.commit(on_step=self.on_step)
        for u in user_ids:
            self._dirty.discard(u)
            self.writebacks += 1

    def enforce(self) -> None:
        """Demote coldest unpinned users until the budget holds."""
        with self._lock:
            self._enforce()

    def _enforce(self) -> None:
        # caller holds self._lock (guarded_by holds=)
        if self._total_bytes <= self.budget_bytes:
            return
        clean, dirty = [], []
        for u in self._resident_bytes:
            if u in self._pins:
                continue
            state = self._demotable(u)
            if state == "clean":
                clean.append(u)
            elif state == "dirty":
                dirty.append(u)
        order = lambda u: self._prio.get(u, (0.0, 0))  # noqa: E731
        for u in sorted(clean, key=order):
            if self._total_bytes <= self.budget_bytes:
                return
            self._demote_one(u)
        if self._total_bytes <= self.budget_bytes:
            return
        if self.writeback and dirty:
            dirty.sort(key=order)
            need, acc = [], self._total_bytes
            for u in dirty:
                if acc <= self.budget_bytes:
                    break
                need.append(u)
                acc -= self._resident_bytes.get(u, 0)
            self._writeback_commit(need)
            for u in need:
                self._demote_one(u)
        elif dirty:
            self.dirty_skips += len(dirty)
        if self._total_bytes > self.budget_bytes:
            # everything left is pinned (or undemotable): the overflow is
            # transient but must never be silent
            self.over_budget_events += 1

    # ---------------- prefetch staging --------------------------------------
    def wants_prefetch(self, user_id: str) -> bool:
        """True when a prefetch would help: demoted, not already staged
        or being warmed."""
        with self._lock:
            return (
                isinstance(self._raw(user_id), _LazyShard)
                and user_id not in self._staged
                and user_id not in self._warming
            )

    def begin_warm(self, user_id: str) -> bool:
        """Claim one user for warming (dedupes concurrent prefetches).
        Returns False when warming would be useless."""
        with self._lock:
            if (
                not isinstance(self._raw(user_id), _LazyShard)
                or user_id in self._staged
                or user_id in self._warming
            ):
                return False
            self._warming.add(user_id)
            self.prefetch_requested += 1
            return True

    def end_warm(self, user_id: str) -> None:
        with self._lock:
            self._warming.discard(user_id)

    def note_prefetch_error(self) -> None:
        """A prefetch read/parse failed — best-effort, counted; the serve
        path will surface the typed fault through quarantine/repair."""
        with self._lock:
            self.prefetch_errors += 1

    def stage(self, user_id: str, delta: UserDelta, nbytes: int,
              elapsed_s: float, comp=None, tiles=None,
              block_trees: int = 32) -> None:
        """Hand a prefetch-parsed delta (plus optionally the hydrated
        forest and pre-decoded heap tiles — pure functions of the shard
        bytes, so the warm thread may compute them) to the manager.  It
        is absorbed into the store ON THE SERVING THREAD
        (``absorb_staged`` / first ``touch``) — the prefetch thread never
        mutates serving structures."""
        with self._lock:
            if not isinstance(self._raw(user_id), _LazyShard):
                return  # materialized (or replaced) while we were reading
            self._staged[user_id] = (
                delta, int(nbytes), comp, tiles, int(block_trees)
            )
            self._warm_ms.append(elapsed_s * 1000.0)
            self.prefetch_staged += 1

    def absorb_staged(self) -> int:
        """Install every staged prefetch into the registry (serving
        thread).  Returns the number absorbed."""
        with self._lock:
            staged = list(self._staged.items())
            self._staged.clear()
            n = 0
            for u, payload in staged:
                if isinstance(self._raw(u), _LazyShard):
                    self._absorb_one(u, *payload)
                    n += 1
            if n:
                self._enforce()
            return n

    def _absorb_one(self, user_id: str, delta: UserDelta, nbytes: int,
                    comp=None, tiles=None, block_trees: int = 32) -> None:
        # caller holds self._lock (guarded_by holds=)
        dict.__setitem__(self.store._deltas, user_id, delta)
        if comp is not None:
            self.store._hydrated[user_id] = comp
        if tiles:
            # seed the tile cache so the serve path skips entropy decode
            # entirely — this is the latency the prefetch exists to hide
            run_key = (user_id, block_trees)
            self.store._tile_counts[run_key] = len(tiles)
            for i, t in enumerate(tiles):
                self.store.cache.put((user_id, block_trees, i), t)
        self._account(user_id, nbytes)
        self._dirty.discard(user_id)
        self._prefetched.add(user_id)

    # ---------------- introspection -----------------------------------------
    def stats(self) -> dict:
        """Residency dashboard feed (surfaced as
        ``ForestServer.stats()["residency"]``)."""
        with self._lock:
            n_users = len(dict.keys(self.store._deltas))
            resident = len(self._resident_bytes)
            return {
                "budget_bytes": self.budget_bytes,
                "resident_bytes": self._total_bytes,
                "resident_users": resident,
                "demoted_users": n_users - resident,
                "dirty_users": len(self._dirty),
                "pinned_users": len(self._pins),
                "staged_prefetches": len(self._staged),
                "demotions": self.demotions,
                "writebacks": self.writebacks,
                "reloads": self.reloads,
                "over_budget_events": self.over_budget_events,
                "dirty_skips": self.dirty_skips,
                "prefetch_requested": self.prefetch_requested,
                "prefetch_staged": self.prefetch_staged,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_errors": self.prefetch_errors,
                "cold_load_ms_p50": _percentile(self._cold_ms, 50),
                "cold_load_ms_p99": _percentile(self._cold_ms, 99),
                "prefetch_load_ms_p50": _percentile(self._warm_ms, 50),
            }


def attach_residency(
    store,
    durable: DurableStore,
    budget_bytes: int,
    clock: Callable[[], float] | None = None,
    writeback: bool = True,
    on_step: Callable[[str], None] | None = None,
) -> ResidencyManager:
    """Put ``store`` under a residency budget backed by ``durable``.

    Converts the store's delta registry to a ``_LazyDeltaMap`` (so a
    demoted user re-materializes on touch), seeds the byte accounting
    from the CURRENT residency state (a user whose resident bytes match
    the live shard is clean; anything else starts dirty), installs the
    manager on both the store and the durable store (the ``_LazyShard``
    load path reports cold loads through ``durable.residency``), and
    enforces the budget once."""
    if not isinstance(store._deltas, _LazyDeltaMap):
        lazy = _LazyDeltaMap(durable)
        for u, v in store._deltas.items():
            dict.__setitem__(lazy, u, v)
        store._deltas = lazy
    else:
        store._deltas._durable = durable
    manager = ResidencyManager(
        store, durable, budget_bytes, clock=clock,
        writeback=writeback, on_step=on_step,
    )
    for u, v in list(dict.items(store._deltas)):
        if isinstance(v, _LazyShard):
            continue
        data = v.to_bytes()
        e = durable.shard_for_user(u)
        clean = (
            e is not None and e.length == len(data)
            and e.crc == (zlib.crc32(data) & 0xFFFFFFFF)
            and e.generation == v.codebook_generation
        )
        manager.seed_resident(u, len(data), dirty=not clean)
    store.residency = manager
    durable.residency = manager
    manager.enforce()
    return manager


@guarded_by("_cv", "_pending")
class Prefetcher:
    """Plan-driven shard warmer over one ``ResidencyManager``.

    ``request`` takes the user ids an upcoming batch needs (the
    scheduler's pre-plan slot calls it with batch ``k+1`` while batch
    ``k`` executes) and warms the demoted ones: read the shard, parse
    the RFD1 frame, and STAGE the delta with the manager — absorption
    into the store happens on the serving thread.  ``background=True``
    runs warms on a daemon thread (the wall-clock deployment);
    ``background=False`` warms inline on the caller's thread (the
    deterministic ``VirtualClock`` mode, mirroring the executor's
    ``overlap=False``).  Quarantined users are never warmed: ``server``
    (optional) supplies the quarantine set at request time.

    Warm failures are best-effort by design: a corrupt shard is counted
    (``prefetch_errors``) and LEFT COLD, so the serve path hits the
    typed ``IntegrityError`` where quarantine + parity auto-repair
    handle it — a prefetch can never paper over a fault."""

    def __init__(self, manager: ResidencyManager, server=None,
                 background: bool = True, decode: bool = True,
                 block_trees: int | None = None) -> None:
        self.manager = manager
        self.server = server
        self.background = bool(background)
        self.decode = bool(decode)
        # match the tile-block size the serving engine will read, so the
        # warmed cache entries are the ones the pack path probes
        # (pipelined/sharded decode at 8, the simple engine at 32)
        if block_trees is None:
            block_trees = 8 if manager.store.arena is not None else 32
        self.block_trees = int(block_trees)
        self._cv = threading.Condition()
        self._pending = 0
        self._work: _queue.SimpleQueue = _queue.SimpleQueue()
        self._worker: threading.Thread | None = None
        if self.background:
            self._worker = threading.Thread(
                target=self._worker_loop, name="residency-prefetch",
                daemon=True,
            )
            self._worker.start()

    def request(self, user_ids: Iterable[str]) -> int:
        """Queue (or inline-run) warms for the demoted users among
        ``user_ids``.  Returns the number of warms issued."""
        server = self.server
        quarantined = (
            set(server.quarantined_users) if server is not None else ()
        )
        issued = 0
        for u in dict.fromkeys(user_ids):
            if u in quarantined:
                continue
            if not self.manager.begin_warm(u):
                continue
            issued += 1
            with self._cv:
                self._pending += 1
            if self.background:
                self._work.put(u)
            else:
                self._warm(u)
        return issued

    def _worker_loop(self) -> None:
        while True:
            u = self._work.get()
            if u is None:
                return
            self._warm(u)

    def _warm(self, user_id: str) -> None:
        m = self.manager
        try:
            entry = m.durable.shard_for_user(user_id)
            if entry is not None:
                t0 = m.clock_now()
                data = m.durable.read_shard(entry.shard_id)
                delta = UserDelta.from_bytes(data)
                comp = tiles = None
                if self.decode:
                    # hydrate + entropy-decode are pure functions of the
                    # shard bytes and the (immutable) codebook generation
                    # it references — safe off-thread, and they are the
                    # bulk of the cold-serve latency
                    from ..serving.pack import iter_heap_tiles

                    comp = hydrate(
                        delta,
                        m.store.codebook_for(delta.codebook_generation),
                    )
                    tiles = list(iter_heap_tiles(comp, self.block_trees))
                m.stage(
                    user_id, delta, len(data), m.clock_now() - t0,
                    comp=comp, tiles=tiles, block_trees=self.block_trees,
                )
        except Exception:  # noqa: BLE001 — best-effort: counted, the
            # serve path surfaces the typed fault through quarantine
            m.note_prefetch_error()
        finally:
            m.end_warm(user_id)
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every issued warm has finished staging."""
        with self._cv:
            return self._cv.wait_for(lambda: self._pending == 0, timeout)

    def close(self) -> None:
        """Drain and stop the background worker (idempotent)."""
        if self._worker is None:
            return
        self.drain()
        self._work.put(None)
        self._worker.join()
        self._worker = None

"""jit-able train / prefill / decode step functions.

These are the exact functions the dry-run lowers and the drivers execute;
there is no separate "dry-run model".
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import decode_step as _decode_step
from ..models import loss_fn, prefill
from ..optim.adamw import AdamWConfig, adamw_update
from ..optim.compression import GradCompressionConfig, compress_gradients


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    remat: str | None = "full",
    grad_comp: GradCompressionConfig | None = None,
    use_flash: bool = False,
    aux_weight: float = 0.01,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    When grad compression is on, opt_state additionally carries the "ef"
    error-feedback pytree (init with optim.compression.init_error_feedback).
    """

    def train_step(params, opt_state, batch):
        def lf(p):
            return loss_fn(
                cfg, p, batch["tokens"], batch["labels"],
                batch.get("frontend_embeds"), aux_weight=aux_weight,
                use_flash=use_flash, remat=remat,
            )

        loss, grads = jax.value_and_grad(lf)(params)
        if grad_comp is not None and grad_comp.enabled:
            grads, new_ef = compress_gradients(
                grad_comp, grads, opt_state["ef"]
            )
        params, new_opt, metrics = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        if grad_comp is not None and grad_comp.enabled:
            new_opt["ef"] = new_ef
        metrics["loss"] = loss
        return params, new_opt, metrics

    return train_step


def make_wire_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh,
    pspecs,
    *,
    bits: int = 4,
    remat: str | None = "full",
    aux_weight: float = 0.01,
    rules: dict | None = None,
):
    """Train step with the data-parallel gradient sync done MANUALLY under
    shard_map (manual over ``data``, ``model`` left automatic) so the §7
    dithered quantizer runs at the wire level: the cross-data traffic is
    int8 4-bit codes instead of bf16/f32 gradients.

    FSDP layout is preserved: params/optimizer enter as their data shards,
    are all-gathered (bf16) for compute, and gradients are sliced back to
    shards after the quantized psum.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from ..models.sharding import logical_sharding
    from ..optim.compression import wire_quantized_psum

    assert "pod" not in mesh.axis_names, "wire grad sync: single-pod demo"
    d_size = mesh.shape["data"]

    def data_dim(spec) -> int | None:
        for i, a in enumerate(spec):
            if a == "data" or (isinstance(a, tuple) and "data" in a):
                return i
        return None

    def project(spec) -> P:
        return P(*(("data" if i == data_dim(spec) else None)
                   for i in range(len(spec))))

    pspecs_data = jax.tree.map(
        project, pspecs, is_leaf=lambda s: isinstance(s, P)
    )
    opt_specs = {"m": pspecs_data, "v": pspecs_data, "step": P()}
    # inside the manual-data region, 'batch'/'d_model_fsdp' must not
    # constrain onto the (now manual) data axis
    inner_rules = dict(rules or {})
    inner_rules.update({"batch": None, "d_model_fsdp": None})

    dims = jax.tree.map(data_dim, pspecs, is_leaf=lambda s: isinstance(s, P))

    def gather_leaf(x, dim):
        if dim is None:
            return x
        return jax.lax.all_gather(x, "data", axis=dim, tiled=True)

    def slice_leaf(g, dim):
        if dim is None:
            return g
        rank = jax.lax.axis_index("data")
        shard = g.shape[dim] // d_size
        return jax.lax.dynamic_slice_in_dim(g, rank * shard, shard, dim)

    from functools import partial as _partial

    import dataclasses as _dc

    from ..optim.adamw import clip_by_global_norm

    no_clip_cfg = _dc.replace(opt_cfg, clip_norm=float("inf"))

    @_partial(
        jax.shard_map, mesh=mesh, axis_names={"data"},
        in_specs=(pspecs_data, opt_specs, {"tokens": P("data", None),
                                           "labels": P("data", None)}),
        out_specs=(pspecs_data, opt_specs,
                   {"grad_norm": P(), "lr": P(), "loss": P()}),
        check_vma=False,
    )
    def train_step(params_shard, opt_state, batch):
        with logical_sharding(mesh, inner_rules):
            params = jax.tree.map(gather_leaf, params_shard, dims)

            def lf(p):
                return loss_fn(cfg, p, batch["tokens"], batch["labels"],
                               aux_weight=aux_weight, remat=remat)

            loss, grads = jax.value_and_grad(lf)(params)
            loss = jax.lax.pmean(loss, "data")
            key = jax.random.fold_in(
                jax.random.PRNGKey(0),
                opt_state["step"] * d_size + jax.lax.axis_index("data"),
            )
            grads = wire_quantized_psum(grads, "data", bits=bits, key=key,
                                        n_ranks=d_size)
            # global clip on the (rank-identical) full gradients, then
            # slice to FSDP shards for the update
            grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
            grads = jax.tree.map(slice_leaf, grads, dims)
            params_shard, new_opt, metrics = adamw_update(
                no_clip_cfg, params_shard, grads, opt_state
            )
            metrics["loss"] = loss
            metrics["grad_norm"] = gnorm
            return params_shard, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, use_flash: bool = False):
    """(params, tokens[, frontend_embeds]) -> (last logits, decode cache)."""

    def prefill_step(params, tokens, frontend_embeds=None):
        return prefill(cfg, params, tokens, frontend_embeds,
                       use_flash=use_flash)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, tokens (B,), cache) -> (logits (B,V), new cache)."""

    def serve_step(params, tokens, cache):
        return _decode_step(cfg, params, tokens, cache)

    return serve_step

"""Single-forest serving benchmark driver.

Serving goes through the unified session API (ISSUE 4):

    from repro.serving import ForestServer
    server = ForestServer.from_forest(comp)
    pred = server.predict(x_binned)

(The PR 1 ``serve_compressed_forest`` shim that bridged callers to this
API has been removed — its deprecation window closed.)  The heap packing
helpers (``tree_to_heap`` / ``iter_heap_tiles``) moved to
``repro.serving.pack`` and are re-exported here for compatibility.

    PYTHONPATH=src python -m repro.launch.serve_forest --trees 100 \
        --depth 8 --rows 5000 --batch 1024
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core.compressed_predict import predict_compressed
from ..core.forest_codec import CompressedForest
from ..serving.pack import iter_heap_tiles, tree_to_heap  # noqa: F401

__all__ = ["iter_heap_tiles", "tree_to_heap"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--rows", type=int, default=5000)
    ap.add_argument("--features", type=int, default=8)
    ap.add_argument("--task", choices=("classification", "regression"),
                    default="classification")
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--block-trees", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..core.forest_codec import compress_forest
    from ..data.tabular import TabularSpec, make_dataset
    from ..forest import fit_binner, to_compact_forest, train_forest
    from ..serving import ForestServer

    spec = TabularSpec("serve", args.rows, args.features, args.task, 2, 2)
    x, y, cat = make_dataset(spec, seed=args.seed)
    binner = fit_binner(x, categorical=cat, n_bins=32)
    model = train_forest(
        x, y, binner, n_trees=args.trees, max_depth=args.depth,
        task=args.task, n_classes=2, seed=args.seed,
    )
    forest = to_compact_forest(model)
    comp = CompressedForest.from_bytes(compress_forest(forest).to_bytes())
    blob_bytes = len(comp.to_bytes())
    xb = binner.transform(x)

    server = ForestServer.from_forest(comp)
    # warm up (jit compile + arena admission) then measure session serving
    server.predict(xb[: args.batch], block_trees=args.block_trees)
    t0 = time.time()
    pred = server.predict(xb[: args.batch], block_trees=args.block_trees)
    t_serve = time.time() - t0
    ref = predict_compressed(comp, xb[: args.batch])
    agree = float((pred == ref).mean()) if args.task == "classification" \
        else float(np.max(np.abs(pred - ref)))
    print(
        f"forest: {args.trees} trees depth {args.depth} "
        f"({blob_bytes} compressed bytes)\n"
        f"serve {args.batch} rows: {t_serve * 1e3:.1f} ms "
        f"({args.batch / t_serve:.0f} rows/s), "
        f"agreement vs predict_compressed: {agree}\n"
        f"session: {server.stats()['plan_cache']}"
    )


if __name__ == "__main__":
    main()

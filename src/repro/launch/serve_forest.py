"""Batched forest serving straight from the compressed bytes (paper §5),
fused with the Pallas traversal kernel.

Pipeline per request batch:

    compressed bytes --(table-driven Huffman decode, vectorized)--> trees
        --(heap packing, tile of ``block_trees`` trees)--> device buffers
        --(forest_predict_agg kernel)--> vote counts / fit sums --> prediction

Tiles are streamed: the device predict for tile ``i`` is dispatched
asynchronously (JAX dispatch returns before the kernel finishes) and the host
immediately decodes + packs tile ``i + 1``, so decode overlaps predict and
the device-side working set stays O(single tree-tile) — the forest is never
materialized on the device at once.  In-kernel ensemble aggregation means
each tile returns only (N, C) votes / (N,) sums, not (T, N) per-tree fits.

    PYTHONPATH=src python -m repro.launch.serve_forest --trees 100 \
        --depth 8 --rows 5000 --batch 1024
"""
from __future__ import annotations

import argparse
import time
from typing import Iterator

import numpy as np

from ..core.compressed_predict import iter_trees, predict_compressed
from ..core.forest_codec import CompressedForest
from ..core.tree import Tree


def tree_to_heap(
    tree: Tree,
    fit_values: np.ndarray | None,
    feature: np.ndarray,
    threshold: np.ndarray,
    fit: np.ndarray,
    is_internal: np.ndarray,
) -> None:
    """Write one preorder compact tree into heap-form rows (node i ->
    children 2i+1 / 2i+2), the layout the Pallas kernel traverses."""
    stack = [(0, 0)]  # (preorder node id, heap slot)
    left, right = tree.children_left, tree.children_right
    feat, thr, nfit = tree.feature, tree.threshold, tree.node_fit
    while stack:
        i, slot = stack.pop()
        if feat[i] >= 0:
            feature[slot] = feat[i]
            threshold[slot] = thr[i]
            is_internal[slot] = True
            stack.append((int(right[i]), 2 * slot + 2))
            stack.append((int(left[i]), 2 * slot + 1))
        elif fit_values is not None:
            fit[slot] = fit_values[int(nfit[i])]
        else:
            fit[slot] = float(nfit[i])


def iter_heap_tiles(
    comp: CompressedForest, block_trees: int
) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Stream (feature, threshold, fit, is_internal) heap tiles of up to
    ``block_trees`` trees each, decoded on the fly from the compressed
    bytes — host memory holds one tile, not the forest."""
    n_heap = (1 << (comp.max_depth + 1)) - 1
    fit_values = (
        comp.fit_values if comp.meta.task == "regression" else None
    )
    buf: list[Tree] = []

    def pack(trees: list[Tree]):
        t = len(trees)
        feature = np.zeros((t, n_heap), np.int32)
        threshold = np.zeros((t, n_heap), np.int32)
        fit = np.zeros((t, n_heap), np.float32)
        is_internal = np.zeros((t, n_heap), bool)
        for k, tree in enumerate(trees):
            tree_to_heap(
                tree, fit_values,
                feature[k], threshold[k], fit[k], is_internal[k],
            )
        return feature, threshold, fit, is_internal

    for tree in iter_trees(comp):
        buf.append(tree)
        if len(buf) == block_trees:
            yield pack(buf)
            buf = []
    if buf:
        yield pack(buf)


def serve_compressed_forest(
    comp: CompressedForest,
    x_binned: np.ndarray,
    block_trees: int = 32,
    interpret: bool | None = None,
) -> np.ndarray:
    """Predict for (n, d) binned observations straight from the compressed
    format through the fused Pallas kernel.  Returns (n,) predictions
    (majority vote / ensemble mean).

    Decode of tile i+1 overlaps the device predict of tile i: the kernel
    call is dispatched asynchronously and only the final accumulated
    votes/sums are synchronized."""
    from ..kernels.tree_predict.tree_predict import forest_predict_agg

    meta = comp.meta
    # tiles stay numpy on the host side: the kernel wrapper's 2**24 range
    # check runs with numpy (no device sync), so each tile's kernel is
    # dispatched without blocking on the previous one
    xb = np.ascontiguousarray(x_binned, np.int32)
    n_classes = meta.n_classes if meta.task == "classification" else 0
    total = None
    n_trees = 0
    for feature, threshold, fit, is_internal in iter_heap_tiles(
        comp, block_trees
    ):
        part = forest_predict_agg(
            xb,
            feature,
            threshold,
            fit,
            is_internal,
            max_depth=comp.max_depth,
            n_classes=n_classes,
            interpret=interpret,
        )  # dispatched async; host continues decoding the next tile
        total = part if total is None else total + part
        n_trees += feature.shape[0]
    if total is None:
        return np.zeros(x_binned.shape[0])
    if meta.task == "classification":
        return np.asarray(total.argmax(-1)).astype(np.float64)
    return np.asarray(total, np.float64) / max(n_trees, 1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--depth", type=int, default=8)
    ap.add_argument("--rows", type=int, default=5000)
    ap.add_argument("--features", type=int, default=8)
    ap.add_argument("--task", choices=("classification", "regression"),
                    default="classification")
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--block-trees", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..core.forest_codec import compress_forest
    from ..data.tabular import TabularSpec, make_dataset
    from ..forest import fit_binner, to_compact_forest, train_forest

    spec = TabularSpec("serve", args.rows, args.features, args.task, 2, 2)
    x, y, cat = make_dataset(spec, seed=args.seed)
    binner = fit_binner(x, categorical=cat, n_bins=32)
    model = train_forest(
        x, y, binner, n_trees=args.trees, max_depth=args.depth,
        task=args.task, n_classes=2, seed=args.seed,
    )
    forest = to_compact_forest(model)
    comp = CompressedForest.from_bytes(compress_forest(forest).to_bytes())
    blob_bytes = len(comp.to_bytes())
    xb = binner.transform(x)

    # warm up (jit compile) then measure streamed serving
    serve_compressed_forest(comp, xb[: args.batch],
                            block_trees=args.block_trees)
    t0 = time.time()
    pred = serve_compressed_forest(comp, xb[: args.batch],
                                   block_trees=args.block_trees)
    t_serve = time.time() - t0
    ref = predict_compressed(comp, xb[: args.batch])
    agree = float((pred == ref).mean()) if args.task == "classification" \
        else float(np.max(np.abs(pred - ref)))
    print(
        f"forest: {args.trees} trees depth {args.depth} "
        f"({blob_bytes} compressed bytes)\n"
        f"serve {args.batch} rows: {t_serve * 1e3:.1f} ms "
        f"({args.batch / t_serve:.0f} rows/s), "
        f"agreement vs predict_compressed: {agree}"
    )


if __name__ == "__main__":
    main()

"""Ragged multi-tenant serving driver and the PR 3 pipelined STAGE
helpers.

Serving goes through the unified session API (ISSUE 4):

    from repro.serving import ForestServer
    server = ForestServer(store)
    plan = server.plan(requests)     # grouping + cost-model engine choice
    preds = server.execute(plan, [x for _, x in requests])

(The PR 2 ``serve_store_batch`` shim that bridged callers to this API has
been removed — its deprecation window closed.)  The PR 3 pipelined STAGE
helpers (``pack_pipelined_batch`` / ``run_pipelined_kernel`` /
``finalize_pipelined_batch``) are kept verbatim below: they are the
un-memoized baseline ``benchmarks/serve_pipeline.py`` times
stage-by-stage and ``benchmarks/serve_session.py`` compares the session's
warm path against.

    PYTHONPATH=src python -m repro.launch.serve_store --users 40 \
        --requests 64 --rows 256 --engine pipelined
"""
from __future__ import annotations

import argparse
import time
from typing import NamedTuple, Sequence

import numpy as np

from ..serving.pack import (
    group_requests as _group_requests,
    pad_heap_width as _pad_heap_width,  # canonical home: serving.pack
    pack_host_tiles,
)
from ..store.runtime import ForestStore

Request = tuple[str, np.ndarray]


def pack_request_batch(
    store: ForestStore,
    requests: Sequence[Request],
    block_trees: int = 32,
):
    """Group a mixed-user batch for the segmented kernel (the PR 2 host
    packing, kept for ``engine="simple"`` oracles and tests; the canonical
    pieces live in ``serving.pack``)."""
    users, _seg_of, xb, obs_seg, row_slices = _group_requests(requests)
    tree_pack, max_depth, seg_trees = pack_host_tiles(
        store, users, block_trees
    )
    return xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees


def _finalize(
    store: ForestStore,
    requests: Sequence[Request],
    row_slices,
    total: np.ndarray,
    task: str,
) -> list[np.ndarray]:
    out: list[np.ndarray] = []
    for (user_id, _), sl in zip(requests, row_slices):
        if task == "classification":
            out.append(total[sl].argmax(-1).astype(np.float64))
        else:
            out.append(
                total[sl].astype(np.float64)
                / max(store.n_trees(user_id), 1)
            )
    return out


def _empty_preds(requests):
    return [np.zeros(len(x), np.float64) for _, x in requests]


# ---------------------------------------------------------------------------
# PR 3 pipelined stage helpers — the un-memoized baseline the benchmarks
# time; the session API composes the same stages through serving.engines.
# ---------------------------------------------------------------------------

class PipelinedBatch(NamedTuple):
    """Output of ``pack_pipelined_batch``: everything the one-launch DMA
    kernel needs, plus the row bookkeeping to undo the segment sort."""

    xb_s: np.ndarray
    oseg_s: np.ndarray
    code: object  # (T_pad, H) device
    fit: object  # (T_pad, H) device
    tree_seg: np.ndarray
    chunk_lo: np.ndarray
    chunk_hi: np.ndarray
    max_depth: int
    block_trees: int
    block_obs: int
    order: np.ndarray
    row_slices: list


def pack_pipelined_batch(
    store, requests, block_trees: int = 8, block_obs: int = 128,
) -> PipelinedBatch | None:
    """Pipelined pack stage: group rows, arena index-gather, segment sort,
    chunk ranges.  Returns None for an all-empty batch.  (Public so the
    benchmark times the EXACT stage the engine runs.)"""
    from ..kernels.tree_predict.tree_predict import segment_chunk_ranges

    users, _seg_of, xb, obs_seg, row_slices = _group_requests(requests)
    n = len(xb)
    if n == 0:
        return None
    code, fit, tree_seg, counts, max_depth = store.arena_pack(
        users, block_trees
    )
    # rows sorted by segment id == arena gather order, so each row block's
    # needed chunk range is tight (block-diagonal work in one launch)
    order = np.argsort(obs_seg, kind="stable")
    xb_s = np.ascontiguousarray(xb[order])
    oseg_s = np.ascontiguousarray(obs_seg[order])
    block_obs = min(block_obs, n)
    chunk_lo, chunk_hi = segment_chunk_ranges(
        oseg_s, tree_seg, block_trees, block_obs
    )
    return PipelinedBatch(
        xb_s, oseg_s, code, fit, tree_seg, chunk_lo, chunk_hi, max_depth,
        block_trees, block_obs, order, row_slices,
    )


def run_pipelined_kernel(store, pb: PipelinedBatch, interpret=None):
    """Pipelined kernel stage: the single double-buffered DMA launch."""
    from ..kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented_packed,
    )

    task = store.shared.task
    n_classes = store.shared.n_classes if task == "classification" else 0
    return forest_predict_agg_segmented_packed(
        pb.xb_s, pb.oseg_s, pb.code, pb.fit, pb.tree_seg, pb.chunk_lo,
        pb.chunk_hi, pb.max_depth, store.arena.tb2, n_classes=n_classes,
        block_trees=pb.block_trees, block_obs=pb.block_obs,
        interpret=interpret,
    )


def finalize_pipelined_batch(
    store, requests, pb: PipelinedBatch, out
) -> list[np.ndarray]:
    """Pipelined finalize stage: unsort + per-request argmax/mean."""
    task = store.shared.task
    out = np.asarray(out, np.float64)
    total = np.empty_like(out)
    total[pb.order] = out
    return _finalize(store, requests, pb.row_slices, total, task)


def serve_pipelined_uncached(
    store, requests, block_trees: int = 8, block_obs: int = 128,
    interpret=None,
) -> list[np.ndarray]:
    """The PR 3 pipelined path composed stage-by-stage WITHOUT the session
    plan cache — the baseline ``benchmarks/serve_session.py`` measures the
    cross-batch gather memoization against."""
    pb = pack_pipelined_batch(store, requests, block_trees, block_obs)
    if pb is None:
        return _empty_preds(requests)
    out = run_pipelined_kernel(store, pb, interpret)
    return finalize_pipelined_batch(store, requests, pb, out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=40)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rows", type=int, default=256,
                    help="rows per request")
    ap.add_argument("--task", choices=("classification", "regression"),
                    default="classification")
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--block-trees", type=int, default=None)
    ap.add_argument("--engine", default=None,
                    choices=("simple", "pipelined", "sharded"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..serving import ForestServer
    from ..store import build_store, make_request_batch, make_synthetic_fleet

    fleet = make_synthetic_fleet(
        args.users, task=args.task, max_depth=args.depth, seed=args.seed
    )
    t0 = time.time()
    store = build_store(fleet)
    t_build = time.time() - t0
    rep = store.size_report()
    server = ForestServer(store)
    requests = make_request_batch(
        store, args.requests, args.rows, args.seed
    )
    plan = server.plan(requests, engine=args.engine,
                       block_trees=args.block_trees)
    server.execute(plan, [x for _, x in requests])  # compile + warm caches
    t0 = time.time()
    preds = server.execute(plan, [x for _, x in requests])
    t_serve = time.time() - t0
    n_rows = sum(len(x) for _, x in requests)

    mismatch = 0
    for (user_id, x), p in zip(requests[:8], preds[:8]):
        ref = store.predict(user_id, x)
        if args.task == "classification":
            mismatch += int((p != ref).sum())
        else:
            mismatch += int(np.max(np.abs(p - ref)) > 1e-4)
    stats = server.stats()
    stats["tile_cache"].pop("per_user", None)  # too chatty for the demo
    print(
        f"store: {rep['n_users']} users, "
        f"{rep['total_bytes']} bytes total "
        f"({rep['shared_codebook_bytes']} shared codebook), "
        f"built in {t_build:.1f}s\n"
        f"plan: engine={plan.engine.name} ({plan.engine.reason}), "
        f"{plan.n_users} users / {plan.t_pad} padded trees / "
        f"{plan.n_row_blocks} row blocks\n"
        f"ragged batch: {len(requests)} requests / {n_rows} rows in "
        f"{t_serve * 1e3:.1f} ms ({n_rows / t_serve:.0f} rows/s)\n"
        f"session stats: {stats}\n"
        f"parity vs per-user predict_compressed (8 requests): "
        f"{mismatch} mismatches"
    )


if __name__ == "__main__":
    main()

"""Ragged multi-tenant serving from the compressed store — pipelined
(ISSUE 3 tentpole).

A request batch mixes MANY users: each request is ``(user_id, x_binned)``
against that user's own forest.  Three engines share one grouping front-end
(rows → one (N, d) block + int32 segment id per row):

* ``engine="pipelined"`` (default) — the device-resident TILE ARENA packs
  each requested user's decoded heap tiles ONCE (fused node attributes,
  common padded width); per batch the driver index-gathers the users' runs
  on device, sorts rows by segment, and makes ONE launch of the
  double-buffered DMA kernel (``forest_predict_agg_segmented_packed``),
  which streams tree chunks HBM→VMEM overlapping the previous chunk's
  traversal and skips chunks outside each row block's segment range.
* ``engine="sharded"`` (default when >1 device) — the ragged tree axis is
  partitioned ACROSS devices (greedy bin-pack on per-user tree counts),
  each device runs the pipelined kernel over its own tree shard against
  the replicated batch, and the (N, C) partial votes/sums all-reduce via
  ``psum`` — fleets whose hot set exceeds one core's VMEM scale out.
* ``engine="simple"`` — the PR 2 path, kept verbatim: host-side tile
  re-pack each call + one segmented-kernel launch per tree chunk.  The
  differential oracle and the serving baseline the pipelined engines are
  benchmarked against (``benchmarks/serve_pipeline.py``).

All engines aggregate per row over that row's own forest only and match
per-user ``predict_compressed`` (vote counts are integer-exact; the
regression mean accumulates in float32 on device).

    PYTHONPATH=src python -m repro.launch.serve_store --users 40 \
        --requests 64 --rows 256 --engine pipelined
"""
from __future__ import annotations

import argparse
import time
from typing import NamedTuple, Sequence

import numpy as np

from ..store.runtime import ForestStore

Request = tuple[str, np.ndarray]

_ENGINE_BLOCKS = {  # per-engine (block_trees, block_obs) sweet spots
    "simple": (32, 256),
    "pipelined": (8, 128),
    "sharded": (8, 128),
}


def _pad_heap_width(tile_arr: np.ndarray, h: int) -> np.ndarray:
    t, h_u = tile_arr.shape
    if h_u == h:
        return tile_arr  # width already common: no copy (hot fleet path)
    out = np.zeros((t, h), dtype=tile_arr.dtype)
    out[:, :h_u] = tile_arr
    return out


def _group_requests(requests: Sequence[Request]):
    """Rows → one (N, d) int32 block + segment id per row; users in
    first-appearance order (their position IS their segment id — the
    returned ``seg_of`` is the one mapping baked into ``obs_seg``)."""
    users: list[str] = []
    seg_of: dict[str, int] = {}
    for user_id, _ in requests:
        if user_id not in seg_of:
            seg_of[user_id] = len(users)
            users.append(user_id)
    xb_parts, oseg_parts, row_slices = [], [], []
    off = 0
    for user_id, x in requests:
        x = np.ascontiguousarray(x, np.int32)
        xb_parts.append(x)
        oseg_parts.append(np.full(len(x), seg_of[user_id], np.int32))
        row_slices.append(slice(off, off + len(x)))
        off += len(x)
    xb = np.concatenate(xb_parts)
    obs_seg = np.concatenate(oseg_parts)
    return users, seg_of, xb, obs_seg, row_slices


def pack_request_batch(
    store: ForestStore,
    requests: Sequence[Request],
    block_trees: int = 32,
):
    """Group a mixed-user batch for the segmented kernel (the PR 2 host
    packing, kept for ``engine="simple"``).

    Returns ``(xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees)``
    where ``tree_pack`` is the ragged concatenation of every requested
    user's heap tiles (feature, threshold, fit, is_internal, tree_seg) at a
    common heap width, and ``seg_trees[s]`` is user s's tree count.

    Re-padding only happens for users whose heap width differs from the
    batch maximum (``_pad_heap_width`` is a no-op otherwise); the pipelined
    engines skip this host pass entirely — their padded tiles persist in
    the store's device arena and each batch is an index-gather
    (``ForestStore.arena_pack``)."""
    users, seg_of, xb, obs_seg, row_slices = _group_requests(requests)
    max_depth = max(store.max_depth(u) for u in users)
    h = (1 << (max_depth + 1)) - 1
    feats, thrs, fits, inters, tsegs = [], [], [], [], []
    for user_id in users:
        for feature, threshold, fit, is_internal in store.tiles(
            user_id, block_trees
        ):
            feats.append(_pad_heap_width(feature, h))
            thrs.append(_pad_heap_width(threshold, h))
            fits.append(_pad_heap_width(fit, h))
            inters.append(_pad_heap_width(is_internal, h))
            tsegs.append(
                np.full(feature.shape[0], seg_of[user_id], np.int32)
            )
    tree_pack = (
        np.concatenate(feats),
        np.concatenate(thrs),
        np.concatenate(fits),
        np.concatenate(inters),
        np.concatenate(tsegs),
    )
    seg_trees = np.array([store.n_trees(u) for u in users], np.int64)
    return xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees


def _finalize(
    store: ForestStore,
    requests: Sequence[Request],
    row_slices,
    total: np.ndarray,
    task: str,
) -> list[np.ndarray]:
    out: list[np.ndarray] = []
    for (user_id, _), sl in zip(requests, row_slices):
        if task == "classification":
            out.append(total[sl].argmax(-1).astype(np.float64))
        else:
            out.append(
                total[sl].astype(np.float64)
                / max(store.n_trees(user_id), 1)
            )
    return out


def _empty_preds(requests):
    return [np.zeros(len(x), np.float64) for _, x in requests]


def _serve_simple(
    store, requests, block_trees, block_obs, interpret
) -> list[np.ndarray]:
    """The PR 2 serving path, verbatim: host pack + one segmented-kernel
    launch per tree chunk over that chunk's row span."""
    from ..kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented,
    )

    xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees = (
        pack_request_batch(store, requests, block_trees)
    )
    feature, threshold, fit, is_internal, tree_seg = tree_pack
    task = store.shared.task
    n_classes = store.shared.n_classes if task == "classification" else 0
    n, c_out = len(xb), max(n_classes, 1)
    t = feature.shape[0]
    if n == 0:
        return _empty_preds(requests)

    # Segments only overlap block-diagonally: sort rows by segment and run
    # each tree chunk against just the row span of the users it contains —
    # work stays ~sum_u T_u * N_u instead of T_total * N_total, while one
    # launch still serves several users' trees (the segment mask sorts out
    # chunk-boundary users).  Spans are padded to block_obs multiples (rows)
    # and block_trees (trees) with non-matching sentinel segments, so the
    # jitted kernel sees a handful of distinct shapes, not one per span.
    order = np.argsort(obs_seg, kind="stable")
    xb_s = np.ascontiguousarray(xb[order])
    oseg_s = np.ascontiguousarray(obs_seg[order])
    n_segs = len(seg_trees)
    seg_start = np.searchsorted(oseg_s, np.arange(n_segs))
    seg_end = np.searchsorted(oseg_s, np.arange(n_segs), side="right")

    total_sorted = np.zeros(
        (n, c_out) if n_classes > 0 else (n,), np.float64
    )
    parts: list[tuple[int, int, object]] = []
    for lo in range(0, t, block_trees):
        hi = min(lo + block_trees, t)
        r0 = int(seg_start[int(tree_seg[lo])])
        r1 = int(seg_end[int(tree_seg[hi - 1])])
        if r1 <= r0:
            continue
        n_rows = r1 - r0
        n_pad = min(-(-n_rows // block_obs) * block_obs, n)
        r1p = min(r0 + n_pad, n)
        r0p = r1p - n_pad  # slide the window instead of materializing pads
        chunk = [tree_seg[lo:hi], feature[lo:hi], threshold[lo:hi],
                 fit[lo:hi], is_internal[lo:hi]]
        if hi - lo < block_trees:  # pad tail chunk to the common tree shape
            pad_t = block_trees - (hi - lo)
            chunk[0] = np.concatenate(
                [chunk[0], np.full(pad_t, -1, np.int32)]
            )
            for i in range(1, 5):
                chunk[i] = np.concatenate(
                    [chunk[i], np.zeros((pad_t,) + chunk[i].shape[1:],
                                        chunk[i].dtype)]
                )
        tseg_c, feat_c, thr_c, fit_c, inter_c = chunk
        part = forest_predict_agg_segmented(
            xb_s[r0p:r1p],
            oseg_s[r0p:r1p],
            tseg_c,
            feat_c,
            thr_c,
            fit_c,
            inter_c,
            max_depth=max_depth,
            n_classes=n_classes,
            block_trees=block_trees,
            block_obs=block_obs,
            interpret=interpret,
            engine="simple",
        )  # dispatched async; host keeps slicing/submitting
        parts.append((r0p, r1p, part))
    for r0p, r1p, part in parts:
        total_sorted[r0p:r1p] += np.asarray(part, np.float64)
    total = np.empty_like(total_sorted)
    total[order] = total_sorted
    return _finalize(store, requests, row_slices, total, task)


class PipelinedBatch(NamedTuple):
    """Output of ``pack_pipelined_batch``: everything the one-launch DMA
    kernel needs, plus the row bookkeeping to undo the segment sort."""

    xb_s: np.ndarray
    oseg_s: np.ndarray
    code: object  # (T_pad, H) device
    fit: object  # (T_pad, H) device
    tree_seg: np.ndarray
    chunk_lo: np.ndarray
    chunk_hi: np.ndarray
    max_depth: int
    block_trees: int
    block_obs: int
    order: np.ndarray
    row_slices: list


def pack_pipelined_batch(
    store, requests, block_trees: int = 8, block_obs: int = 128,
) -> PipelinedBatch | None:
    """Pipelined pack stage: group rows, arena index-gather, segment sort,
    chunk ranges.  Returns None for an all-empty batch.  (Public so the
    benchmark times the EXACT stage the engine runs.)"""
    from ..kernels.tree_predict.tree_predict import segment_chunk_ranges

    users, _seg_of, xb, obs_seg, row_slices = _group_requests(requests)
    n = len(xb)
    if n == 0:
        return None
    code, fit, tree_seg, counts, max_depth = store.arena_pack(
        users, block_trees
    )
    # rows sorted by segment id == arena gather order, so each row block's
    # needed chunk range is tight (block-diagonal work in one launch)
    order = np.argsort(obs_seg, kind="stable")
    xb_s = np.ascontiguousarray(xb[order])
    oseg_s = np.ascontiguousarray(obs_seg[order])
    block_obs = min(block_obs, n)
    chunk_lo, chunk_hi = segment_chunk_ranges(
        oseg_s, tree_seg, block_trees, block_obs
    )
    return PipelinedBatch(
        xb_s, oseg_s, code, fit, tree_seg, chunk_lo, chunk_hi, max_depth,
        block_trees, block_obs, order, row_slices,
    )


def run_pipelined_kernel(store, pb: PipelinedBatch, interpret=None):
    """Pipelined kernel stage: the single double-buffered DMA launch."""
    from ..kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented_packed,
    )

    task = store.shared.task
    n_classes = store.shared.n_classes if task == "classification" else 0
    return forest_predict_agg_segmented_packed(
        pb.xb_s, pb.oseg_s, pb.code, pb.fit, pb.tree_seg, pb.chunk_lo,
        pb.chunk_hi, pb.max_depth, store.arena.tb2, n_classes=n_classes,
        block_trees=pb.block_trees, block_obs=pb.block_obs,
        interpret=interpret,
    )


def finalize_pipelined_batch(
    store, requests, pb: PipelinedBatch, out
) -> list[np.ndarray]:
    """Pipelined finalize stage: unsort + per-request argmax/mean."""
    task = store.shared.task
    out = np.asarray(out, np.float64)
    total = np.empty_like(out)
    total[pb.order] = out
    return _finalize(store, requests, pb.row_slices, total, task)


def _serve_pipelined(
    store, requests, block_trees, block_obs, interpret
) -> list[np.ndarray]:
    """Arena index-gather + ONE double-buffered DMA kernel launch."""
    pb = pack_pipelined_batch(store, requests, block_trees, block_obs)
    if pb is None:
        return _empty_preds(requests)
    out = run_pipelined_kernel(store, pb, interpret)
    return finalize_pipelined_batch(store, requests, pb, out)


def _serve_sharded(
    store, requests, block_trees, block_obs, interpret
) -> list[np.ndarray]:
    """Tree axis sharded across devices: per-device pipelined partial
    aggregation + one all-reduce."""
    import jax
    import jax.numpy as jnp

    from ..kernels.tree_predict.ops import (
        forest_predict_agg_segmented_sharded,
        partition_segments_by_load,
    )
    from ..kernels.tree_predict.tree_predict import segment_chunk_ranges

    users, _seg_of, xb, obs_seg, row_slices = _group_requests(requests)
    task = store.shared.task
    n_classes = store.shared.n_classes if task == "classification" else 0
    n = len(xb)
    if n == 0:
        return _empty_preds(requests)

    n_dev = len(jax.devices())
    # admit the WHOLE batch before any per-shard gather: a later shard's
    # cold admission may grow the arena heap width, which would leave
    # earlier shards' gathered arrays at a stale (narrower) width
    store.arena_ensure(users, block_trees)
    seg_trees = np.array([store.n_trees(u) for u in users], np.int64)
    shards = partition_segments_by_load(seg_trees, n_dev)
    # per-shard users ascend by segment id: sorted rows keep ranges tight
    shards = [sorted(s) for s in shards]
    t_pad = max(
        max(
            (-(-int(seg_trees[s].sum()) // block_trees) * block_trees
             for s in map(np.asarray, shards) if len(s)),
            default=block_trees,
        ),
        block_trees,
    )
    block_obs = min(block_obs, n)
    order = np.argsort(obs_seg, kind="stable")
    xb_s = np.ascontiguousarray(xb[order])
    oseg_s = np.ascontiguousarray(obs_seg[order])

    codes, fits, tsegs, los, his = [], [], [], [], []
    max_depth = 0
    for shard in shards:
        shard_users = [users[s] for s in shard]
        code, fit, tseg, _, max_depth = store.arena_pack(
            shard_users, block_trees, pad_to=t_pad, seg_ids=shard
        )
        lo, hi = segment_chunk_ranges(
            oseg_s, tseg, block_trees, block_obs
        )
        codes.append(code)
        fits.append(fit)
        tsegs.append(tseg)
        los.append(lo)
        his.append(hi)
    out = forest_predict_agg_segmented_sharded(
        xb_s, oseg_s, jnp.stack(codes), jnp.stack(fits),
        np.stack(tsegs), np.stack(los), np.stack(his),
        max_depth, store.arena.tb2, n_classes=n_classes,
        block_trees=block_trees, block_obs=block_obs, interpret=interpret,
    )
    out = np.asarray(out, np.float64)
    total = np.empty_like(out)
    total[order] = out
    return _finalize(store, requests, row_slices, total, task)


def serve_store_batch(
    store: ForestStore,
    requests: Sequence[Request],
    block_trees: int | None = None,
    block_obs: int | None = None,
    interpret: bool | None = None,
    engine: str | None = None,
) -> list[np.ndarray]:
    """Serve a mixed-user request batch in one ragged pass.  Returns one
    prediction array per request (majority vote / ensemble mean), matching
    per-user ``predict_compressed`` (vote counts are integer-exact; the
    regression mean accumulates in float32 on device).

    ``engine=None`` picks ``"sharded"`` on multi-device hosts, else
    ``"pipelined"``, falling back to ``"simple"`` when the store schema is
    incompatible with the fused arena layout."""
    if not requests:
        return []
    if engine is None:
        if store.arena is None:
            engine = "simple"
        else:
            import jax

            engine = "sharded" if len(jax.devices()) > 1 else "pipelined"
    if engine not in _ENGINE_BLOCKS:
        raise ValueError(f"unknown serving engine {engine!r}")
    if engine != "simple" and store.arena is None:
        raise ValueError(
            f"engine={engine!r} needs the fused tile arena, which this "
            "store's schema cannot use (packed code word >= 2**24); use "
            "engine='simple'"
        )
    bt_default, bo_default = _ENGINE_BLOCKS[engine]
    block_trees = bt_default if block_trees is None else block_trees
    block_obs = bo_default if block_obs is None else block_obs
    serve = {
        "simple": _serve_simple,
        "pipelined": _serve_pipelined,
        "sharded": _serve_sharded,
    }[engine]
    return serve(store, requests, block_trees, block_obs, interpret)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=40)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rows", type=int, default=256,
                    help="rows per request")
    ap.add_argument("--task", choices=("classification", "regression"),
                    default="classification")
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--block-trees", type=int, default=None)
    ap.add_argument("--engine", default=None,
                    choices=("simple", "pipelined", "sharded"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..store import build_store, make_request_batch, make_synthetic_fleet

    fleet = make_synthetic_fleet(
        args.users, task=args.task, max_depth=args.depth, seed=args.seed
    )
    t0 = time.time()
    store = build_store(fleet)
    t_build = time.time() - t0
    rep = store.size_report()
    requests = make_request_batch(
        store, args.requests, args.rows, args.seed
    )
    serve_store_batch(store, requests[:2], block_trees=args.block_trees,
                      engine=args.engine)  # compile + warm cache
    t0 = time.time()
    preds = serve_store_batch(store, requests,
                              block_trees=args.block_trees,
                              engine=args.engine)
    t_serve = time.time() - t0
    n_rows = sum(len(x) for _, x in requests)

    mismatch = 0
    for (user_id, x), p in zip(requests[:8], preds[:8]):
        ref = store.predict(user_id, x)
        if args.task == "classification":
            mismatch += int((p != ref).sum())
        else:
            mismatch += int(np.max(np.abs(p - ref)) > 1e-4)
    cache_stats = store.cache.stats()
    cache_stats.pop("per_user", None)  # too chatty for the demo printout
    print(
        f"store: {rep['n_users']} users, "
        f"{rep['total_bytes']} bytes total "
        f"({rep['shared_codebook_bytes']} shared codebook), "
        f"built in {t_build:.1f}s\n"
        f"ragged batch [{args.engine or 'auto'}]: {len(requests)} requests "
        f"/ {len(set(u for u, _ in requests))} distinct users / "
        f"{n_rows} rows in {t_serve * 1e3:.1f} ms "
        f"({n_rows / t_serve:.0f} rows/s)\n"
        f"tile cache: {cache_stats}\n"
        f"tile arena: "
        f"{store.arena.stats() if store.arena is not None else None}\n"
        f"parity vs per-user predict_compressed (8 requests): "
        f"{mismatch} mismatches"
    )


if __name__ == "__main__":
    main()

"""Ragged multi-tenant serving from the compressed store (store piece 4).

A request batch mixes MANY users: each request is ``(user_id, x_binned)``
against that user's own forest.  Instead of one kernel launch per user,
the driver:

1. groups the batch — concatenates all rows into one (N, d) block with an
   int32 segment id per row, and all requested users' decoded heap tiles
   (from the store's tile LRU, so hot users skip entropy decode) into one
   ragged tree axis with an int32 segment id per tree;
2. streams tree tiles of ``block_trees`` through the segment-aware Pallas
   kernel ``forest_predict_agg_segmented`` — a (tree, obs) pair contributes
   only when segments match, so users of different forest sizes share one
   launch with zero per-user padding along the tree axis;
3. splits the aggregated (N, C) votes / (N,) sums back into per-request
   predictions (argmax / mean over that user's own tree count).

    PYTHONPATH=src python -m repro.launch.serve_store --users 40 \
        --requests 64 --rows 256
"""
from __future__ import annotations

import argparse
import time
from typing import Sequence

import numpy as np

from ..store.runtime import ForestStore

Request = tuple[str, np.ndarray]


def _pad_heap_width(tile_arr: np.ndarray, h: int) -> np.ndarray:
    t, h_u = tile_arr.shape
    if h_u == h:
        return tile_arr
    out = np.zeros((t, h), dtype=tile_arr.dtype)
    out[:, :h_u] = tile_arr
    return out


def pack_request_batch(
    store: ForestStore,
    requests: Sequence[Request],
    block_trees: int = 32,
):
    """Group a mixed-user batch for the segmented kernel.

    Returns ``(xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees)``
    where ``tree_pack`` is the ragged concatenation of every requested
    user's heap tiles (feature, threshold, fit, is_internal, tree_seg) at a
    common heap width, and ``seg_trees[s]`` is user s's tree count."""
    users: list[str] = []
    seg_of: dict[str, int] = {}
    for user_id, _ in requests:
        if user_id not in seg_of:
            seg_of[user_id] = len(users)
            users.append(user_id)

    xb_parts, oseg_parts, row_slices = [], [], []
    off = 0
    for user_id, x in requests:
        x = np.ascontiguousarray(x, np.int32)
        xb_parts.append(x)
        oseg_parts.append(np.full(len(x), seg_of[user_id], np.int32))
        row_slices.append(slice(off, off + len(x)))
        off += len(x)
    xb = np.concatenate(xb_parts)
    obs_seg = np.concatenate(oseg_parts)

    max_depth = max(store.max_depth(u) for u in users)
    h = (1 << (max_depth + 1)) - 1
    feats, thrs, fits, inters, tsegs = [], [], [], [], []
    for user_id in users:
        for feature, threshold, fit, is_internal in store.tiles(
            user_id, block_trees
        ):
            feats.append(_pad_heap_width(feature, h))
            thrs.append(_pad_heap_width(threshold, h))
            fits.append(_pad_heap_width(fit, h))
            inters.append(_pad_heap_width(is_internal, h))
            tsegs.append(
                np.full(feature.shape[0], seg_of[user_id], np.int32)
            )
    tree_pack = (
        np.concatenate(feats),
        np.concatenate(thrs),
        np.concatenate(fits),
        np.concatenate(inters),
        np.concatenate(tsegs),
    )
    seg_trees = np.array([store.n_trees(u) for u in users], np.int64)
    return xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees


def serve_store_batch(
    store: ForestStore,
    requests: Sequence[Request],
    block_trees: int = 32,
    block_obs: int = 256,
    interpret: bool | None = None,
) -> list[np.ndarray]:
    """Serve a mixed-user request batch in one ragged pass.  Returns one
    prediction array per request (majority vote / ensemble mean), matching
    per-user ``predict_compressed`` (vote counts are integer-exact; the
    regression mean accumulates in float32 on device)."""
    from ..kernels.tree_predict.tree_predict import forest_predict_agg_segmented

    if not requests:
        return []
    xb, obs_seg, row_slices, tree_pack, max_depth, seg_trees = (
        pack_request_batch(store, requests, block_trees)
    )
    feature, threshold, fit, is_internal, tree_seg = tree_pack
    task = store.shared.task
    n_classes = store.shared.n_classes if task == "classification" else 0
    n, c_out = len(xb), max(n_classes, 1)
    t = feature.shape[0]

    # Segments only overlap block-diagonally: sort rows by segment and run
    # each tree chunk against just the row span of the users it contains —
    # work stays ~sum_u T_u * N_u instead of T_total * N_total, while one
    # launch still serves several users' trees (the segment mask sorts out
    # chunk-boundary users).  Spans are padded to block_obs multiples (rows)
    # and block_trees (trees) with non-matching sentinel segments, so the
    # jitted kernel sees a handful of distinct shapes, not one per span.
    order = np.argsort(obs_seg, kind="stable")
    xb_s = np.ascontiguousarray(xb[order])
    oseg_s = np.ascontiguousarray(obs_seg[order])
    n_segs = len(seg_trees)
    seg_start = np.searchsorted(oseg_s, np.arange(n_segs))
    seg_end = np.searchsorted(oseg_s, np.arange(n_segs), side="right")

    total_sorted = np.zeros(
        (n, c_out) if n_classes > 0 else (n,), np.float64
    )
    parts: list[tuple[int, int, object]] = []
    for lo in range(0, t, block_trees):
        hi = min(lo + block_trees, t)
        r0 = int(seg_start[int(tree_seg[lo])])
        r1 = int(seg_end[int(tree_seg[hi - 1])])
        if r1 <= r0:
            continue
        n_rows = r1 - r0
        n_pad = min(-(-n_rows // block_obs) * block_obs, n)
        r1p = min(r0 + n_pad, n)
        r0p = r1p - n_pad  # slide the window instead of materializing pads
        chunk = [tree_seg[lo:hi], feature[lo:hi], threshold[lo:hi],
                 fit[lo:hi], is_internal[lo:hi]]
        if hi - lo < block_trees:  # pad tail chunk to the common tree shape
            pad_t = block_trees - (hi - lo)
            chunk[0] = np.concatenate(
                [chunk[0], np.full(pad_t, -1, np.int32)]
            )
            for i in range(1, 5):
                chunk[i] = np.concatenate(
                    [chunk[i], np.zeros((pad_t,) + chunk[i].shape[1:],
                                        chunk[i].dtype)]
                )
        tseg_c, feat_c, thr_c, fit_c, inter_c = chunk
        part = forest_predict_agg_segmented(
            xb_s[r0p:r1p],
            oseg_s[r0p:r1p],
            tseg_c,
            feat_c,
            thr_c,
            fit_c,
            inter_c,
            max_depth=max_depth,
            n_classes=n_classes,
            block_trees=block_trees,
            block_obs=block_obs,
            interpret=interpret,
        )  # dispatched async; host keeps slicing/submitting
        parts.append((r0p, r1p, part))
    for r0p, r1p, part in parts:
        total_sorted[r0p:r1p] += np.asarray(part, np.float64)
    total = np.empty_like(total_sorted)
    total[order] = total_sorted

    out: list[np.ndarray] = []
    for (user_id, _), sl in zip(requests, row_slices):
        if task == "classification":
            out.append(total[sl].argmax(-1).astype(np.float64))
        else:
            out.append(
                total[sl].astype(np.float64)
                / max(store.n_trees(user_id), 1)
            )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=40)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rows", type=int, default=256,
                    help="rows per request")
    ap.add_argument("--task", choices=("classification", "regression"),
                    default="classification")
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--block-trees", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..store import build_store, make_synthetic_fleet

    rng = np.random.default_rng(args.seed)
    fleet = make_synthetic_fleet(
        args.users, task=args.task, max_depth=args.depth, seed=args.seed
    )
    t0 = time.time()
    store = build_store(fleet)
    t_build = time.time() - t0
    rep = store.size_report()
    d = store.shared.n_features
    n_bins = int(store.shared.n_bins_per_feature[0])

    user_ids = store.user_ids
    requests = [
        (
            user_ids[int(rng.integers(len(user_ids)))],
            rng.integers(0, n_bins, (args.rows, d)).astype(np.int32),
        )
        for _ in range(args.requests)
    ]
    serve_store_batch(store, requests[:2],
                      block_trees=args.block_trees)  # compile + warm cache
    t0 = time.time()
    preds = serve_store_batch(store, requests,
                              block_trees=args.block_trees)
    t_serve = time.time() - t0
    n_rows = sum(len(x) for _, x in requests)

    mismatch = 0
    for (user_id, x), p in zip(requests[:8], preds[:8]):
        ref = store.predict(user_id, x)
        if args.task == "classification":
            mismatch += int((p != ref).sum())
        else:
            mismatch += int(np.max(np.abs(p - ref)) > 1e-4)
    print(
        f"store: {rep['n_users']} users, "
        f"{rep['total_bytes']} bytes total "
        f"({rep['shared_codebook_bytes']} shared codebook), "
        f"built in {t_build:.1f}s\n"
        f"ragged batch: {len(requests)} requests / "
        f"{len(set(u for u, _ in requests))} distinct users / "
        f"{n_rows} rows in {t_serve * 1e3:.1f} ms "
        f"({n_rows / t_serve:.0f} rows/s)\n"
        f"tile cache: {store.cache.stats()}\n"
        f"parity vs per-user predict_compressed (8 requests): "
        f"{mismatch} mismatches"
    )


if __name__ == "__main__":
    main()

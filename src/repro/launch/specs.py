"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these; nothing is ever allocated.

``input_specs(cfg, shape)`` returns (args, pspec tree) for the step that
the cell lowers:
  * train_*   -> train_step(params, opt_state, batch)
  * prefill_* -> prefill_step(params, tokens[, frontend_embeds])
  * decode_*  -> serve_step(params, tokens, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import init_cache
from . import shardings as sh


def _token_struct(b, s=None, dtype=jnp.int32):
    shape = (b,) if s is None else (b, s)
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    """Training batch pytree (host pipeline produces exactly this)."""
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _token_struct(b, s),
        "labels": _token_struct(b, s),
    }
    if cfg.frontend is not None and cfg.n_frontend_tokens:
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return batch


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    b = shape.global_batch
    specs = {
        "tokens": sh.batch_spec(mesh, b, 2),
        "labels": sh.batch_spec(mesh, b, 2),
    }
    if cfg.frontend is not None and cfg.n_frontend_tokens:
        specs["frontend_embeds"] = sh.batch_spec(mesh, b, 3)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(abstract args tuple, matching PartitionSpec tuple) for the cell."""
    params, opt = sh.abstract_train_state(cfg)
    pspecs = sh.param_pspecs(cfg, mesh)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        args = (params, opt, batch_struct(cfg, shape))
        specs = (pspecs, sh.opt_pspecs(cfg, mesh, pspecs),
                 batch_pspecs(cfg, mesh, shape))
        return args, specs

    if shape.kind == "prefill":
        args = [params, _token_struct(b, s)]
        specs = [pspecs, sh.batch_spec(mesh, b, 2)]
        if cfg.frontend is not None and cfg.n_frontend_tokens:
            args.append(jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            ))
            specs.append(sh.batch_spec(mesh, b, 3))
        return tuple(args), tuple(specs)

    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    cache_specs = sh.cache_pspecs(cfg, mesh, b, s)
    args = (params, _token_struct(b), cache)
    specs = (pspecs, sh.batch_spec(mesh, b, 1), cache_specs)
    return args, specs

"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/run1 \
        [--ckpt-codec lossless] [--grad-bits 4] [--resume]

On this CPU container you train the reduced (``--smoke``) configs; the
same driver drives the full configs on a real pod (the dry-run proves
they lower/compile on the production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointConfig, CheckpointManager
from ..configs.registry import ARCHITECTURES, get_config
from ..data.tokens import Prefetcher, TokenDataConfig
from ..models import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from ..optim.compression import GradCompressionConfig, init_error_feedback
from ..runtime import StragglerMonitor, TrainLoop
from .steps import make_train_step


def build_state(cfg, opt_cfg, seed: int, grad_comp=None):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    if grad_comp is not None and grad_comp.enabled:
        opt["ef"] = init_error_feedback(params)
    return {"params": params, "opt": opt}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-codec", default=None,
                    choices=[None, "lossless", "q8", "q10", "q12"])
    ap.add_argument("--grad-bits", type=int, default=0,
                    help=">0 enables §7 dithered gradient compression")
    ap.add_argument("--remat", default=None, choices=[None, "full", "dots"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps)
    grad_comp = (
        GradCompressionConfig(bits=args.grad_bits)
        if args.grad_bits else None
    )
    data_cfg = TokenDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    )
    step_fn_raw = jax.jit(
        make_train_step(cfg, opt_cfg, remat=args.remat, grad_comp=grad_comp),
        donate_argnums=(0, 1),
    )

    prefetch = Prefetcher(data_cfg, start_step=0)
    straggler = StragglerMonitor()

    def step_fn(state, step):
        t0 = time.time()
        got_step, batch = prefetch.get()
        fetch_s = time.time() - t0
        if straggler.should_skip(step, host=0, seconds=fetch_s):
            return state, {"skipped": 1.0}
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn_raw(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, {
            k: float(v) for k, v in metrics.items()
        }

    state = build_state(cfg, opt_cfg, args.seed, grad_comp)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}", flush=True)

    if args.ckpt_dir:
        mgr = CheckpointManager(
            CheckpointConfig(args.ckpt_dir, codec=args.ckpt_codec)
        )
        loop = TrainLoop(step_fn, mgr, save_every=args.ckpt_every)
        state = loop.run(state, args.steps)
        log = loop.metrics_log
    else:
        log = []
        for step in range(args.steps):
            state, m = step_fn(state, step)
            log.append(dict(m, step=step))
    for m in log:
        if m["step"] % args.log_every == 0 and "loss" in m:
            print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
                  f"lr {m.get('lr', 0):.2e}", flush=True)
    losses = [m["loss"] for m in log if "loss" in m]
    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})",
              flush=True)
    prefetch.close()


if __name__ == "__main__":
    main()

"""Parameter / optimizer / batch / cache PartitionSpecs for the production
mesh, for every assigned architecture.

Strategy (see DESIGN.md §6):
  * TP over ``model``: attention head dims, FF hidden, vocab, expert dim.
  * ZeRO-3 FSDP over ``data``: the d_model dim of every weight matrix.
  * ``pod`` joins ``data`` for batch parallelism (multi-pod default).
  * KV caches: batch over data; kv-head dim over model when divisible,
    else the TIME dim over model (ragged head sharding would pad memory).

Every rule checks divisibility and falls back to replication — e.g.
hymba's vocab 32001 and granite's 49155 don't split 16 ways, so their
embeddings stay replicated rather than unevenly padded.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models import init_cache, init_params
from ..optim.adamw import init_opt_state


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _div(mesh: Mesh, dim: int, axis):
    """axis if dim divides evenly over it, else None (replicate)."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 else None


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_spec(mesh: Mesh, global_batch: int, rank: int) -> P:
    ba = _div(mesh, global_batch, batch_axes(mesh))
    if ba is None and global_batch % mesh.shape["data"] == 0:
        ba = "data"
    return P(ba, *([None] * (rank - 1)))


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
# name -> logical spec for the UNSTACKED leaf; "D" = d_model dim (FSDP over
# data), "M" = tensor-parallel dim (over model), "E" = expert dim.
_PARAM_RULES: dict[str, tuple[str | None, ...]] = {
    "embed": ("M", "D"),
    "lm_head": ("D", "M"),
    # attention (gqa)
    "wq": ("D", "M"), "wk": ("D", "M"), "wv": ("D", "M"), "wo": ("M", "D"),
    # dense mlp / shared expert
    "w1": ("D", "M"), "w3": ("D", "M"), "w2": ("M", "D"),
    # mla
    "w_dq": ("D", None), "w_uq": (None, "M"), "w_dkv": ("D", None),
    "w_kr": ("D", None), "w_uk": (None, "M"), "w_uv": (None, "M"),
    # rwkv6
    "w_r": ("D", "M"), "w_k": ("D", "M"), "w_v": ("D", "M"),
    "w_g": ("D", "M"), "w_o": ("M", "D"),
    "w_lora_a": ("D", None), "w_lora_b": (None, None),
    "u": (None, None), "mu": (None, None),
    # ssm (hymba)
    "w_in": ("D", "M"), "conv_w": (None, "M"), "w_dt": ("M", None),
    "w_b": ("M", None), "w_c": ("M", None), "a_log": ("M", None),
    "w_out": ("M", "D"),
    # mtp
    "proj": ("D", None),
}

# MoE expert tensors are matched by (name, rank) to avoid clashing with the
# dense-mlp names above.
_MOE_RULES: dict[str, tuple[str | None, ...]] = {
    "w1": ("E", "D", None),
    "w3": ("E", "D", None),
    "w2": ("E", None, "D"),
    "router": (None, None),
}


def _logical_to_mesh(mesh: Mesh, logical: tuple[str | None, ...],
                     shape: tuple[int, ...]) -> P:
    out = []
    for name, dim in zip(logical, shape):
        if name == "D":
            out.append(_div(mesh, dim, "data"))
        elif name in ("M", "E"):
            out.append(_div(mesh, dim, "model"))
        else:
            out.append(None)
    return P(*out)


def _param_leaf_spec(mesh: Mesh, path, leaf) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    stacked = any(k in ("layers", "layers_dense") for k in keys)
    shape = tuple(leaf.shape)
    base_shape = shape[1:] if stacked else shape
    rule: tuple[str | None, ...] | None = None
    if "mlp" in keys and "shared" not in keys and name in _MOE_RULES:
        if len(base_shape) == len(_MOE_RULES[name]):
            rule = _MOE_RULES[name]
    if rule is None:
        rule = _PARAM_RULES.get(name)
    if rule is None or len(rule) != len(base_shape):
        rule = (None,) * len(base_shape)
    spec = _logical_to_mesh(mesh, rule, base_shape)
    if stacked:
        spec = P(None, *spec)
    return spec


def param_pspecs(cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree matching init_params(cfg)."""
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_leaf_spec(mesh, path, leaf), shapes
    )


def opt_pspecs(cfg: ModelConfig, mesh: Mesh, pspecs=None):
    """Optimizer state mirrors the parameter sharding; step is replicated."""
    pspecs = pspecs if pspecs is not None else param_pspecs(cfg, mesh)
    return {"m": pspecs, "v": pspecs, "step": P()}


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def _cache_leaf_spec(mesh: Mesh, path, leaf, batch: int) -> P:
    keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = keys[-1]
    shape = tuple(leaf.shape)
    ba = _div(mesh, batch, batch_axes(mesh)) or _div(mesh, batch, "data")
    if name == "pos":
        return P(ba)
    # all other leaves are stacked (L, B, ...)
    body = shape[2:]
    if name in ("k", "v"):  # (L,B,T,KV,hd): prefer KV over model, else T
        t, kv = body[0], body[1]
        if kv % mesh.shape["model"] == 0:
            return P(None, ba, None, "model", None)
        return P(None, ba, _div(mesh, t, "model"), None, None)
    if name in ("c_kv", "k_rope"):  # (L,B,T,r): shard T
        return P(None, ba, _div(mesh, body[0], "model"), None)
    if name == "state":  # rwkv6 (L,B,H,hd,hd)
        return P(None, ba, _div(mesh, body[0], "model"), None, None)
    if name in ("x_prev_tm", "x_prev_cm"):  # (L,B,d)
        return P(None, ba, _div(mesh, body[0], "model"))
    if name == "h":  # ssm (L,B,di,st)
        return P(None, ba, _div(mesh, body[0], "model"), None)
    if name == "conv":  # (L,B,3,di)
        return P(None, ba, None, _div(mesh, body[1], "model"))
    return P(None, ba, *([None] * len(body)))


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int):
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(mesh, path, leaf, batch), shapes
    )


# ---------------------------------------------------------------------------
# convenience: NamedSharding trees + eval_shape structs for the dry-run
# ---------------------------------------------------------------------------
def to_named(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_train_state(cfg: ModelConfig):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: init_opt_state(params))
    return params, opt

"""HLO-derived roofline statistics, with while-loop trip-count attribution.

``compiled.cost_analysis()`` counts each while-loop body ONCE regardless of
trip count — useless for scan-over-layers models (an 80-layer model reports
~1 layer of FLOPs).  This module parses ``compiled.as_text()`` instead:

  * splits the module into named computations,
  * reads each while op's ``known_trip_count`` backend config,
  * propagates multipliers through the call graph
    (entry -> while bodies x trip, fusions/calls x 1),
  * per computation, accumulates
      - dot FLOPs: 2 * prod(result dims) * prod(contracted dims),
      - HBM bytes: operand + result bytes of each instruction AT THE
        FUSION BOUNDARY (fusion internals live in registers/VMEM and are
        excluded — their dots still count toward FLOPs),
      - collective bytes: result bytes of all-gather / all-reduce /
        reduce-scatter / all-to-all / collective-permute (skipping the
        ``-done`` halves of async pairs).

All numbers are per device: post-SPMD shapes in the HLO are shards.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "  %name = <shapes> opcode(operands...), attrs" ; shapes may be a tuple
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],]+(?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(",
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}"
)

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
    "ragged-all-to-all",
}
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}

# elementwise / reduction opcodes counted as 1 FLOP per output element
# (matches XLA's HloCostAnalysis convention closely enough to validate
# within ~15% against fully-unrolled cost_analysis()).
_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt",
    "power", "log", "log-plus-one", "negate", "abs", "cosine", "sine",
    "logistic", "atan2", "remainder", "floor", "ceil", "round-nearest-afz",
}
_REDUCE_OPS = {"reduce", "reduce-window"}


def _shape_dims(shape_str: str):
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype in _DTYPE_BYTES:
            dim_list = [int(d) for d in dims.split(",") if d]
            yield dtype, dim_list


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class _Instr:
    name: str
    shape: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # defined name -> shape str
    # (called_comp, trip multiplier) edges
    calls: list = field(default_factory=list)
    fusion_bodies: set = field(default_factory=set)


_LINE_START_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=|^ENTRY|^%|^\}|^HloModule"
)


def _logical_lines(txt: str):
    """Join wrapped instructions (the HLO printer breaks long tuples)."""
    buf: list[str] = []
    for line in txt.splitlines():
        if _LINE_START_RE.match(line):
            if buf:
                yield " ".join(buf)
            buf = [line]
        elif buf:
            buf.append(line.strip())
        else:
            buf = [line]
    if buf:
        yield " ".join(buf)


def _parse_computations(txt: str) -> tuple[dict[str, _Computation], str]:
    comps: dict[str, _Computation] = {}
    entry = None
    cur: _Computation | None = None
    for line in _logical_lines(txt):
        hdr = _COMP_HDR_RE.match(line)
        if hdr and "{" in line:
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameters: "  %p = f32[...] parameter(0)" matches; others skip
            continue
        name, shape, opcode = m.group(1), m.group(2), m.group(3)
        cur.shapes[name] = shape
        cur.instrs.append(_Instr(name, shape, opcode, line))
        if opcode == "while":
            trip = _TRIP_RE.search(line)
            n = int(trip.group(1)) if trip else 1
            for cm in _CALLED_RE.finditer(line):
                target = cm.group(1)
                if target:
                    cur.calls.append((target, n))
        else:
            for cm in _CALLED_RE.finditer(line):
                if cm.group(1):
                    cur.calls.append((cm.group(1), 1))
                    if opcode == "fusion":
                        cur.fusion_bodies.add(cm.group(1))
                elif cm.group(2):
                    for t in re.findall(r"%?([\w.\-]+)", cm.group(2)):
                        cur.calls.append((t, 1))
    if entry is None:
        entry = next(iter(comps))
    return comps, entry


def _multipliers(comps: dict[str, _Computation], entry: str):
    mult: dict[str, float] = {name: 0.0 for name in comps}
    no_bytes: set[str] = set()  # fusion/apply bodies: VMEM-internal

    def visit(name: str, m: float, inside_fusion: bool):
        if name not in comps:
            return
        mult[name] += m
        if inside_fusion:
            no_bytes.add(name)
        c = comps[name]
        for target, trip in c.calls:
            child_fusion = inside_fusion or target in c.fusion_bodies \
                or _is_small_apply(comps.get(target))
            visit(target, m * trip, child_fusion)

    visit(entry, 1.0, False)
    return mult, no_bytes


def _is_small_apply(comp: _Computation | None) -> bool:
    """reduce/scatter to_apply bodies — scalar lambdas, no HBM traffic."""
    return comp is not None and len(comp.instrs) <= 4


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(comp: _Computation, instr: _Instr) -> float:
    out_elems = 0
    for _, dims in _shape_dims(instr.shape):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    m = _CONTRACT_RE.search(instr.line)
    contract = 1
    if m:
        operands = re.findall(r"%([\w.\-]+)", instr.line.split("(", 1)[1])
        lhs_shape = comp.shapes.get(operands[0]) if operands else None
        if lhs_shape:
            dims_list = next(iter(_shape_dims(lhs_shape)), (None, []))[1]
            for di in m.group(1).split(","):
                if di and int(di) < len(dims_list):
                    contract *= dims_list[int(di)]
    return 2.0 * out_elems * contract


def _fusion_effective_bytes(
    comps: dict[str, _Computation], comp: _Computation, instr: _Instr
) -> tuple[int, int] | None:
    """(operand bytes, result bytes) for a fusion call, charging only what
    the body actually TOUCHES:

      * a body parameter whose only users are slice/dynamic-slice/gather
        is charged at the sliced size, not the full array (a scan body
        reading one chunk of a big stacked input does not stream the
        whole input from HBM every iteration);
      * if the body root is a dynamic-update-slice (in-place buffer
        update under XLA aliasing), the result is charged at the update
        size, not the full buffer.
    """
    m = re.search(r"calls=%?([\w.\-]+)", instr.line)
    if not m or m.group(1) not in comps:
        return None
    body = comps[m.group(1)]
    param_shape: dict[str, str] = {}
    users: dict[str, list[_Instr]] = {}
    for bi in body.instrs:
        if bi.opcode == "parameter":
            param_shape[bi.name] = bi.shape
        ops = re.findall(r"%([\w.\-]+)", bi.line.split("(", 1)[1])
        for op in ops:
            users.setdefault(op, []).append(bi)
    op_bytes = 0
    for pname, pshape in param_shape.items():
        us = users.get(pname, [])
        if us and all(
            u.opcode in ("dynamic-slice", "slice", "gather") for u in us
        ):
            op_bytes += sum(_shape_bytes(u.shape) for u in us)
        else:
            op_bytes += _shape_bytes(pshape)
    root = body.instrs[-1] if body.instrs else None
    if root is not None and root.opcode == "dynamic-update-slice":
        ops = re.findall(r"%([\w.\-]+)", root.line.split("(", 1)[1])
        upd = body.shapes.get(ops[1]) if len(ops) > 1 else None
        res_bytes = _shape_bytes(upd) if upd else _shape_bytes(instr.shape)
    elif root is not None and root.opcode == "scatter":
        # in-place under aliasing: traffic = the updates, not the buffer
        ops = re.findall(r"%([\w.\-]+)", root.line.split("(", 1)[1])
        upd = body.shapes.get(ops[2]) if len(ops) > 2 else None
        res_bytes = _shape_bytes(upd) if upd else _shape_bytes(instr.shape)
    else:
        res_bytes = _shape_bytes(instr.shape)
    return op_bytes, res_bytes


def _instr_bytes(
    comp: _Computation, instr: _Instr,
    comps: dict[str, _Computation] | None = None,
) -> int:
    if instr.opcode in _NO_TRAFFIC_OPS:
        return 0
    if instr.opcode == "fusion" and comps is not None:
        eff = _fusion_effective_bytes(comps, comp, instr)
        if eff is not None:
            return eff[0] + eff[1]
    if instr.opcode in ("dynamic-slice", "slice", "gather"):
        # reads only the slice, plus writes it
        return 2 * _shape_bytes(instr.shape)
    if instr.opcode == "dynamic-update-slice":
        operands = re.findall(r"%([\w.\-]+)", instr.line.split("(", 1)[1])
        upd = comp.shapes.get(operands[1]) if len(operands) > 1 else None
        if upd:
            return 2 * _shape_bytes(upd)
    if instr.opcode == "scatter":
        operands = re.findall(r"%([\w.\-]+)", instr.line.split("(", 1)[1])
        upd = comp.shapes.get(operands[2]) if len(operands) > 2 else None
        if upd:
            return 2 * _shape_bytes(upd)
    total = _shape_bytes(instr.shape)  # result
    operands = re.findall(r"%([\w.\-]+)", instr.line.split("(", 1)[1])
    for op in operands:
        s = comp.shapes.get(op)
        if s:
            total += _shape_bytes(s)
    return total


def hlo_stats(txt: str) -> dict:
    """Per-device {flops, hbm_bytes, collective_bytes, collectives{kind:
    {count, bytes}}} with loop trip counts applied."""
    comps, entry = _parse_computations(txt)
    mult, no_bytes = _multipliers(comps, entry)

    flops = 0.0
    hbm = 0.0
    coll: dict[str, dict[str, float]] = {}
    sites: list[tuple[float, str, str, float, str]] = []
    for name, comp in comps.items():
        m = mult[name]
        if m == 0:
            continue
        count_bytes = name not in no_bytes
        for instr in comp.instrs:
            if instr.opcode == "dot":
                flops += m * _dot_flops(comp, instr)
            elif instr.opcode in _ELEMENTWISE_FLOP_OPS:
                flops += m * _shape_elems(instr.shape)
            elif instr.opcode in _REDUCE_OPS:
                # ~1 flop per input element; use first operand's size
                operands = re.findall(
                    r"%([\w.\-]+)", instr.line.split("(", 1)[1]
                )
                if operands and operands[0] in comp.shapes:
                    flops += m * _shape_elems(comp.shapes[operands[0]])
            if not count_bytes:
                continue
            if instr.opcode in _COLLECTIVES:
                kind = instr.opcode.replace("-start", "")
                b = _shape_bytes(instr.shape)
                e = coll.setdefault(kind, {"count": 0, "bytes": 0.0})
                e["count"] += m
                e["bytes"] += m * b
                sites.append((m * b, kind, instr.shape, m, name))
                hbm += m * _instr_bytes(comp, instr, comps)
            elif instr.opcode.endswith("-done"):
                continue
            else:
                hbm += m * _instr_bytes(comp, instr, comps)

    sites.sort(reverse=True)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "collectives": coll,
        "top_collective_sites": [
            {"bytes": b, "kind": k, "shape": s[:120], "mult": m, "comp": c}
            for b, k, s, m, c in sites[:12]
        ],
    }


def collective_bytes(txt: str) -> dict[str, dict[str, float]]:
    return hlo_stats(txt)["collectives"]


def total_collective_bytes(txt: str) -> int:
    return int(hlo_stats(txt)["collective_bytes"])

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, with NO array allocation (ShapeDtypeStruct
inputs only), and record memory / FLOP / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --multi-pod

Results land in experiments/dryrun/<cell>.json; benchmarks/roofline.py
turns them into the EXPERIMENTS.md tables.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402 — must precede any jax import

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs.base import SHAPES, ShapeConfig
from ..configs.registry import ARCHITECTURES, get_config
from ..models.sharding import logical_sharding, multi_pod_rules, single_pod_rules
from ..optim.adamw import AdamWConfig
from . import specs as specs_mod
from .hlo_stats import hlo_stats
from .mesh import make_production_mesh
from .shardings import to_named
from .steps import make_decode_step, make_prefill_step, make_train_step

# TPU v5e hardware model for the roofline terms
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

DEFAULT_OUT = Path("experiments/dryrun")


def applicable(cfg, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False  # quadratic attention at 500k is exactly what we skip
    return True


def model_flops(cfg, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for the cell (6ND train / 2ND inference)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: Path,
    *,
    remat: str | None = "full",
    label: str | None = None,
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    name = label or cell_name(arch, shape_name, multi_pod)
    if not applicable(cfg, shape):
        return {"cell": name, "status": "skipped",
                "reason": "full attention at 500k context (see DESIGN.md)"}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = multi_pod_rules() if multi_pod else single_pod_rules()
    args, in_pspecs = specs_mod.input_specs(cfg, shape, mesh)

    from jax.sharding import PartitionSpec as P

    from .shardings import batch_spec

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(cfg, opt_cfg, remat=remat)
        donate = (0, 1)
        metrics_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
        out_pspecs = (in_pspecs[0], in_pspecs[1], metrics_specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        donate = ()
        out_pspecs = None
    else:
        step = make_decode_step(cfg)
        donate = (2,)
        out_pspecs = (batch_spec(mesh, shape.global_batch, 2), in_pspecs[2])

    jit_kwargs = dict(
        in_shardings=to_named(mesh, in_pspecs),
        donate_argnums=donate,
    )
    if out_pspecs is not None:
        jit_kwargs["out_shardings"] = _named_or_none(mesh, out_pspecs)

    with logical_sharding(mesh, rules):
        lowered = jax.jit(step, **jit_kwargs).lower(*args)
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    stats = hlo_stats(compiled.as_text())
    colls = stats["collectives"]
    n_dev = mesh.devices.size

    flops_dev = stats["flops"]
    bytes_dev = stats["hbm_bytes"]
    coll_dev = stats["collective_bytes"]
    mf = model_flops(cfg, shape)

    rec = {
        "cell": name,
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "multi_pod": multi_pod,
        "n_devices": int(n_dev),
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
        },
        "collectives": colls,
        "top_collective_sites": stats["top_collective_sites"],
        "cost_analysis_naive": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
        },
        "roofline_s": {
            "compute": flops_dev / PEAK_FLOPS,
            "memory": bytes_dev / HBM_BW,
            "collective": coll_dev / ICI_BW,
        },
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flop_fraction": (mf / n_dev) / flops_dev if flops_dev else 0.0,
        "remat": remat,
    }
    terms = rec["roofline_s"]
    rec["bottleneck"] = max(terms, key=terms.get)
    return rec


def _named_or_none(mesh, tree):
    """to_named, but passing None subtrees through (= auto sharding)."""
    import jax.tree_util as jtu
    from jax.sharding import NamedSharding, PartitionSpec as P

    def conv(x):
        if isinstance(x, P):
            return NamedSharding(mesh, x)
        return x

    return jtu.tree_map(conv, tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run each cell on the 16x16 AND 2x16x16 meshes")
    ap.add_argument("--all", action="store_true", help="every cell")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    remat = None if args.remat == "none" else args.remat
    args.out.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else sorted(ARCHITECTURES)
    shapes = [args.shape] if args.shape else sorted(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = cell_name(arch, shape, mp)
                path = args.out / f"{name}.json"
                try:
                    rec = run_cell(arch, shape, mp, args.out, remat=remat)
                except Exception as e:  # a failing cell is a bug; record it
                    rec = {"cell": name, "status": "failed",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                path.write_text(json.dumps(rec, indent=1))
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "failed"
                if st == "ok":
                    m = rec["memory"]["peak_bytes"] / 2**30
                    r = rec["roofline_s"]
                    print(
                        f"[ok]   {name:55s} {rec['compile_s']:7.1f}s "
                        f"peak {m:6.2f} GiB/dev  "
                        f"c={r['compute']:.3e} m={r['memory']:.3e} "
                        f"x={r['collective']:.3e}  -> {rec['bottleneck']}",
                        flush=True,
                    )
                else:
                    print(f"[{st[:4]}] {name:55s} "
                          f"{rec.get('reason', rec.get('error', ''))[:90]}",
                          flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.registry import ARCHITECTURES, get_config
from ..models import init_params
from .steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    k_param, k_prompt, k_sample = jax.random.split(key, 3)
    params = init_params(cfg, k_param)

    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(
        k_prompt, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    fe = None
    if cfg.frontend is not None and cfg.n_frontend_tokens:
        fe = jax.random.normal(
            k_prompt, (args.batch, cfg.n_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype),
        )

    prefill_step = jax.jit(
        make_prefill_step(cfg), static_argnames=(), donate_argnums=()
    )
    decode_step = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    from ..models import prefill as _prefill

    logits, cache = jax.jit(
        lambda p, t, f: _prefill(cfg, p, t, f, max_len=max_len)
    )(params, prompts, fe)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, -1)
    out = [tokens]
    t0 = time.time()
    for i in range(args.gen - 1):
        k_sample, k = jax.random.split(k_sample)
        logits, cache = decode_step(params, tokens, cache)
        if args.temperature > 0:
            tokens = jax.random.categorical(k, logits / args.temperature, -1)
        else:
            tokens = jnp.argmax(logits, -1)
        out.append(tokens)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out, 1)
    toks_s = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"arch={cfg.name} prefill({args.batch}x{args.prompt_len}) "
          f"{t_prefill:.2f}s; decode {args.gen - 1} steps "
          f"{t_decode:.2f}s = {toks_s:.1f} tok/s")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

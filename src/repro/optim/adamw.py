"""AdamW + cosine schedule + global-norm clipping, as pure pytree math.

No optax dependency — the optimizer is part of the substrate we own, and the
state layout (m, v as plain pytrees mirroring params) is what the
checkpoint manager and the elastic re-sharding path serialize.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new params, new state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m.astype(p.dtype if p.dtype == jnp.float32 else jnp.float32), v.astype(jnp.float32)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tree, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(tree, [o[1] for o in out]),
        "v": jax.tree.unflatten(tree, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Gradient compression for the data-parallel all-reduce — the paper's §7
quantizer applied to distributed training (beyond-paper extension, see
DESIGN.md §3).

b-bit uniform quantization with per-tensor (lo, step), optional dither, and
ERROR FEEDBACK: the quantization residual is carried into the next step's
gradient, so the scheme is unbiased in the long run and training converges
at full-precision quality (tested in test_substrate.py).

Wire format per tensor per step: int codes (b bits) + 2 fp32 scalars — an
8x reduction at b=4 on the all-reduce payload vs fp32 gradients.  The paper
supplies the distortion bound: uniform quantization error variance is
step^2/12 = (2^r / 2^b)^2 / 12 (§7), which the error-feedback loop turns
into a vanishing bias.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GradCompressionConfig:
    bits: int = 4
    dither: bool = False
    enabled: bool = True


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _quantize_leaf(g, bits: int):
    """Uniform b-bit quantization of one tensor; returns reconstruction.

    This is the jnp twin of kernels/quantize (which is the TPU Pallas
    path); the math must match §7: midpoint reconstruction, error <= step/2.
    """
    gf = g.astype(jnp.float32)
    lo = gf.min()
    hi = gf.max()
    n_levels = 1 << bits
    step = jnp.maximum((hi - lo) / n_levels, 1e-30)
    q = jnp.clip(jnp.floor((gf - lo) / step), 0, n_levels - 1)
    return lo + (q + 0.5) * step


def compress_gradients(cfg: GradCompressionConfig, grads, error_feedback):
    """Returns (decoded grads as the receiver would see them, new error
    feedback state).  In the jit'd train step this models the exact math of
    quantize -> all-reduce -> dequantize; the wire encoding itself is the
    Pallas quantize kernel + entropy coder at the transport layer."""
    if not cfg.enabled:
        return grads, error_feedback

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        recon = _quantize_leaf(corrected, cfg.bits)
        new_e = corrected - recon
        return recon.astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(tree, [o[0] for o in out]),
        jax.tree.unflatten(tree, [o[1] for o in out]),
    )


def wire_quantized_psum(
    grads, axis: str, bits: int = 4, key=None, n_ranks: int | None = None
):
    """§7's dithered quantizer applied to the data-parallel gradient sum
    AT THE WIRE LEVEL (used under shard_map manual over ``axis``).

    Per tensor: shared scale = pmax of local max-|g|; each rank quantizes
    its local gradient to ``bits``-bit signed codes with UNIFORM DITHER
    (unbiased — §7's dithered quantization), the integer codes are
    psum'd in the smallest carrier that cannot overflow (int8 for
    <= 16 ranks at 4 bits), and the sum is dequantized.  Wire bytes drop
    2x vs bf16 gradients (4x vs f32); the dither keeps E[decoded] equal
    to the true mean gradient.
    """
    import numpy as np

    n = n_ranks if n_ranks is not None else jax.lax.axis_size(axis)
    qmax = (1 << (bits - 1)) - 1
    carrier = jnp.int8 if n * qmax <= 127 else jnp.int16

    leaves, tree = jax.tree.flatten(grads)
    keys = (
        jax.random.split(key, len(leaves)) if key is not None
        else [None] * len(leaves)
    )

    out = []
    for g, k in zip(leaves, keys):
        gf = g.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.abs(gf).max(), axis)
        scale = jnp.maximum(scale, 1e-30)
        dither = (
            jax.random.uniform(k, gf.shape, minval=-0.5, maxval=0.5)
            if k is not None else 0.0
        )
        codes = jnp.clip(
            jnp.round(gf / scale * qmax + dither), -qmax, qmax
        ).astype(carrier)
        total = jax.lax.psum(codes, axis)  # the only cross-rank traffic
        out.append((total.astype(jnp.float32) * scale / qmax / n).astype(g.dtype))
    return jax.tree.unflatten(tree, out)


def payload_bytes(params, bits: int) -> int:
    """All-reduce payload per step under compression (codes + scales)."""
    n = sum(p.size for p in jax.tree.leaves(params))
    n_tensors = len(jax.tree.leaves(params))
    return n * bits // 8 + n_tensors * 8

import numpy as np
import pytest

from repro.core.tree import Forest, ForestMeta, Tree


def random_tree(rng, d=5, max_depth=8, n_fit_syms=2, p_split=0.7, n_bins=16):
    feature, thresh, left, right, fit = [], [], [], [], []

    def build(depth):
        i = len(feature)
        feature.append(0)
        thresh.append(0)
        left.append(-1)
        right.append(-1)
        fit.append(int(rng.integers(n_fit_syms)))
        if depth < max_depth and rng.random() < p_split:
            feature[i] = int(rng.integers(d))
            thresh[i] = int(rng.integers(n_bins))
            left[i] = build(depth + 1)
            right[i] = build(depth + 1)
        else:
            feature[i] = -1
            thresh[i] = -1
        return i

    build(0)
    return Tree(
        np.array(feature),
        np.array(thresh),
        np.array(left),
        np.array(right),
        np.array(fit, dtype=np.int64),
    )


def random_forest(
    seed=0, n_trees=20, d=5, max_depth=8, task="classification",
    n_classes=2, n_bins=16, n_fit_values=40,
):
    rng = np.random.default_rng(seed)
    n_fit_syms = n_classes if task == "classification" else n_fit_values
    meta = ForestMeta(
        n_features=d,
        task=task,
        n_classes=n_classes,
        n_bins_per_feature=np.full(d, n_bins, np.int32),
        n_train_obs=1000,
    )
    trees = [
        random_tree(rng, d, max_depth, n_fit_syms, n_bins=n_bins)
        for _ in range(n_trees)
    ]
    fit_values = (
        rng.normal(size=n_fit_values)
        if task == "regression"
        else np.zeros(0)
    )
    return Forest(trees=trees, meta=meta, fit_values=fit_values)


@pytest.fixture
def rng():
    return np.random.default_rng(0)

"""Per-architecture smoke tests (reduced same-family configs): one forward
+ one decode step + train-step gradient, asserting shapes, finiteness, and
decode/forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn, mtp_loss

ARCH_NAMES = sorted(ARCHITECTURES)


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).smoke()
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(smoke_state, name):
    cfg, params = smoke_state(name)
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    fe = (
        jnp.ones((b, cfg.n_frontend_tokens, cfg.d_model))
        if cfg.frontend
        else None
    )
    logits, aux = forward(cfg, params, tokens, fe)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grad_finite(smoke_state, name):
    cfg, params = smoke_state(name)
    b, s = 2, 8
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, labels)
    )(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    # at least the embedding and head must receive gradient
    assert float(jnp.abs(grads["embed"]).sum()) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(smoke_state, name):
    """Token-by-token decode must reproduce the teacher-forced forward
    logits (the KV/state caches are exact, not approximations).

    MoE archs run in dropless mode (capacity_factor = E/k) here: with the
    default 1.25 factor, capacity-overflow dropping is order-dependent, so
    step-wise and full-sequence routing legitimately differ — that is a
    property of capacity-based MoE, not a cache bug."""
    import dataclasses

    cfg, params = smoke_state(name)
    if cfg.mlp_type == "moe":
        cfg = dataclasses.replace(
            cfg, capacity_factor=cfg.n_experts / cfg.top_k
        )
    b, s = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, params, tokens)
    cache = init_cache(cfg, b, max_len=s)
    got = []
    for t in range(s):
        lg, cache = decode_step(cfg, params, tokens[:, t], cache)
        got.append(lg)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-3,
        atol=2e-3,
    )


@pytest.mark.parametrize(
    "name", ["hymba-1.5b", "rwkv6-1.6b"]
)
def test_subquadratic_state_is_constant(smoke_state, name):
    """long_500k eligibility: cache size must not grow with max_len."""
    cfg, _ = smoke_state(name)
    small = init_cache(cfg, 1, max_len=64)
    big = init_cache(cfg, 1, max_len=4096)

    def nbytes(c):
        return sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(c))

    assert cfg.sub_quadratic
    if name == "rwkv6-1.6b":
        assert nbytes(small) == nbytes(big)
    else:  # hymba: sliding-window KV is capped at window size
        assert nbytes(big) <= nbytes(small) * (cfg.sliding_window / 64 + 1)


def test_full_attention_archs_are_not_subquadratic():
    for name in ARCH_NAMES:
        cfg = get_config(name)
        if cfg.attn_type == "gqa" and not cfg.sliding_window:
            assert not cfg.sub_quadratic


def test_mtp_head_deepseek_v3():
    cfg = get_config("deepseek-v3-671b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)
    l1 = jnp.roll(tokens, -1, 1)
    l2 = jnp.roll(tokens, -2, 1)
    loss = mtp_loss(cfg, params, tokens, l1, l2)
    assert np.isfinite(float(loss))


def test_param_counts_match_published():
    """Analytic parameter counts should be near the published sizes."""
    expect = {
        "deepseek-7b": 7.0e9,
        "qwen3-4b": 4.0e9,
        "starcoder2-3b": 3.0e9,
        "qwen2.5-3b": 3.1e9,
        "internvl2-76b": 76e9 * 0.9,  # backbone only (ViT frontend stubbed)
        "deepseek-v3-671b": 671e9,
        "rwkv6-1.6b": 1.6e9,
        "hymba-1.5b": 1.5e9,
        # musicgen-large's 3.3B is essentially all decoder backbone (48L,
        # d=2048); the EnCodec frontend is tiny and stubbed out here
        "musicgen-large": 3.3e9,
    }
    for name, target in expect.items():
        got = get_config(name).n_params()
        assert 0.5 * target < got < 1.7 * target, (name, got, target)


def test_moe_active_params_much_smaller():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.n_active_params() < 0.12 * cfg.n_params()


def test_sliding_window_masks_old_tokens():
    cfg = get_config("hymba-1.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = 1
    s = cfg.sliding_window + 8  # beyond the window
    tokens = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size)
    logits, _ = forward(cfg, params, tokens)
    assert np.isfinite(np.asarray(logits)).all()

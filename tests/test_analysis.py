"""Tests for the repro-lint static-analysis suite (tools/analysis).

Three layers:

* fixture tests — each pass must FIRE on a minimal broken snippet and
  stay SILENT on the fixed version of the same snippet (a linter that
  cannot fail guards nothing);
* registry tests — the frame-schema registry must stay in lockstep
  with docs/format.md's tag table and with the real writer/reader
  sources;
* whole-repo gate — the tree this test suite ships with must be clean,
  so the CI job's ``repro_lint --baseline`` run is reproducible here.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

from analysis import (  # noqa: E402
    determinism,
    frame_safety,
    kernel_invariants,
    lock_discipline,
)
from analysis.findings import Baseline, Finding  # noqa: E402
from analysis.frame_schema import (  # noqa: E402
    REGISTRY,
    ModuleIndex,
    documented_tags,
    extract_shape,
)
from analysis.repro_lint import main as lint_main  # noqa: E402


# ---------------------------------------------------------------------------
# fixture scaffolding
# ---------------------------------------------------------------------------

def _mini_repo(tmp_path: Path) -> Path:
    """A skeletal repo layout the passes can run against."""
    for sub in (
        "src/repro/core", "src/repro/store", "src/repro/sched",
        "src/repro/serving", "src/repro/runtime",
        "src/repro/kernels/tree_predict",
    ):
        (tmp_path / sub).mkdir(parents=True)
        (tmp_path / sub / "__init__.py").write_text("")
    return tmp_path


def _write(root: Path, rel: str, code: str) -> None:
    (root / rel).write_text(textwrap.dedent(code))


def _codes(findings: list[Finding]) -> set[str]:
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# frame-safety pass
# ---------------------------------------------------------------------------

class TestFrameSafety:
    def test_bare_unpack_on_read_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/x.py", """
            import struct

            def parse(inp):
                (n,) = struct.unpack("<I", inp.read(4))
                return n
        """)
        codes = _codes(frame_safety.run_pass(root))
        assert "FRAME001" in codes

    def test_clamped_read_is_clean(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/x.py", """
            from .framing import read_struct

            def parse(inp):
                (n,) = read_struct(inp, "<I", "count")
                return n
        """)
        findings = frame_safety.run_pass(root)
        assert "FRAME001" not in _codes(findings)

    def test_assert_on_read_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/x.py", """
            def parse(inp):
                assert inp.read(4) == b"RFX1"
        """)
        assert "FRAME002" in _codes(frame_safety.run_pass(root))

    def test_raw_wb_open_fires_outside_framing(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            def save(path, data):
                with open(path, "wb") as f:
                    f.write(data)
        """)
        assert "FRAME006" in _codes(frame_safety.run_pass(root))

    def test_framing_module_may_open_wb(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/framing.py", """
            def atomic_write_bytes(path, data):
                with open(path + ".tmp", "wb") as f:
                    f.write(data)
        """)
        assert "FRAME006" not in _codes(frame_safety.run_pass(root))

    def test_read_handles_with_length_checks_are_clean(self, tmp_path):
        # open() for READING with explicit length validation is the
        # sanctioned pattern (durable.py slab reads) — no finding.
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            def load(path, length):
                with open(path, "rb") as f:
                    data = f.read(length)
                if len(data) != length:
                    raise ValueError("short read")
                return data
        """)
        assert not frame_safety.run_pass(root)


class TestFrameRegistry:
    def test_registry_matches_docs_tag_table(self):
        docs = documented_tags(REPO / "docs" / "format.md")
        declared = {s.tag for s in REGISTRY if s.documented}
        assert declared == docs, (
            "frame registry and docs/format.md numbered sections "
            f"disagree: registry-only={declared - docs}, "
            f"docs-only={docs - declared}"
        )

    def test_legacy_rfc1_is_registered_but_undocumented(self):
        rfc = [s for s in REGISTRY if s.tag == "RFC1"]
        assert len(rfc) == 1 and not rfc[0].documented

    @pytest.mark.parametrize("spec", REGISTRY, ids=lambda s: s.tag)
    def test_writer_and_reader_match_declared_schema(self, spec):
        index = ModuleIndex.parse(REPO / spec.module)
        w = extract_shape(index, spec.writer)
        r = extract_shape(index, spec.reader)
        assert w.shape == spec.schema
        assert r.shape == spec.schema
        assert w.calls_with_crc and r.calls_check_crc
        assert r.has_magic

    def test_whole_repo_frame_pass_is_clean(self):
        assert frame_safety.run_pass(REPO) == []

    def test_desynced_writer_is_caught(self, tmp_path):
        """Drop one field from a real writer: FRAME004 must fire."""
        root = _mini_repo(tmp_path)
        # copy the real RFM1 module, minus the fits_map field write
        src = (REPO / "src/repro/store/lifecycle.py").read_text()
        broken = src.replace(
            "        write_arr(out, self.fits_map.astype(np.int32))\n", ""
        )
        assert broken != src, "expected the RFM1 fits_map write line"
        for spec in REGISTRY:
            (root / Path(spec.module).parent).mkdir(
                parents=True, exist_ok=True
            )
            text = (
                broken if spec.module.endswith("lifecycle.py")
                else (REPO / spec.module).read_text()
            )
            (root / spec.module).write_text(text)
        findings = frame_safety.run_pass(root)
        rfm = [f for f in findings if f.subject == "RFM1-writer-shape"]
        assert rfm and rfm[0].code == "FRAME004"

    def test_unsealed_writer_is_caught(self, tmp_path):
        root = _mini_repo(tmp_path)
        src = (REPO / "src/repro/store/lifecycle.py").read_text()
        broken = src.replace(
            "        write_arr(out, self.fits_map.astype(np.int32))\n"
            "        return with_crc(out.getvalue())",
            "        write_arr(out, self.fits_map.astype(np.int32))\n"
            "        return out.getvalue()",
        )
        assert broken != src
        for spec in REGISTRY:
            (root / Path(spec.module).parent).mkdir(
                parents=True, exist_ok=True
            )
            text = (
                broken if spec.module.endswith("lifecycle.py")
                else (REPO / spec.module).read_text()
            )
            (root / spec.module).write_text(text)
        subjects = {f.subject for f in frame_safety.run_pass(root)}
        assert "RFM1-unsealed" in subjects


# ---------------------------------------------------------------------------
# determinism pass
# ---------------------------------------------------------------------------

class TestDeterminism:
    def test_wall_clock_in_store_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            import time

            def stamp():
                return time.time()
        """)
        assert "DET001" in _codes(determinism.run_pass(root))

    def test_injected_timer_is_clean(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            import time

            def stamp(timer=time.perf_counter):
                return timer()
        """)
        assert not determinism.run_pass(root)

    def test_unseeded_rng_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/x.py", """
            import numpy as np

            def jitter(n):
                return np.random.default_rng().normal(size=n)
        """)
        assert "DET002" in _codes(determinism.run_pass(root))

    def test_seeded_rng_is_clean(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/core/x.py", """
            import numpy as np

            def jitter(n, seed):
                return np.random.default_rng(seed).normal(size=n)
        """)
        assert not determinism.run_pass(root)

    def test_unsorted_dict_iteration_in_emitter_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            from ..core.framing import write_u16

            def to_bytes(out, splits):
                for v, c in splits.items():
                    write_u16(out, v)
        """)
        assert "DET003" in _codes(determinism.run_pass(root))

    def test_sorted_dict_iteration_in_emitter_is_clean(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            from ..core.framing import write_u16

            def to_bytes(out, splits):
                for v, c in sorted(splits.items()):
                    write_u16(out, v)
        """)
        assert not determinism.run_pass(root)

    def test_unsorted_iteration_outside_emitter_is_clean(self, tmp_path):
        # non-serializing code may iterate dicts freely
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/store/x.py", """
            def total(counts):
                return sum(v for v in counts.values())
        """)
        assert not determinism.run_pass(root)

    def test_sched_wall_clock_fires_outside_clock_py(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/sched/x.py", """
            import time

            def tick():
                return time.monotonic()
        """)
        assert "DET004" in _codes(determinism.run_pass(root))

    def test_sched_clock_py_is_exempt(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/sched/clock.py", """
            import time

            class WallClock:
                def now(self):
                    return time.monotonic()
        """)
        assert not determinism.run_pass(root)

    def test_whole_repo_is_clean(self):
        assert determinism.run_pass(REPO) == []


# ---------------------------------------------------------------------------
# lock-discipline pass
# ---------------------------------------------------------------------------

_GUARDED_CLASS = """
    from ..runtime.guards import guarded_by

    @guarded_by("_lock", "_data", holds=("_refill",))
    class Cache:
        def __init__(self):
            self._data = {}

        def get(self, k):
            %s

        def pump(self):
            %s

        def _refill(self):
            self._data.clear()
"""


class TestLockDiscipline:
    def test_off_lock_access_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/serving/x.py", _GUARDED_CLASS % (
            "return self._data[k]",
            "with self._lock:\n                self._refill()",
        ))
        findings = lock_discipline.run_pass(root)
        assert [f.code for f in findings] == ["LOCK001"]
        assert findings[0].subject == "_data"
        assert findings[0].scope == "Cache.get"

    def test_locked_access_is_clean(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/serving/x.py", _GUARDED_CLASS % (
            "with self._lock:\n                return self._data[k]",
            "with self._lock:\n                self._refill()",
        ))
        assert not lock_discipline.run_pass(root)

    def test_holds_method_called_off_lock_fires(self, tmp_path):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/serving/x.py", _GUARDED_CLASS % (
            "with self._lock:\n                return self._data[k]",
            "self._refill()",
        ))
        findings = lock_discipline.run_pass(root)
        assert [f.code for f in findings] == ["LOCK002"]
        assert findings[0].subject == "_refill"

    def test_init_is_exempt(self, tmp_path):
        # __init__ writes guarded state before the object is shared
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/serving/x.py", _GUARDED_CLASS % (
            "with self._lock:\n                return self._data[k]",
            "with self._lock:\n                self._refill()",
        ))
        assert not lock_discipline.run_pass(root)

    def test_lambda_under_with_is_lexically_held(self, tmp_path):
        # Condition.wait_for(lambda: ...) under `with` must not fire —
        # the executor's backpressure pattern.
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/serving/x.py", """
            from ..runtime.guards import guarded_by

            @guarded_by("_idle", "_inflight")
            class Exec:
                def drain(self):
                    with self._idle:
                        self._idle.wait_for(lambda: self._inflight == 0)
        """)
        assert not lock_discipline.run_pass(root)

    def test_annotated_production_classes_are_clean(self):
        assert lock_discipline.run_pass(REPO) == []

    def test_guarded_by_decorator_records_contract(self):
        sys.path.insert(0, str(REPO / "src"))
        try:
            from repro.runtime.guards import guarded_by

            @guarded_by("_lock", "_a", "_b", holds=("_fill",))
            class C:
                pass

            assert C.__guarded_by__ == {"_a": "_lock", "_b": "_lock"}
            assert C.__guard_holds__ == {"_lock": ("_fill",)}
            with pytest.raises(ValueError):
                guarded_by("_lock")(C)
        finally:
            sys.path.remove(str(REPO / "src"))


# ---------------------------------------------------------------------------
# kernel-invariants pass
# ---------------------------------------------------------------------------

_KERNEL_OK = """
    import jax.experimental.pallas as pl
    from jax.experimental import pallas as pltpu

    _F32_EXACT_INT = 1 << 24

    def _validate_f32_exact(max_depth, d, **arrays):
        pass

    def _kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def _impl(x):
        return pl.pallas_call(
            _kernel,
            out_shape=None,
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        )(x)

    def forest_predict(x, feature, threshold, fit, is_internal,
                       max_depth, block=8):
        _validate_f32_exact(max_depth, x.shape[1], x=x)
        return _impl(x)
"""

_REF_OK = """
    def forest_predict_reference(x, feature, threshold, fit,
                                 is_internal, max_depth):
        return x
"""


class TestKernelInvariants:
    def _root(self, tmp_path, kernel_src, ref_src=_REF_OK):
        root = _mini_repo(tmp_path)
        twins = {
            k: v for k, v in kernel_invariants.KERNEL_TWINS.items()
            if k == "forest_predict"
        }
        _write(
            root, "src/repro/kernels/tree_predict/tree_predict.py",
            kernel_src,
        )
        _write(root, "src/repro/kernels/tree_predict/ref.py", ref_src)
        return root, twins

    def _run(self, root, twins, monkeypatch):
        monkeypatch.setattr(kernel_invariants, "KERNEL_TWINS", twins)
        return kernel_invariants.run_pass(root)

    def test_guarded_kernel_is_clean(self, tmp_path, monkeypatch):
        root, twins = self._root(tmp_path, _KERNEL_OK)
        assert not self._run(root, twins, monkeypatch)

    def test_missing_precision_guard_fires(self, tmp_path, monkeypatch):
        src = _KERNEL_OK.replace(
            "        _validate_f32_exact(max_depth, x.shape[1], x=x)\n",
            "",
        )
        root, twins = self._root(tmp_path, src)
        codes = _codes(self._run(root, twins, monkeypatch))
        assert "KERN001" in codes

    def test_implicit_specs_fire(self, tmp_path, monkeypatch):
        src = _KERNEL_OK.replace(
            "            in_specs=[pl.BlockSpec((8, 128), "
            "lambda i: (i, 0))],\n",
            "",
        )
        root, twins = self._root(tmp_path, src)
        codes = _codes(self._run(root, twins, monkeypatch))
        assert "KERN002" in codes

    def test_blockspec_without_layout_fires(self, tmp_path, monkeypatch):
        src = _KERNEL_OK.replace(
            "pl.BlockSpec((8, 128), lambda i: (i, 0))],",
            "pl.BlockSpec()],",
        )
        root, twins = self._root(tmp_path, src)
        codes = _codes(self._run(root, twins, monkeypatch))
        assert "KERN002" in codes

    def test_missing_reference_twin_fires(self, tmp_path, monkeypatch):
        root, twins = self._root(tmp_path, _KERNEL_OK, ref_src="")
        codes = _codes(self._run(root, twins, monkeypatch))
        assert "KERN003" in codes

    def test_twin_signature_drift_fires(self, tmp_path, monkeypatch):
        ref = _REF_OK.replace(
            "fit,\n                                 is_internal",
            "is_internal,\n                                 fit",
        )
        root, twins = self._root(tmp_path, _KERNEL_OK, ref_src=ref)
        codes = _codes(self._run(root, twins, monkeypatch))
        assert "KERN003" in codes

    def test_unregistered_public_kernel_fires(self, tmp_path, monkeypatch):
        src = textwrap.dedent(_KERNEL_OK) + textwrap.dedent("""
            def forest_predict_extra(x, max_depth):
                _validate_f32_exact(max_depth, x.shape[1], x=x)
                return _impl(x)
        """)
        root, twins = self._root(tmp_path, src)
        findings = self._run(root, twins, monkeypatch)
        assert any(
            f.code == "KERN003" and f.subject == "forest_predict_extra"
            for f in findings
        )

    def test_orphan_kernel_fires(self, tmp_path, monkeypatch):
        src = textwrap.dedent(_KERNEL_OK) + textwrap.dedent("""
            def _orphan_impl(x):
                return pl.pallas_call(
                    _kernel, out_shape=None, in_specs=[], out_specs=None,
                )(x)
        """)
        root, twins = self._root(tmp_path, src)
        findings = self._run(root, twins, monkeypatch)
        assert any(
            f.code == "KERN004" and f.subject == "_orphan_impl"
            for f in findings
        )

    def test_whole_repo_is_clean(self):
        assert kernel_invariants.run_pass(REPO) == []


# ---------------------------------------------------------------------------
# baseline + CLI
# ---------------------------------------------------------------------------

class TestBaselineAndCli:
    def test_fingerprint_is_line_stable(self):
        a = Finding("X001", "a.py", 10, "C.m", "attr", "msg")
        b = Finding("X001", "a.py", 99, "C.m", "attr", "other msg")
        assert a.fingerprint == b.fingerprint

    def test_baseline_filters_known_findings(self, tmp_path):
        f = Finding("X001", "a.py", 1, "f", "s", "msg")
        g = Finding("X002", "a.py", 2, "f", "s", "msg")
        bl = Baseline(path=tmp_path / "b.json")
        bl.accepted[f.fingerprint] = "known"
        bl.save()
        loaded = Baseline.load(tmp_path / "b.json")
        assert loaded.filter_new([f, g]) == [g]
        assert loaded.stale_entries([g]) == [f.fingerprint]

    def test_shipped_baseline_is_empty(self):
        bl = Baseline.load(
            REPO / "tools" / "analysis" / "baseline.json"
        )
        assert bl.accepted == {}, (
            "the shipped baseline must stay empty — fix findings "
            "instead of baselining them (see docs/analysis.md)"
        )

    def test_cli_clean_repo_exits_zero(self, capsys):
        assert lint_main(["--root", str(REPO)]) == 0
        assert "clean" in capsys.readouterr().out

    def test_cli_fails_on_findings_and_baseline_suppresses(
        self, tmp_path, capsys
    ):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/sched/x.py", """
            import time

            def tick():
                return time.monotonic()
        """)
        args = ["--root", str(root), "--passes", "determinism"]
        assert lint_main(args) == 1
        out = capsys.readouterr().out
        assert "DET004" in out
        # write a baseline accepting the finding, then it must pass
        bl_path = tmp_path / "baseline.json"
        assert lint_main(
            args + ["--baseline", str(bl_path), "--write-baseline"]
        ) == 0
        capsys.readouterr()
        assert lint_main(args + ["--baseline", str(bl_path)]) == 0
        assert "baselined" in capsys.readouterr().out

    def test_cli_json_format(self, tmp_path, capsys):
        root = _mini_repo(tmp_path)
        _write(root, "src/repro/sched/x.py", """
            import time

            def tick():
                return time.time()
        """)
        assert lint_main([
            "--root", str(root), "--passes", "determinism",
            "--format", "json",
        ]) == 1
        payload = json.loads(capsys.readouterr().out)
        assert payload["findings"][0]["code"] == "DET004"

    def test_cli_entrypoint_runs_as_script(self):
        proc = subprocess.run(
            [sys.executable,
             str(REPO / "tools" / "analysis" / "repro_lint.py"),
             "--baseline"],
            capture_output=True, text=True, cwd=str(REPO),
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

"""Durable self-healing shard store (ISSUE 8).

Covers the tentpole guarantees end to end:

* atomic writes + manifest-epoch recovery — a kill at EVERY write /
  compaction step reopens to a bit-exact fleet (pre- or post-commit,
  never torn);
* XOR parity — any single corrupt-or-missing shard in a slab group
  reconstructs bit-exact and heals on disk; double faults raise a typed
  ``UnrepairableError``, never a silent wrong forest;
* lazy residency — ``load_store`` touches only the manifest + codebooks
  until a user's delta is actually accessed;
* ``Scrubber`` incremental scanning + repair, and its scheduling by
  ``LifecycleDriver`` in low-load gaps;
* ``ForestServer.serve_safe`` quarantine -> parity-repair -> verify ->
  release, surfaced in ``stats()["health"]``;
* the shared ``atomic_write_bytes`` helper (the ``MigrationJournal``
  dir-fsync bugfix rides on it).
"""
import os
import shutil

import numpy as np
import pytest

from repro.core import framing
from repro.core.framing import (
    IntegrityError,
    UnrepairableError,
    atomic_write_bytes,
)
from repro.runtime.chaos import (
    CrashSchedule,
    DiskFaults,
    InjectedCrash,
    record_steps,
)
from repro.serving import ForestServer
from repro.store import MigrationJournal, build_store, make_synthetic_fleet
from repro.store.durable import (
    KIND_CODEBOOK,
    KIND_DELTA,
    DurableStore,
    Manifest,
    Scrubber,
    attach_auto_repair,
    xor_parity,
)


@pytest.fixture(scope="module")
def ref_store():
    fleet = make_synthetic_fleet(
        n_users=6, d=5, n_bins=12, seed=3, n_trees=(3, 5), max_depth=3
    )
    return build_store(fleet, seed=0)


@pytest.fixture(scope="module")
def ref_bytes(ref_store):
    return {u: ref_store.delta(u).to_bytes() for u in ref_store.user_ids}


@pytest.fixture()
def durable(tmp_path, ref_store):
    return DurableStore.create(str(tmp_path / "fleet"), ref_store)


def _assert_fleet_bit_exact(durable, ref_bytes, users=None):
    loaded = durable.load_store(lazy=False)
    expect = set(ref_bytes) if users is None else set(users)
    assert set(loaded.user_ids) == expect
    for u in loaded.user_ids:
        assert loaded.delta(u).to_bytes() == ref_bytes[u], u


# ---------------------------------------------------------------------------
# the shared atomic-write helper (+ journal bugfix)
# ---------------------------------------------------------------------------

class TestAtomicWrite:
    def test_write_and_overwrite(self, tmp_path):
        p = str(tmp_path / "x.bin")
        atomic_write_bytes(p, b"one")
        assert open(p, "rb").read() == b"one"
        atomic_write_bytes(p, b"two")
        assert open(p, "rb").read() == b"two"
        assert not os.path.exists(p + ".tmp")

    def test_journal_persist_uses_shared_helper(self, tmp_path, monkeypatch):
        """The ISSUE 8 bugfix: ``MigrationJournal._persist`` routes
        through the one dir-fsyncing helper instead of its old inline
        (fsync-less-rename) copy."""
        calls = []
        real = framing.atomic_write_bytes

        def spy(path, data):
            calls.append(path)
            real(path, data)

        import repro.store.lifecycle as lifecycle
        monkeypatch.setattr(lifecycle, "atomic_write_bytes", spy)
        path = str(tmp_path / "journal.rfj")
        j = MigrationJournal(path=path)
        j.log_migrate_intent("u0", b"delta-bytes")
        assert calls == [path]
        assert MigrationJournal.load(path).to_bytes() == j.to_bytes()


# ---------------------------------------------------------------------------
# RFN1 manifest frame
# ---------------------------------------------------------------------------

class TestManifest:
    def test_roundtrip(self, durable):
        man = durable.manifest
        again = Manifest.from_bytes(man.to_bytes())
        assert again == man

    def test_corruption_is_typed(self, durable):
        data = durable.manifest.to_bytes()
        bad = bytearray(data)
        bad[10] ^= 0xFF
        with pytest.raises(IntegrityError):
            Manifest.from_bytes(bytes(bad))

    def test_missing_trailer_is_typed(self, durable):
        """Manifests are born with CRC trailers — a missing one means the
        file lost its tail, not a legacy frame."""
        data = durable.manifest.to_bytes()
        with pytest.raises(IntegrityError, match="CRC"):
            Manifest.from_bytes(data[:-8])

    def test_xor_parity_recovers_any_single_payload(self):
        payloads = [b"abcdef", b"xy", b"0123456789", b""]
        parity = xor_parity(payloads)
        assert len(parity) == 10
        for i, victim in enumerate(payloads):
            acc = np.frombuffer(parity, np.uint8).copy()
            for j, p in enumerate(payloads):
                if j != i:
                    a = np.frombuffer(p, np.uint8)
                    acc[: len(a)] ^= a
            assert acc[: len(victim)].tobytes() == victim


# ---------------------------------------------------------------------------
# create / open / commit basics
# ---------------------------------------------------------------------------

class TestDurableBasics:
    def test_create_rejects_existing(self, tmp_path, ref_store, durable):
        with pytest.raises(ValueError, match="already"):
            DurableStore.create(durable.path, ref_store)

    def test_open_missing_dir_typed(self, tmp_path):
        with pytest.raises(IntegrityError):
            DurableStore.open(str(tmp_path / "nope"))

    def test_empty_store_roundtrip(self, tmp_path):
        d = DurableStore.create(str(tmp_path / "empty"))
        d2 = DurableStore.open(d.path)
        assert d2.manifest.epoch == 0
        with pytest.raises(IntegrityError, match="no live codebook"):
            d2.load_store()

    def test_eager_roundtrip_bit_exact(self, durable, ref_bytes):
        _assert_fleet_bit_exact(DurableStore.open(durable.path), ref_bytes)

    def test_lazy_roundtrip_bit_exact(self, durable, ref_store, ref_bytes):
        loaded = DurableStore.open(durable.path).load_store(lazy=True)
        assert loaded.generations == ref_store.generations
        assert set(loaded.user_ids) == set(ref_bytes)
        for u in sorted(ref_bytes):
            assert loaded.delta(u).to_bytes() == ref_bytes[u]

    def test_lazy_load_touches_only_codebooks(self, durable, ref_store):
        faults = DiskFaults()
        d = DurableStore.open(durable.path, read_fault=faults.on_read)
        loaded = d.load_store(lazy=True)
        n_cb = len(ref_store.generations)
        assert faults.reads == n_cb  # manifest + codebooks only
        assert loaded._deltas.n_loaded() == 0
        # generation scans stay out-of-core (placeholders carry the stamp)
        assert loaded.referenced_generations() == {ref_store.generation}
        assert faults.reads == n_cb
        # first real access loads exactly that user's shard
        u = ref_store.user_ids[0]
        loaded.delta(u)
        assert faults.reads == n_cb + 1
        assert loaded._deltas.n_loaded() == 1
        # second access is resident — no further disk reads
        loaded.delta(u)
        assert faults.reads == n_cb + 1

    def test_replace_and_remove(self, durable, ref_store, ref_bytes):
        users = ref_store.user_ids
        durable.put_delta("extra", ref_store.delta(users[0]))
        durable.remove_user(users[5])
        epoch = durable.commit()
        assert epoch == durable.manifest.epoch
        assert durable.stats()["dead_shards"] == 1
        want = dict(ref_bytes)
        del want[users[5]]
        want["extra"] = ref_bytes[users[0]]
        _assert_fleet_bit_exact(DurableStore.open(durable.path), want)

    def test_epoch_monotonic_and_open_picks_highest(self, durable,
                                                    ref_store):
        e0 = durable.manifest.epoch
        durable.put_delta("u_a", ref_store.delta(ref_store.user_ids[0]))
        e1 = durable.commit()
        durable.put_delta("u_b", ref_store.delta(ref_store.user_ids[1]))
        e2 = durable.commit()
        assert e0 < e1 < e2
        assert DurableStore.open(durable.path).manifest.epoch == e2

    def test_torn_manifest_rolls_back_to_previous_epoch(
        self, durable, ref_store, ref_bytes
    ):
        durable.put_delta("late", ref_store.delta(ref_store.user_ids[0]))
        e2 = durable.commit()
        # tear the newest manifest: recovery must fall back to the
        # previous epoch (kept on disk exactly for this) and roll the
        # torn commit back
        newest = os.path.join(durable.path, f"manifest-{e2:08d}.rfn")
        DiskFaults(seed=1).torn_write(newest, os.path.getsize(newest) // 2)
        d = DurableStore.open(durable.path)
        assert d.manifest.epoch == e2 - 1
        assert not os.path.exists(newest)  # rolled back = deleted
        _assert_fleet_bit_exact(d, ref_bytes)

    def test_garbage_manifest_file_rolled_back(self, durable, ref_bytes):
        e = durable.manifest.epoch
        garbage = os.path.join(durable.path, f"manifest-{e + 1:08d}.rfn")
        with open(garbage, "wb") as f:
            f.write(b"not a manifest")
        d = DurableStore.open(durable.path)
        assert d.manifest.epoch == e
        assert not os.path.exists(garbage)
        _assert_fleet_bit_exact(d, ref_bytes)

    def test_enospc_mid_commit_is_retryable(self, durable, ref_store,
                                            ref_bytes):
        e0 = durable.manifest.epoch
        faults = DiskFaults(enospc_after=1)
        durable.write_fault = faults.on_write
        durable.put_delta("late", ref_store.delta(ref_store.user_ids[0]))
        with pytest.raises(OSError):
            durable.commit()
        # manifest untouched: reopen sees the pre-commit fleet
        assert durable.manifest.epoch == e0
        _assert_fleet_bit_exact(DurableStore.open(durable.path), ref_bytes)
        # staging survived the failure; clearing the fault retries clean
        durable.write_fault = None
        durable.commit()
        want = dict(ref_bytes, late=ref_bytes[ref_store.user_ids[0]])
        _assert_fleet_bit_exact(DurableStore.open(durable.path), want)

    def test_sync_is_incremental(self, durable, ref_store):
        report = durable.sync(ref_store)
        assert report["codebooks"] == 0 and report["deltas"] == 0
        assert report["unchanged"] == len(ref_store.user_ids) + 1

    def test_gc_leaves_foreign_files_alone(self, durable, ref_store):
        foreign = os.path.join(durable.path, "journal.rfj")
        with open(foreign, "wb") as f:
            f.write(b"keep me")
        durable.put_delta("late", ref_store.delta(ref_store.user_ids[0]))
        durable.commit()
        durable.compact()
        assert open(foreign, "rb").read() == b"keep me"


# ---------------------------------------------------------------------------
# parity repair
# ---------------------------------------------------------------------------

class TestRepair:
    def _corrupt_user(self, durable, user_id, n=16):
        entry = durable.shard_for_user(user_id)
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, n))
        return entry

    def test_single_corruption_detected_then_repaired(
        self, durable, ref_store, ref_bytes
    ):
        u = ref_store.user_ids[0]
        entry = self._corrupt_user(durable, u)
        with pytest.raises(IntegrityError):
            durable.read_shard(entry.shard_id)
        assert durable.read_shard(entry.shard_id, repair=True) == ref_bytes[u]
        assert durable.n_repairs == 1
        # the slab file was healed on disk: plain reads pass again
        assert durable.read_shard(entry.shard_id) == ref_bytes[u]

    def test_truncated_slab_repairs_last_shard(self, durable, ref_store,
                                               ref_bytes):
        # tearing the slab's tail destroys (at least) the last shard —
        # a single-shard fault the parity reconstructs
        slab = durable.manifest.slabs[0]
        last = max(slab.shards, key=lambda e: e.offset)
        path, off, _ = durable.shard_location(last.shard_id)
        DiskFaults().torn_write(path, off + 1)
        data = durable.read_shard(last.shard_id, repair=True)
        _crc_ref = [e for e in slab.shards if e.shard_id == last.shard_id]
        assert len(data) == _crc_ref[0].length
        _assert_fleet_bit_exact(durable, ref_bytes)

    def test_double_fault_is_typed_unrepairable(self, durable, ref_store):
        u1, u2 = ref_store.user_ids[0], ref_store.user_ids[1]
        e1 = self._corrupt_user(durable, u1)
        self._corrupt_user(durable, u2)
        with pytest.raises(UnrepairableError):
            durable.read_shard(e1.shard_id, repair=True)
        # and the plain read stays a typed reject — never silent bytes
        with pytest.raises(IntegrityError):
            durable.read_shard(e1.shard_id)

    def test_missing_parity_plus_corrupt_shard_unrepairable(
        self, durable, ref_store
    ):
        entry = self._corrupt_user(durable, ref_store.user_ids[0])
        slab_id = durable.manifest.slabs[0].slab_id
        DiskFaults().missing(durable.parity_location(slab_id))
        with pytest.raises(UnrepairableError, match="parity"):
            durable.read_shard(entry.shard_id, repair=True)

    def test_missing_parity_alone_rebuilds(self, durable):
        slab_id = durable.manifest.slabs[0].slab_id
        DiskFaults().missing(durable.parity_location(slab_id))
        scrubber = Scrubber(durable)
        out = scrubber.scrub_all()
        assert out["parity_rebuilt"] == 1
        assert out["unrepairable"] == 0
        assert durable.n_parity_rebuilds == 1
        # rebuilt parity is bit-identical: a later shard fault repairs
        u = durable.delta_entries()[0]
        path, off, length = durable.shard_location(u.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 8))
        durable.read_shard(u.shard_id, repair=True)

    def test_missing_single_shard_slab_file_repairs(self, durable,
                                                    ref_store, ref_bytes):
        # a fresh commit of ONE shard makes a one-shard slab: losing the
        # whole slab file is still a single-shard fault
        u = ref_store.user_ids[0]
        durable.put_delta("solo", ref_store.delta(u))
        durable.commit()
        entry = durable.shard_for_user("solo")
        path, _, _ = durable.shard_location(entry.shard_id)
        DiskFaults().missing(path)
        assert durable.read_shard(entry.shard_id, repair=True) == ref_bytes[u]
        assert os.path.exists(path)  # healed on disk

    def test_missing_multi_shard_slab_file_unrepairable(self, durable,
                                                        ref_store):
        slab = durable.manifest.slabs[0]
        assert len(slab.shards) > 1
        DiskFaults().missing(
            os.path.join(durable.path, f"slab-{slab.slab_id:08d}.rfb")
        )
        with pytest.raises(UnrepairableError):
            durable.read_shard(slab.shards[0].shard_id, repair=True)

    def test_bit_rot_on_read_hook(self, durable, ref_store, ref_bytes):
        u = ref_store.user_ids[2]
        entry = durable.shard_for_user(u)
        faults = DiskFaults(seed=9, rot_shards=(entry.shard_id,))
        d = DurableStore.open(durable.path, read_fault=faults.on_read)
        with pytest.raises(IntegrityError):
            d.read_shard(entry.shard_id)
        # parity repair routes around the rotting reader bit-exactly
        assert d.read_shard(entry.shard_id, repair=True) == ref_bytes[u]
        assert faults.rotted


# ---------------------------------------------------------------------------
# scrubber
# ---------------------------------------------------------------------------

class TestScrubber:
    def test_incremental_ticks_cover_everything(self, durable):
        man = durable.manifest
        n_items = sum(len(s.shards) + 1 for s in man.slabs)
        scrubber = Scrubber(durable, shards_per_tick=3)
        total = 0
        while scrubber.passes == 0 or scrubber._cursor < len(scrubber._items):
            total += scrubber.tick()["scanned"]
            if total >= n_items:
                break
        stats = scrubber.stats()
        assert stats["shards_scanned"] + stats["parities_scanned"] >= n_items
        assert stats["bytes_scanned"] > 0
        assert stats["repairs"] == 0 and stats["unrepairable"] == []

    def test_scrub_repairs_and_reload_is_bit_exact(self, durable, ref_store,
                                                   ref_bytes):
        u = ref_store.user_ids[3]
        entry = durable.shard_for_user(u)
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 32))
        out = Scrubber(durable).scrub_all()
        assert out["repaired"] == 1
        assert out["unrepairable"] == 0
        _assert_fleet_bit_exact(durable, ref_bytes)

    def test_scrub_records_unrepairable(self, durable, ref_store):
        for u in ref_store.user_ids[:2]:
            entry = durable.shard_for_user(u)
            path, off, length = durable.shard_location(entry.shard_id)
            DiskFaults().corrupt_region(path, off, min(length, 8))
        scrubber = Scrubber(durable)
        out = scrubber.scrub_all()
        assert out["unrepairable"] == 2
        assert out["repaired"] == 0
        assert len(scrubber.stats()["unrepairable"]) == 2


# ---------------------------------------------------------------------------
# crash sweeps: kill at EVERY write/compaction step
# ---------------------------------------------------------------------------

class TestCrashSweep:
    def _sweep(self, base, snap, op, check):
        steps = record_steps(op)
        assert steps, "operation produced no steps"
        assert steps[-2:] == ["manifest", "gc"]
        for i, name in enumerate(steps):
            shutil.rmtree(base)
            shutil.copytree(snap, base)
            with pytest.raises(InjectedCrash):
                op(CrashSchedule(fail_at=(i,)))
            check(i, name)
        return steps

    def test_commit_crash_at_every_step(self, tmp_path, ref_store,
                                        ref_bytes):
        base = str(tmp_path / "fleet")
        users = ref_store.user_ids
        # small slabs so the commit spans multiple slab+parity steps
        DurableStore.create(base, ref_store, slab_shards=3)
        snap = str(tmp_path / "snap")
        shutil.copytree(base, snap)
        post = dict(ref_bytes)
        del post[users[5]]
        post["late"] = ref_bytes[users[0]]

        def op(on_step):
            d = DurableStore.open(base)
            d.put_delta("late", DurableStore.open(base).load_store()
                        .delta(users[0]))
            d.remove_user(users[5])
            d.commit(on_step=on_step)

        def check(i, name):
            d = DurableStore.open(base)
            # the manifest write is the commit point: any crash before
            # it recovers the PRE state, any after recovers POST
            want = ref_bytes if name != "gc" else post
            _assert_fleet_bit_exact(d, want)

        steps = self._sweep(base, snap, op, check)
        assert sum(s.startswith("slab:") for s in steps) >= 1

    def test_compact_crash_at_every_step(self, tmp_path, ref_store,
                                         ref_bytes):
        base = str(tmp_path / "fleet")
        users = ref_store.user_ids
        d0 = DurableStore.create(base, ref_store, slab_shards=3)
        # make garbage to compact: replace two users, drop one
        d0.put_delta(users[0], ref_store.delta(users[0]))
        d0.remove_user(users[5])
        d0.commit()
        assert d0.stats()["dead_bytes"] > 0
        snap = str(tmp_path / "snap")
        shutil.copytree(base, snap)
        live = dict(ref_bytes)
        del live[users[5]]

        def op(on_step):
            DurableStore.open(base).compact(on_step=on_step)

        def check(i, name):
            d = DurableStore.open(base)
            # compaction must NEVER change fleet content, whichever side
            # of the manifest swap the crash lands on
            _assert_fleet_bit_exact(d, live)
            # and re-running it converges to a garbage-free store
            d.compact()
            assert d.stats()["dead_bytes"] == 0
            _assert_fleet_bit_exact(d, live)

        self._sweep(base, snap, op, check)


# ---------------------------------------------------------------------------
# serving: quarantine -> repair -> verify -> release
# ---------------------------------------------------------------------------

def _requests_for(store, users, rows=4, seed=0):
    rng = np.random.default_rng(seed)
    d = store.shared.n_features
    n_bins = int(store.shared.n_bins_per_feature[0])
    return [
        (u, rng.integers(0, n_bins, (rows, d)).astype(np.int32))
        for u in users
    ]


class TestServeAutoRepair:
    def test_corrupt_user_repaired_and_served_exact(self, durable,
                                                    ref_store):
        victim = ref_store.user_ids[0]
        entry = durable.shard_for_user(victim)
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 32))

        server = ForestServer(durable.load_store(lazy=True))
        attach_auto_repair(server, durable)
        requests = _requests_for(ref_store, ref_store.user_ids, seed=5)
        statuses = server.serve_safe(requests, engine="simple")
        assert [s.status for s in statuses] == ["ok"] * len(requests)
        health = server.stats()["health"]
        assert health["repairs"] == 1
        assert health["repair_attempts"] >= 1
        assert health["n_quarantined"] == 0
        # zero silent wrongs: every prediction matches the clean fleet
        clean = ForestServer(ref_store)
        for s, (u, x) in zip(statuses, requests):
            np.testing.assert_array_equal(
                s.prediction, clean.serve([(u, x)], engine="simple")[0]
            )

    def test_unrepairable_user_stays_quarantined(self, durable, ref_store):
        u1, u2 = ref_store.user_ids[0], ref_store.user_ids[1]
        for u in (u1, u2):
            entry = durable.shard_for_user(u)
            path, off, length = durable.shard_location(entry.shard_id)
            DiskFaults().corrupt_region(path, off, min(length, 32))

        server = ForestServer(durable.load_store(lazy=True))
        attach_auto_repair(server, durable)
        requests = _requests_for(ref_store, ref_store.user_ids, seed=6)
        statuses = {s.user_id: s.status
                    for s in server.serve_safe(requests, engine="simple")}
        assert statuses[u1] == "quarantined"
        assert statuses[u2] == "quarantined"
        assert all(v == "ok" for k, v in statuses.items()
                   if k not in (u1, u2))
        health = server.stats()["health"]
        assert health["repairs"] == 0
        assert "UnrepairableError" in health["last_repair_error"]
        # failed repairs are remembered: the next batch does not
        # re-attempt them
        attempts = server.repair_attempts
        server.serve_safe(requests, engine="simple")
        assert server.repair_attempts == attempts

    def test_repairer_ignores_unknown_users(self, durable, ref_store):
        server = ForestServer(durable.load_store(lazy=True))
        repair = attach_auto_repair(server, durable)
        assert repair("no_such_user") is False


# ---------------------------------------------------------------------------
# lifecycle driver schedules scrubbing in low-load gaps
# ---------------------------------------------------------------------------

class TestDriverScrub:
    def _driver(self, durable, **kw):
        from repro.sched.driver import LifecycleDriver

        server = ForestServer(durable.load_store(lazy=True))
        scrubber = Scrubber(durable, shards_per_tick=4)
        driver = LifecycleDriver(
            server, clock=None, scrubber=scrubber,
            scrub_interval_s=2.0, low_load_rows=64, **kw
        )
        return driver, scrubber

    def test_scrub_ticks_in_low_load_gaps_only(self, durable):
        driver, _ = self._driver(durable)
        driver.tick(0.0, pending_rows=1000)   # loaded: no scrub
        assert driver.n_scrub_ticks == 0
        driver.tick(0.1, pending_rows=0)      # idle: scrub
        assert driver.n_scrub_ticks == 1
        driver.tick(0.5, pending_rows=0)      # inside the interval: no
        assert driver.n_scrub_ticks == 1
        driver.tick(2.5, pending_rows=0)      # interval elapsed: scrub
        assert driver.n_scrub_ticks == 2
        assert driver.stats()["scrub"]["bytes_scanned"] > 0

    def test_driver_scrub_repairs_corruption(self, durable, ref_store,
                                             ref_bytes):
        entry = durable.shard_for_user(ref_store.user_ids[4])
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 16))
        driver, scrubber = self._driver(durable)
        t = 0.0
        while scrubber.repairs == 0 and t < 100.0:
            driver.tick(t, pending_rows=0)
            t += 2.5
        assert scrubber.repairs == 1
        assert driver.n_scrub_failures == 0
        _assert_fleet_bit_exact(durable, ref_bytes)

    def test_scrubber_fault_counted_not_raised(self, durable, monkeypatch):
        driver, scrubber = self._driver(durable)
        monkeypatch.setattr(
            scrubber, "tick",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom")),
        )
        driver.tick(0.0, pending_rows=0)
        assert driver.n_scrub_failures == 1
        assert "boom" in driver.last_error


# ---------------------------------------------------------------------------
# durable <-> lifecycle interop
# ---------------------------------------------------------------------------

class TestLifecycleInterop:
    def test_sync_after_mutation_then_reload(self, durable, ref_store,
                                             ref_bytes):
        """A served store mutates in memory (re-registration); sync
        persists exactly the changed shards, and a fresh open/load is
        bit-exact vs the mutated store."""
        loaded = durable.load_store(lazy=True)
        u = ref_store.user_ids[0]
        # re-register one user (content identical here — force a byte
        # change by re-encoding another user's delta under their id)
        other = ref_store.delta(ref_store.user_ids[1])
        loaded.add_delta(u, other)
        report = durable.sync(loaded)
        assert report["deltas"] == 1
        assert report["removed"] == 0
        want = dict(ref_bytes)
        want[u] = ref_bytes[ref_store.user_ids[1]]
        _assert_fleet_bit_exact(DurableStore.open(durable.path), want)

    def test_kind_constants_stable(self):
        # wire-format constants (docs/format.md §10): frozen
        assert KIND_CODEBOOK == 0
        assert KIND_DELTA == 1

"""End-to-end system tests: model serving continuity across all families,
sharding-spec construction for the production mesh, launch-layer smoke."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHITECTURES, get_config
from repro.launch import specs as specs_mod
from repro.launch.hlo_stats import hlo_stats
from repro.launch.steps import make_decode_step, make_train_step
from repro.launch.train import build_state
from repro.models import decode_step, forward, init_params, prefill
from repro.optim.adamw import AdamWConfig

FAMILIES = ["qwen3-4b", "starcoder2-3b", "deepseek-v3-671b", "rwkv6-1.6b",
            "hymba-1.5b", "musicgen-large", "internvl2-76b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_matches_forward_and_decode_continues(arch):
    """prefill(prompt) == forward(prompt) last logits; decode_step continues
    exactly (MoE archs: capacity-dropping is batch-dependent, so only the
    prefill check is exact there)."""
    cfg = get_config(arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None and cfg.n_frontend_tokens:
        fe = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))
    full, _ = forward(cfg, params, toks, fe)
    lg, cache = prefill(cfg, params, toks, fe, max_len=s + 4)
    np.testing.assert_allclose(
        np.asarray(full[:, -1], np.float32), np.asarray(lg, np.float32),
        rtol=2e-2, atol=2e-2,
    )
    if cfg.mlp_type == "moe":
        return  # capacity dropping differs between (B*S) and (B*1) batches
    nxt = jax.random.randint(jax.random.PRNGKey(3), (b,), 0, cfg.vocab_size)
    lg2, _ = decode_step(cfg, params, nxt, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    full2, _ = forward(cfg, params, toks2, fe)
    np.testing.assert_allclose(
        np.asarray(full2[:, -1], np.float32), np.asarray(lg2, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_chunked_ce_matches_dense():
    from repro.models.layers import chunked_ce_loss, cross_entropy_loss

    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (2, 64, 16))
    head = jax.random.normal(jax.random.PRNGKey(1), (16, 40))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, 40)
    dense = cross_entropy_loss(jnp.einsum("bsd,dv->bsv", x, head), labels)
    chunked = chunked_ce_loss(x, head, labels, chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-5)
    # gradients agree too (the rematted scan path)
    g1 = jax.grad(lambda h: cross_entropy_loss(
        jnp.einsum("bsd,dv->bsv", x, h), labels))(head)
    g2 = jax.grad(lambda h: chunked_ce_loss(x, h, labels, chunk=16))(head)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_input_specs_cover_every_cell():
    """Every (arch x shape) cell builds abstract inputs + pspecs without
    touching devices."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    n = 0
    for arch in ARCHITECTURES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            args, specs = specs_mod.input_specs(cfg, shape, mesh)
            assert jax.tree.structure(
                jax.tree.map(lambda _: 0, args)
            ) == jax.tree.structure(jax.tree.map(lambda _: 0, specs))
            n += 1
    assert n == 32


def test_train_step_decreases_loss_smoke():
    cfg = get_config("deepseek-7b").smoke()
    cfg = dataclasses.replace(cfg, dtype="float32", n_layers=2)
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=25)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=None),
                   donate_argnums=(0, 1))
    state = build_state(cfg, opt_cfg, seed=0)
    from repro.data.tokens import TokenDataConfig, synth_batch

    data = TokenDataConfig(cfg.vocab_size, 32, 4, seed=0)
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in synth_batch(data, i).items()}
        p, o, m = step(state["params"], state["opt"], batch)
        state = {"params": p, "opt": o}
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_decode_step_jit_with_donation():
    cfg = get_config("hymba-1.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.models import init_cache

    cache = init_cache(cfg, 2, 32)
    dec = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    toks = jnp.array([1, 2], jnp.int32)
    for _ in range(3):
        logits, cache = dec(params, toks, cache)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_hlo_stats_trip_count_attribution():
    """The parser must recover ~L x the per-layer cost from a rolled scan
    (the naive cost_analysis famously reports ~1 layer)."""
    cfg = get_config("qwen2.5-3b").smoke()  # 2 layers
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((2, 64), jnp.int32)
    from repro.models import loss_fn

    c = jax.jit(lambda p, t: loss_fn(cfg, p, t, t)).lower(params, toks).compile()
    st = hlo_stats(c.as_text())
    naive = c.cost_analysis()["flops"]
    assert st["flops"] > 1.2 * naive  # recovered the second layer


def test_dryrun_cell_on_host_devices():
    """A full dry-run cell (lower+compile+stats) on a tiny mesh: the same
    code path the 512-device run uses."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_host_mesh
    from repro.launch.shardings import param_pspecs, to_named
    from repro.models.sharding import logical_sharding, single_pod_rules

    mesh = make_host_mesh(1, 1)
    cfg = get_config("qwen3-4b").smoke()
    opt_cfg = AdamWConfig()
    step = make_train_step(cfg, opt_cfg, remat="full")
    params, opt = specs_mod.sh.abstract_train_state(cfg)
    pspecs = param_pspecs(cfg, mesh)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
    }
    with logical_sharding(mesh, single_pod_rules()):
        lowered = jax.jit(
            step,
            in_shardings=(
                to_named(mesh, pspecs),
                to_named(mesh, {"m": pspecs, "v": pspecs, "step": P()}),
                to_named(mesh, {"tokens": P(), "labels": P()}),
            ),
        ).lower(params, opt, batch)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes > 0
    st = hlo_stats(compiled.as_text())
    assert st["flops"] > 0

"""Tests for the JAX random-forest substrate + full pipeline integration."""
import numpy as np
import pytest

from repro.core import (
    CompressedForest,
    compress_forest,
    decompress_forest,
    estimate_sigma2,
    predict_compressed,
)
from repro.data.tabular import TabularSpec, make_dataset
from repro.forest import (
    fit_binner,
    light_compress,
    light_report,
    per_tree_predictions,
    predict_forest,
    standard_compress,
    to_compact_forest,
    train_forest,
)


@pytest.fixture(scope="module")
def cls_setup():
    spec = TabularSpec("t", 800, 6, "classification", 2, 1)
    x, y, cat = make_dataset(spec, seed=1)
    binner = fit_binner(x, n_bins=16, categorical=cat)
    model = train_forest(
        x, y, binner, n_trees=12, max_depth=6, task="classification",
        n_classes=2, seed=0, chunk=12,
    )
    return x, y, binner, model


@pytest.fixture(scope="module")
def reg_setup():
    spec = TabularSpec("t", 600, 5, "regression")
    x, y, cat = make_dataset(spec, seed=2)
    binner = fit_binner(x, n_bins=16, categorical=cat)
    model = train_forest(
        x, y, binner, n_trees=10, max_depth=6, task="regression", seed=0,
        chunk=10,
    )
    return x, y, binner, model


class TestTraining:
    def test_classification_learns(self, cls_setup):
        x, y, _, model = cls_setup
        acc = (predict_forest(model, x) == y).mean()
        assert acc > 0.85  # in-sample fit of an unpruned forest

    def test_regression_learns(self, reg_setup):
        x, y, _, model = reg_setup
        pred = predict_forest(model, x)
        ss_res = ((pred - y) ** 2).sum()
        ss_tot = ((y - y.mean()) ** 2).sum()
        assert 1 - ss_res / ss_tot > 0.5

    def test_trees_are_diverse(self, cls_setup):
        """Bootstrap + mtry must decorrelate trees (the i.i.d. premise)."""
        x, _, _, model = cls_setup
        preds = per_tree_predictions(model, x[:100])
        disagreement = (preds != preds[0:1]).mean()
        assert disagreement > 0.01

    def test_no_nans(self, cls_setup):
        _, _, _, model = cls_setup
        assert np.isfinite(model.node_fit).all()


class TestCompactConversion:
    def test_preorder_and_prediction_equivalence(self, cls_setup):
        x, _, binner, model = cls_setup
        forest = to_compact_forest(model)
        xb = binner.transform(x[:128])
        heap_pred = predict_forest(model, x[:128])
        votes = np.zeros((128, 2), np.int64)
        for t in forest.trees:
            for i in range(128):
                votes[i, int(t.predict_one(xb[i]))] += 1
        assert np.array_equal(votes.argmax(1), heap_pred)

    def test_regression_fit_dictionary(self, reg_setup):
        _, _, _, model = reg_setup
        forest = to_compact_forest(model)
        assert len(forest.fit_values) > 0
        for t in forest.trees:
            assert t.node_fit.max() < len(forest.fit_values)


class TestFullPipeline:
    def test_trained_forest_roundtrip_and_prediction(self, cls_setup):
        x, _, binner, model = cls_setup
        forest = to_compact_forest(model)
        comp = compress_forest(forest)
        back = decompress_forest(CompressedForest.from_bytes(comp.to_bytes()))
        assert forest.equals(back)
        xb = binner.transform(x[:64])
        assert np.array_equal(
            predict_compressed(comp, xb), predict_forest(model, x[:64])
        )

    def test_beats_light_compression(self, cls_setup):
        """Paper's headline: our scheme < light < standard, on a trained
        classification forest."""
        _, _, _, model = cls_setup
        forest = to_compact_forest(model)
        ours = compress_forest(forest).size_report()["total_serialized"]
        light = len(light_compress(forest))
        standard = len(standard_compress(forest))
        assert ours < light < standard

    def test_sigma2_estimator_positive(self, reg_setup):
        x, _, _, model = reg_setup
        preds = per_tree_predictions(model, x[:200])
        assert estimate_sigma2(preds) > 0


class TestBaselines:
    def test_light_report_buckets(self, cls_setup):
        _, _, _, model = cls_setup
        forest = to_compact_forest(model)
        rep = light_report(forest)
        assert rep["total"] == sum(
            rep[k] for k in ("structure", "var_names", "split_values", "fits")
        )

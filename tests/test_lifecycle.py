"""ISSUE 5: codebook lifecycle — drift monitor, versioned codebook
generations, online re-clustering, bit-exact delta migration, and
serving-session partial invalidation across a migration."""
import numpy as np
import pytest

from repro.core.tree import Forest, ForestMeta, Tree
from repro.serving import ForestServer
from repro.store import (
    ForestStore,
    RemapTable,
    build_store,
    drift_report,
    make_drifted_fleet,
    make_synthetic_fleet,
    recluster,
)
from repro.store.lifecycle import (
    build_remap,
    migrate_user,
    migrate_users,
    relabel_delta,
    user_fallback_report,
)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def drifted_store(task="classification", n_users=10, late_fraction=0.3,
                  seed=0):
    """A store frozen on the initial population, with the late (drifted)
    users onboarded afterwards — the fallback-heavy state recluster
    repairs.  Returns (store, full fleet dict, late user ids)."""
    initial, late = make_drifted_fleet(
        n_users, late_fraction=late_fraction, task=task,
        n_trees=(4, 8), max_depth=4, seed=seed,
    )
    store = build_store(initial)
    for u, f in late.items():
        store.add_user(u, f)
    return store, {**initial, **late}, sorted(late)


def one_tree_on_feature(v: int, d: int = 8, n_bins: int = 16) -> Forest:
    """A forest whose single tree splits ONLY on feature ``v`` — every
    model emits symbols a codebook built without feature ``v`` cannot
    code, forcing the all-local fallback path."""
    tree = Tree(
        feature=np.array([v, -1, -1]),
        threshold=np.array([3, -1, -1]),
        children_left=np.array([1, -1, -1]),
        children_right=np.array([2, -1, -1]),
        node_fit=np.array([0, 1, 0], dtype=np.int64),
    )
    meta = ForestMeta(
        n_features=d, task="classification", n_classes=2,
        n_bins_per_feature=np.full(d, n_bins, np.int32),
        n_train_obs=1000, categorical=np.zeros(d, dtype=bool),
    )
    return Forest(trees=[tree], meta=meta)


class TestGenerationFraming:
    def test_codebook_and_delta_carry_generation(self):
        from repro.store import SharedCodebook, UserDelta

        fleet = make_synthetic_fleet(3, n_trees=(3, 5), max_depth=3)
        store = build_store(fleet)
        assert store.generation == 1
        cb = SharedCodebook.from_bytes(store.shared.to_bytes())
        assert cb.generation == 1
        delta = store.delta(store.user_ids[0])
        assert delta.codebook_generation == 1
        rt = UserDelta.from_bytes(delta.to_bytes())
        assert rt.codebook_generation == 1

    def test_hydrate_rejects_generation_mismatch(self):
        import dataclasses

        from repro.store.delta import hydrate

        fleet = make_synthetic_fleet(2, n_trees=(3, 5), max_depth=3)
        store = build_store(fleet)
        wrong = dataclasses.replace(store.shared, generation=7)
        with pytest.raises(ValueError, match="generation"):
            hydrate(store.delta(store.user_ids[0]), wrong)

    def test_rft1_roundtrips_retained_codebooks(self):
        """Mid-migration stores serialize BOTH generations and restore
        them (the old codebook must survive until its last delta
        migrates)."""
        store, fleet, late = drifted_store()
        res = recluster(store, migrate=False)
        migrate_users(store, late, res.remap)
        assert store.generations == [1, 2]
        clone = ForestStore.from_bytes(store.to_bytes())
        assert clone.generations == [1, 2]
        assert all(
            clone.reconstruct(u).equals(fleet[u]) for u in clone.user_ids
        )
        # finishing the migration on the clone drops generation 1
        migrate_users(
            clone, [u for u in clone.user_ids if u not in late], res.remap
        )
        assert clone.generations == [2]


class TestDriftMonitor:
    def test_clean_fleet_reports_no_drift(self):
        store = build_store(make_synthetic_fleet(4, n_trees=(3, 5),
                                                 max_depth=3))
        rep = drift_report(store)
        assert rep["fallback_user_fraction"] == 0.0
        assert rep["fallback_bytes"] == 0
        assert not rep["recommend_recluster"]

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_drifted_fleet_trips_the_monitor(self, task):
        store, _, late = drifted_store(task=task)
        rep = drift_report(store)
        assert rep["n_fallback_users"] == len(late)
        assert rep["fallback_user_fraction"] == pytest.approx(
            len(late) / rep["n_users"]
        )
        assert rep["fallback_bytes"] > 0
        assert 0 < rep["fallback_overhead_fraction"] < 1
        assert rep["recommend_recluster"]
        for u in late:
            assert rep["per_user"][u]["uses_fallback"]

    def test_server_stats_surface_drift(self, rng):
        store, _, _ = drifted_store()
        server = ForestServer(store)
        drift = server.stats()["store"]
        assert drift["codebook_generation"] == 1
        assert drift["fallback_user_fraction"] > 0
        # single-forest sessions have no fleet codebook to monitor
        from conftest import random_forest

        single = ForestServer.from_forest(random_forest(seed=1, n_trees=3))
        assert single.stats()["store"] is None


class TestRemapTable:
    def test_extend_remap_is_identity_and_roundtrips(self):
        store, _, _ = drifted_store()
        res = recluster(store, migrate=False)
        remap = res.remap
        assert remap.is_identity
        assert remap.old_generation == 1 and remap.new_generation == 2
        rt = RemapTable.from_bytes(remap.to_bytes())
        assert rt.old_generation == 1 and rt.new_generation == 2
        assert rt.fit_table_prefix == remap.fit_table_prefix
        assert np.array_equal(rt.vars_map, remap.vars_map)
        assert np.array_equal(rt.fits_map, remap.fits_map)
        assert set(rt.splits_map) == set(remap.splits_map)
        for v in remap.splits_map:
            assert np.array_equal(rt.splits_map[v], remap.splits_map[v])

    def test_build_remap_matches_identical_twins_only(self):
        store, _, _ = drifted_store()
        remap = build_remap(store.shared, store.shared)
        assert remap.is_identity  # a codebook is its own twin
        assert remap.fit_table_prefix


class TestRecluster:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_extend_is_bit_exact_and_shrinks_bytes(self, task):
        store, fleet, late = drifted_store(task=task, n_users=12)
        res = recluster(store, mode="extend")
        assert res.new_generation == 2 and store.generation == 2
        assert res.verified_bit_exact
        assert all(
            store.reconstruct(u).equals(fleet[u]) for u in store.user_ids
        )
        # fallback users re-encode, clean users relabel
        assert res.n_reencoded == len(late)
        assert res.n_relabeled == len(store.user_ids) - len(late)
        assert res.bytes_after <= res.bytes_before
        # the drift is repaired and the old generation dropped
        rep = drift_report(store)
        assert rep["fallback_user_fraction"] == 0.0
        assert store.generations == [2]

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_full_rebuild_is_bit_exact(self, task):
        store, fleet, _ = drifted_store(task=task)
        res = recluster(store, mode="full")
        assert store.generation == 2
        assert all(
            store.reconstruct(u).equals(fleet[u]) for u in store.user_ids
        )
        rep = drift_report(store)
        assert rep["fallback_user_fraction"] == 0.0
        # totals are NOT asserted for full mode: the rebuilt shared
        # codebook may outgrow a tiny fleet's per-user savings (the
        # 100-user tradeoff lives in benchmarks/recluster_bench.py)
        assert set(r["status"] for r in res.per_user.values()) <= {
            "relabeled", "reencoded"
        }

    def test_unknown_mode_rejected(self):
        store = build_store(make_synthetic_fleet(2, n_trees=(3, 4),
                                                 max_depth=3))
        with pytest.raises(ValueError, match="mode"):
            recluster(store, mode="nope")

    def test_empty_store(self):
        fleet = make_synthetic_fleet(1, n_trees=(3, 4), max_depth=3)
        store = build_store(fleet)
        # build an EMPTY store sharing the codebook
        empty = ForestStore(store.shared)
        for mode in ("extend", "full"):
            res = recluster(empty, mode=mode)
            assert res.n_users == 0
            assert res.n_relabeled == res.n_reencoded == 0
        assert empty.generation == 3

    def test_singleton_fleet(self):
        fleet = make_synthetic_fleet(1, n_trees=(3, 4), max_depth=3)
        store = build_store(fleet)
        for mode in ("extend", "full"):
            res = recluster(store, mode=mode)
            assert res.n_users == 1
        (u,) = store.user_ids
        assert store.reconstruct(u).equals(fleet[u])
        assert store.generations == [3]

    def test_late_user_with_only_local_clusters(self):
        """A user NO shared cluster can code at all (every model local)
        migrates onto shared clusters and drops its fallback bytes."""
        initial, _ = make_drifted_fleet(
            6, late_fraction=0.0, n_trees=(4, 8), max_depth=4,
        )
        store = build_store(initial)
        d = store.shared.n_features
        loner = one_tree_on_feature(d - 1, d=d)
        store.add_user("loner", loner)
        rep = user_fallback_report(store, "loner")
        assert rep["uses_fallback"] and rep["n_local_clusters"] > 0
        res = recluster(store, mode="extend")
        assert res.per_user["loner"]["status"] == "reencoded"
        assert store.reconstruct("loner").equals(loner)
        assert not user_fallback_report(store, "loner")["uses_fallback"]


class TestMigration:
    def test_incremental_migration_keeps_old_generation_alive(self):
        store, fleet, late = drifted_store()
        res = recluster(store, migrate=False)
        assert res.n_pending == len(store.user_ids)
        assert store.generations == [1, 2]
        # new onboarding lands on the NEW generation immediately
        extra = make_synthetic_fleet(1, n_trees=(3, 4), max_depth=3,
                                     seed=99)
        (uid, forest), = extra.items()
        store.add_user("fresh-" + uid, forest)
        assert store.delta("fresh-" + uid).codebook_generation == 2
        # migrate half: both generations stay resident
        migrate_users(store, late, res.remap)
        assert store.generations == [1, 2]
        # migrate the rest: generation 1 is garbage-collected
        rest = [
            u for u in store.user_ids
            if store.delta(u).codebook_generation == 1
        ]
        migrate_users(store, rest, res.remap)
        assert store.generations == [2]
        assert all(
            store.reconstruct(u).equals(fleet[u]) for u in fleet
        )

    def test_migrate_user_already_current(self):
        store, _, _ = drifted_store()
        res = recluster(store)
        rec = migrate_user(store, store.user_ids[0], res.remap)
        assert rec["status"] == "current"

    def test_relabel_preserves_bytes_and_decoded_artifact(self):
        """Relabeled deltas differ ONLY in the generation stamp: same
        size, identical reconstruction, tile cache untouched."""
        store, fleet, late = drifted_store()
        clean = [u for u in store.user_ids if u not in late]
        before = {u: len(store.delta(u).to_bytes()) for u in clean}
        ver_before = {u: store.user_version(u) for u in clean}
        store.tiles(clean[0], 8)  # warm one user's decoded tiles
        tiles_before = len(store.cache)
        res = recluster(store, mode="extend")
        for u in clean:
            assert res.per_user[u]["status"] == "relabeled"
            assert len(store.delta(u).to_bytes()) == before[u]
            # per-user serving version unchanged: caches stay valid
            assert store.user_version(u) == ver_before[u]
        assert len(store.cache) == tiles_before  # tiles survived

    def test_serving_mid_migration_mixes_generations(self, rng):
        store, fleet, late = drifted_store(n_users=8)
        server = ForestServer(store)
        res = recluster(store, migrate=False)
        migrate_users(store, late, res.remap)
        users = store.user_ids
        x = rng.integers(0, 12, (9, 8)).astype(np.int32)
        gens = {store.delta(u).codebook_generation for u in users}
        assert gens == {1, 2}
        mixed = [(u, x) for u in users[:2] + late[:2]]
        preds = server.serve(mixed)
        for (u, xx), p in zip(mixed, preds):
            assert np.array_equal(p, store.predict(u, xx))


class TestServingAcrossMigration:
    def test_warm_session_invalidates_only_migrated_users(self, rng):
        """THE acceptance property: a warm session crossing a migration
        keeps untouched (relabeled) users' cached packs and re-gathers
        only re-encoded users' packs."""
        store, fleet, late = drifted_store(n_users=10)
        server = ForestServer(store)
        clean = [u for u in store.user_ids if u not in late]
        x = rng.integers(0, 12, (9, 8)).astype(np.int32)
        reqs_clean = [(clean[0], x), (clean[1], x)]
        reqs_late = [(late[0], x), (late[1], x)]
        for _ in range(2):
            server.serve(reqs_clean)
            server.serve(reqs_late)
        hits0 = server.plan_cache.pack_hits
        misses0 = server.plan_cache.pack_misses

        res = recluster(store, mode="extend")
        assert res.n_reencoded == len(late)

        preds_clean = server.serve(reqs_clean)  # pack HIT: users relabeled
        preds_late = server.serve(reqs_late)  # pack MISS: users re-encoded
        assert server.plan_cache.pack_hits == hits0 + 1
        assert server.plan_cache.pack_misses == misses0 + 1
        assert_preds = lambda reqs, preds: [
            np.testing.assert_array_equal(p, store.predict(u, xx))
            for (u, xx), p in zip(reqs, preds)
        ]
        assert_preds(reqs_clean, preds_clean)
        assert_preds(reqs_late, preds_late)

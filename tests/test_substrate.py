"""Tests for the distributed substrate: tensor codec, checkpointing,
fault tolerance, gradient compression, data pipeline determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager, save_checkpoint, load_checkpoint, latest_step
from repro.configs.registry import get_config
from repro.core.tensor_codec import (
    CompressedTensors,
    compress_tensors,
    decompress_tensors,
    flatten_pytree,
    unflatten_pytree,
)
from repro.core.vechuff import VectorHuffman
from repro.core.huffman import code_lengths, entropy_bits
from repro.data.tokens import TokenDataConfig, synth_batch
from repro.launch.steps import make_train_step
from repro.launch.train import build_state
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import (
    GradCompressionConfig,
    compress_gradients,
    init_error_feedback,
)
from repro.runtime import Preemption, PreemptionSchedule, StragglerMonitor, TrainLoop


# ---------------------------------------------------------------------------
# vectorized Huffman
# ---------------------------------------------------------------------------
class TestVectorHuffman:
    def test_roundtrip_many_streams(self):
        rng = np.random.default_rng(0)
        freqs = np.bincount(rng.zipf(1.4, 20000) % 64, minlength=64)
        vh = VectorHuffman(code_lengths(freqs))
        p = freqs / freqs.sum()
        chunks = [
            rng.choice(64, size=rng.integers(1, 500), p=p) for _ in range(50)
        ]
        blobs, ns = [], []
        for c in chunks:
            b, _ = vh.encode(c)
            blobs.append(b)
            ns.append(len(c))
        out = vh.decode_streams(blobs, np.array(ns))
        for o, c in zip(out, chunks):
            assert (o == c).all()

    def test_rate_near_entropy(self):
        rng = np.random.default_rng(1)
        freqs = np.array([1000, 500, 250, 125, 60, 30, 20, 15])
        vh = VectorHuffman(code_lengths(freqs))
        syms = rng.choice(8, size=20000, p=freqs / freqs.sum())
        _, bits = vh.encode(syms)
        h = entropy_bits(np.bincount(syms, minlength=8))
        assert h <= bits <= h + len(syms)  # within 1 bit/symbol

    def test_single_symbol_alphabet(self):
        vh = VectorHuffman(code_lengths(np.array([0, 7, 0])))
        blob, _ = vh.encode(np.array([1, 1, 1, 1]))
        assert (vh.decode(blob, 4) == 1).all()


# ---------------------------------------------------------------------------
# tensor codec
# ---------------------------------------------------------------------------
class TestTensorCodec:
    def _tree(self, seed=0):
        rng = np.random.default_rng(seed)
        return {
            "layers": {
                "wq": rng.normal(scale=0.05, size=(4, 32, 32)).astype(np.float16),
                "wk": rng.normal(scale=0.02, size=(4, 32, 32)).astype(np.float16),
            },
            "embed": rng.normal(scale=0.02, size=(128, 32)).astype(np.float16),
            "step": np.array(9, np.int32),
        }

    def test_lossless_roundtrip_bit_exact(self):
        flat = flatten_pytree(self._tree())
        comp = compress_tensors(flat)
        back = decompress_tensors(comp)
        for k, v in flat.items():
            assert back[k].dtype == v.dtype
            assert (back[k] == v).all(), k

    def test_lossless_beats_raw(self):
        flat = flatten_pytree(self._tree())
        comp = compress_tensors(flat)
        raw = sum(v.nbytes for v in flat.values())
        assert comp.nbytes < raw

    def test_serialization(self):
        flat = flatten_pytree(self._tree())
        comp = CompressedTensors.from_bytes(
            compress_tensors(flat).to_bytes()
        )
        back = decompress_tensors(comp)
        assert all((back[k] == flat[k]).all() for k in flat)

    def test_partial_decode(self):
        flat = flatten_pytree(self._tree())
        comp = compress_tensors(flat)
        part = decompress_tensors(comp, names=["embed"])
        assert set(part) == {"embed"}
        assert (part["embed"] == flat["embed"]).all()

    @pytest.mark.parametrize("bits", [4, 8, 12])
    def test_quantized_distortion_bound(self, bits):
        flat = flatten_pytree(self._tree())
        comp = compress_tensors(flat, bits=bits)
        back = decompress_tensors(comp)
        for k, v in flat.items():
            if v.dtype.itemsize != 2:
                continue
            a = v.astype(np.float64)
            b = back[k].astype(np.float64)
            step = (a.max() - a.min()) / (1 << bits)
            ulp = float(np.spacing(np.float16(np.abs(b).max())))
            assert np.abs(a - b).max() <= step / 2 + 2 * ulp + 1e-12

    def test_flatten_unflatten(self):
        tree = self._tree()
        back = unflatten_pytree(flatten_pytree(tree))
        assert (back["layers"]["wq"] == tree["layers"]["wq"]).all()
        assert back["step"] == tree["step"]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _state(self, seed=0):
        rng = np.random.default_rng(seed)
        return {
            "params": {"w": rng.normal(size=(16, 8)).astype(np.float32)},
            "opt": {"m": rng.normal(size=(16, 8)).astype(np.float32),
                    "step": np.int32(3)},
        }

    def test_save_load_roundtrip(self, tmp_path):
        st = self._state()
        save_checkpoint(tmp_path, 5, st)
        back, step = load_checkpoint(tmp_path)
        assert step == 5
        assert (back["params"]["w"] == st["params"]["w"]).all()

    def test_uncommitted_is_invisible(self, tmp_path):
        save_checkpoint(tmp_path, 1, self._state())
        # fake a crashed save: step dir without COMMIT
        d = tmp_path / "step_00000009"
        d.mkdir()
        (d / "manifest.json").write_text("{}")
        assert latest_step(tmp_path) == 1

    def test_rolling_gc(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=2))
        for s in (1, 2, 3, 4):
            mgr.save(s, self._state(s))
        steps = sorted(
            int(p.name.split("_")[1]) for p in tmp_path.iterdir()
            if p.name.startswith("step_")
        )
        assert steps == [3, 4]

    def test_entropy_coded_checkpoint_bit_exact(self, tmp_path):
        rng = np.random.default_rng(0)
        st = {"w": rng.normal(scale=0.03, size=(64, 64)).astype(np.float16)}
        save_checkpoint(tmp_path, 2, st, codec="lossless")
        back, _ = load_checkpoint(tmp_path)
        assert back["w"].dtype == np.float16
        assert (back["w"] == st["w"]).all()

    @pytest.mark.skipif(
        not hasattr(jax.sharding, "AxisType"),
        reason="jax.sharding.AxisType needs a newer jax than this environment",
    )
    def test_elastic_reshard(self, tmp_path):
        """Load with explicit shardings onto the (1-device) mesh — the
        device_put path used for elastic re-scale."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        st = self._state()
        save_checkpoint(tmp_path, 1, st)
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), st
        )
        back, _ = load_checkpoint(tmp_path, shardings=sh)
        assert (np.asarray(back["params"]["w"]) == st["params"]["w"]).all()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
class TestFaultTolerance:
    def _loop(self, tmp_path, fail_at=(), save_every=4):
        def step_fn(state, step):
            # deterministic pure-numpy "training"
            rng = np.random.default_rng(step)
            g = rng.normal(size=state["w"].shape)
            return {"w": state["w"] - 0.1 * g}, {"gnorm": float(np.abs(g).sum())}

        mgr = CheckpointManager(CheckpointConfig(str(tmp_path)))
        return TrainLoop(
            step_fn, mgr, save_every=save_every,
            preemption=PreemptionSchedule(fail_at=tuple(fail_at)),
        )

    def test_preemption_recovery_is_bit_exact(self, tmp_path):
        init = {"w": np.zeros((8, 8))}
        ref = self._loop(tmp_path / "a").run(dict(init), 20)
        out = self._loop(tmp_path / "b", fail_at=(3, 11, 17)).run(dict(init), 20)
        assert (ref["w"] == out["w"]).all()

    def test_restart_counter(self, tmp_path):
        loop = self._loop(tmp_path, fail_at=(5,))
        loop.run({"w": np.zeros((4,))}, 10)
        assert loop.restarts == 1

    def test_too_many_preemptions_raises(self, tmp_path):
        loop = self._loop(tmp_path, fail_at=(1,), save_every=100)
        loop.max_restarts = 0
        # failing before any post-init commit and with no restart budget
        with pytest.raises(Preemption):
            loop.run({"w": np.zeros(2)}, 5)

    def test_straggler_monitor(self):
        mon = StragglerMonitor(window=16, threshold=3.0)
        for i in range(16):
            mon.observe(0, 1.0)
        assert not mon.should_skip(16, 0, 1.2)
        assert mon.should_skip(17, 1, 10.0)
        assert mon.skipped == [(17, 1)]


# ---------------------------------------------------------------------------
# gradient compression (§7 quantizer + error feedback)
# ---------------------------------------------------------------------------
class TestGradCompression:
    def test_error_feedback_preserves_signal(self):
        """With EF, the long-run sum of decoded gradients tracks the true
        sum (quantizer is contractive + bias correction)."""
        cfg = GradCompressionConfig(bits=4)
        rng = np.random.default_rng(0)
        g_true = [
            {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
            for _ in range(50)
        ]
        ef = init_error_feedback(g_true[0])
        total_dec = jnp.zeros(32)
        total_true = jnp.zeros(32)
        for g in g_true:
            dec, ef = compress_gradients(cfg, g, ef)
            total_dec += dec["w"]
            total_true += g["w"]
        # residual bounded by one quantization step, not growing with T
        resid = jnp.abs(total_dec - total_true).max()
        step_bound = jnp.abs(jnp.stack([g["w"] for g in g_true])).max() / 4
        assert resid < step_bound

    def test_training_with_compression_converges(self):
        cfg = get_config("qwen2.5-3b").smoke()
        cfg = dataclasses.replace(cfg, n_layers=1, dtype="float32")
        opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
        comp = GradCompressionConfig(bits=8)
        data = TokenDataConfig(cfg.vocab_size, 32, 4, seed=0)
        step = jax.jit(
            make_train_step(cfg, opt_cfg, remat=None, grad_comp=comp),
            donate_argnums=(0, 1),
        )
        state = build_state(cfg, opt_cfg, 0, comp)
        losses = []
        for i in range(30):
            batch = {k: jnp.asarray(v) for k, v in synth_batch(data, i).items()}
            p, o, m = step(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o}
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
class TestData:
    def test_deterministic_in_seed_step(self):
        cfg = TokenDataConfig(1024, 64, 8, seed=3)
        a = synth_batch(cfg, 7)
        b = synth_batch(cfg, 7)
        assert (a["tokens"] == b["tokens"]).all()

    def test_host_slicing_partitions_global_batch(self):
        full = TokenDataConfig(1024, 16, 8, seed=1, n_hosts=1, host_id=0)
        parts = [
            TokenDataConfig(1024, 16, 8, seed=1, n_hosts=2, host_id=h)
            for h in (0, 1)
        ]
        got = [synth_batch(p, 5)["tokens"] for p in parts]
        assert got[0].shape == (4, 16)
        # distinct slices (host streams differ)
        assert not (got[0] == got[1]).all()

    def test_labels_shift(self):
        cfg = TokenDataConfig(512, 32, 2, seed=0)
        b = synth_batch(cfg, 0)
        assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()

"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    ArithmeticCode,
    CompressedForest,
    HuffmanCode,
    compress_forest,
    decompress_forest,
    entropy_bits,
    zaks_decode,
    zaks_encode,
    zaks_is_valid,
)

from conftest import random_forest, random_tree


@st.composite
def freq_tables(draw):
    b = draw(st.integers(2, 40))
    freqs = draw(
        st.lists(st.integers(0, 1000), min_size=b, max_size=b).filter(
            lambda f: sum(1 for x in f if x > 0) >= 2
        )
    )
    return np.array(freqs, dtype=np.int64)


@given(freq_tables(), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_huffman_roundtrip_and_prefix_free(freqs, seed):
    code = HuffmanCode.from_freqs(freqs)
    # prefix-freeness: Kraft sum == 1 for a complete Huffman code
    lens = code.lengths[code.lengths > 0]
    assert abs(sum(2.0 ** -l for l in lens) - 1.0) < 1e-9
    # roundtrip with symbols drawn from the support
    rng = np.random.default_rng(seed)
    support = np.flatnonzero(freqs > 0)
    syms = rng.choice(support, size=100)
    assert np.array_equal(code.decode(code.encode(syms), 100), syms)
    # optimality: average length within 1 bit of entropy
    avg = code.encoded_bits(freqs) / freqs.sum()
    h = entropy_bits(freqs) / freqs.sum()
    assert h - 1e-9 <= avg < h + 1


@given(freq_tables(), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_table_decoder_matches_bitwise(freqs, seed):
    """The table-driven decoder (LUT + canonical fallback + vectorized
    whole-stream path) is symbol- and position-exact vs the bit-at-a-time
    oracle on arbitrary codebooks."""
    from repro.core.bitio import BitReader

    code = HuffmanCode.from_freqs(freqs)
    rng = np.random.default_rng(seed)
    support = np.flatnonzero(freqs > 0)
    syms = rng.choice(support, size=80)
    blob = code.encode(syms)
    assert np.array_equal(code.decode(blob, 80), syms)
    assert np.array_equal(code.decode_bitwise(blob, 80), syms)
    r1, r2 = BitReader(blob), BitReader(blob)
    for _ in range(80):
        assert code.decode_symbol(r1) == code.decode_symbol_bitwise(r2)
        assert r1.pos == r2.pos


@given(freq_tables(), st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_arithmetic_roundtrip(freqs, seed):
    rng = np.random.default_rng(seed)
    support = np.flatnonzero(freqs > 0)
    syms = rng.choice(support, size=64)
    code = ArithmeticCode(freqs)
    assert np.array_equal(code.decode(code.encode(syms), 64), syms)


@given(st.integers(0, 2**32 - 1), st.integers(1, 10))
@settings(max_examples=30, deadline=None)
def test_zaks_roundtrip_random_trees(seed, max_depth):
    rng = np.random.default_rng(seed)
    t = random_tree(rng, d=4, max_depth=max_depth)
    z = zaks_encode(t)
    assert zaks_is_valid(z)
    # condition ii: #0 = #1 + 1
    assert (z == 0).sum() == (z == 1).sum() + 1
    left, right, leaf = zaks_decode(z)
    assert np.array_equal(left, t.children_left)
    assert np.array_equal(right, t.children_right)


@given(
    st.integers(0, 2**32 - 1),
    st.integers(1, 12),
    st.integers(2, 6),
    st.sampled_from(["classification", "regression"]),
)
@settings(max_examples=15, deadline=None)
def test_codec_lossless_invariant(seed, n_trees, max_depth, task):
    """THE paper invariant: decompress(compress(F)) == F for any forest."""
    forest = random_forest(
        seed=seed, n_trees=n_trees, max_depth=max_depth, task=task
    )
    comp = compress_forest(forest)
    back = decompress_forest(CompressedForest.from_bytes(comp.to_bytes()))
    assert forest.equals(back)


@given(
    st.integers(0, 2**32 - 1),
    st.integers(2, 5),
    st.integers(2, 5),
    st.sampled_from(["classification", "regression"]),
)
@settings(max_examples=10, deadline=None)
def test_store_delta_roundtrip_bit_exact(seed, n_users, max_depth, task):
    """THE store invariant (ISSUE 2): for a random fleet, every user's
    delta-encoded forest — serialized and deserialized — reconstructs
    bit-exactly against the shared codebook, fit-value tables included."""
    from repro.store import (
        UserDelta,
        build_shared_codebook,
        encode_user_delta,
        reconstruct_user,
    )

    fleet = [
        random_forest(
            seed=seed + u, n_trees=3 + (seed + u) % 4, max_depth=max_depth,
            task=task, n_fit_values=12,
        )
        for u in range(n_users)
    ]
    shared = build_shared_codebook(fleet, seed=seed % 7)
    for forest in fleet:
        delta = encode_user_delta(forest, shared, seed=seed % 5)
        rt = UserDelta.from_bytes(delta.to_bytes())
        assert reconstruct_user(rt, shared).equals(forest)


@st.composite
def segmented_batches(draw):
    """Random ragged multi-tenant batch: random heap depth, random per-user
    tree counts, random (unsorted) segment maps on both axes."""
    depth = draw(st.integers(1, 6))
    d = draw(st.integers(2, 6))
    n_bins = draw(st.integers(2, 16))
    n_segs = draw(st.integers(1, 5))
    tree_counts = draw(
        st.lists(st.integers(0, 6), min_size=n_segs, max_size=n_segs)
    )
    n = draw(st.integers(1, 40))
    seed = draw(st.integers(0, 2**32 - 1))
    return depth, d, n_bins, tree_counts, n, seed


@given(segmented_batches(), st.sampled_from([0, 3]))
@settings(max_examples=15, deadline=None)
def test_segmented_kernel_engines_match_reference(batch, n_classes):
    """ISSUE 3 invariant: the pipelined DMA engine and the simple oracle
    both match the pure-jnp segmented reference on random segment maps,
    ragged per-user tree counts, and random heap depths (classification
    vote counts integer-exact; regression sums to f32 tolerance)."""
    import jax.numpy as jnp

    from repro.kernels.tree_predict.ref import (
        forest_predict_agg_segmented_reference,
    )
    from repro.kernels.tree_predict.tree_predict import (
        forest_predict_agg_segmented,
    )

    depth, d, n_bins, tree_counts, n, seed = batch
    rng = np.random.default_rng(seed)
    t = sum(tree_counts)
    if t == 0:
        return  # no trees: serving driver never launches the kernel
    h = (1 << (depth + 1)) - 1
    feature = rng.integers(0, d, (t, h)).astype(np.int32)
    threshold = rng.integers(0, n_bins, (t, h)).astype(np.int32)
    inter = rng.random((t, h)) < 0.6
    inter[:, (h - 1) // 2 :] = False  # bottom level must be leaves
    xb = rng.integers(0, n_bins, (n, d)).astype(np.int32)
    tseg = rng.permutation(
        np.repeat(np.arange(len(tree_counts)), tree_counts)
    ).astype(np.int32)
    oseg = rng.integers(0, len(tree_counts), n).astype(np.int32)
    if n_classes > 0:
        fit = rng.integers(0, n_classes, (t, h)).astype(np.float32)
    else:
        fit = rng.normal(size=(t, h)).astype(np.float32)
    ref = np.asarray(
        forest_predict_agg_segmented_reference(
            jnp.asarray(xb), jnp.asarray(oseg), jnp.asarray(tseg),
            jnp.asarray(feature), jnp.asarray(threshold),
            jnp.asarray(fit), jnp.asarray(inter), depth,
            n_classes=n_classes,
        )
    )
    for engine in ("simple", "pipelined"):
        got = np.asarray(
            forest_predict_agg_segmented(
                xb, oseg, tseg, feature, threshold, fit, inter,
                max_depth=depth, n_classes=n_classes,
                block_trees=4, block_obs=16, engine=engine,
            )
        )
        if n_classes > 0:
            assert np.array_equal(got, ref), engine
        else:
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ISSUE 6: single-bit corruption of any serialized frame either
# reconstructs bit-exactly or raises a typed integrity error — never a
# silently wrong artifact
# ---------------------------------------------------------------------------

def _corruption_frames():
    """One small instance of each top-level frame (RFS1/RFD1/RFT1/RFM1),
    built once and cached: (frame bytes, parser)."""
    from repro.store import build_store
    from repro.store.codebook import SharedCodebook
    from repro.store.delta import UserDelta
    from repro.store.fleet import make_synthetic_fleet
    from repro.store.lifecycle import RemapTable
    from repro.store.runtime import ForestStore

    store = build_store(make_synthetic_fleet(n_users=2, d=5, n_bins=12,
                                             seed=23))
    remap = RemapTable(
        old_generation=1, new_generation=2,
        vars_map=np.arange(3, dtype=np.int32),
        splits_map={1: np.arange(2, dtype=np.int32)},
        fits_map=np.arange(2, dtype=np.int32),
    )
    return {
        "RFS1": (store.shared.to_bytes(), SharedCodebook.from_bytes),
        "RFD1": (
            store.delta(store.user_ids[0]).to_bytes(), UserDelta.from_bytes
        ),
        "RFT1": (store.to_bytes(), ForestStore.from_bytes),
        "RFM1": (remap.to_bytes(), RemapTable.from_bytes),
    }


_FRAME_CACHE: dict = {}


@given(st.sampled_from(["RFS1", "RFD1", "RFT1", "RFM1"]), st.data())
@settings(max_examples=120, deadline=None)
def test_single_bit_corruption_never_silently_wrong(frame, data):
    from repro.core.framing import FramingError
    from repro.runtime.chaos import flip_bit

    if not _FRAME_CACHE:
        _FRAME_CACHE.update(_corruption_frames())
    blob, parse = _FRAME_CACHE[frame]
    bit = data.draw(st.integers(0, 8 * len(blob) - 1), label="bit")
    corrupted = flip_bit(blob, bit)
    try:
        reparsed = parse(corrupted)
    except FramingError:
        return  # typed rejection: the acceptable outcome
    # parse survived (the flip landed in the CRC trailer magic, making
    # the frame read as CRC-less with an intact payload): the decoded
    # artifact must then be BIT-EXACT
    assert reparsed.to_bytes() == blob, (frame, bit)


# ---------------------------------------------------------------------------
# ISSUE 8: repair semantics of the durable shard store — any SINGLE
# corrupted-or-deleted shard in a slab group scrubs back to a bit-exact
# fleet; any DOUBLE fault in one group is a typed UnrepairableError and
# the silent-wrong count stays 0
# ---------------------------------------------------------------------------

_DURABLE_TEMPLATE: dict = {}


def _durable_template():
    """One small durable fleet on disk (one slab group: 1 codebook + 6
    delta shards + parity), built once; examples copy it fresh."""
    import tempfile

    from repro.store import DurableStore, build_store
    from repro.store.fleet import make_synthetic_fleet

    store = build_store(make_synthetic_fleet(
        n_users=6, d=5, n_bins=12, seed=29, n_trees=(3, 5), max_depth=3,
    ))
    root = tempfile.mkdtemp(prefix="durable_prop_")
    path = f"{root}/fleet"
    durable = DurableStore.create(path, store, slab_shards=8)
    shard_ids = sorted(e.shard_id for _, e in durable.manifest.live_entries())
    ref = {e.shard_id: durable.read_shard(e.shard_id)
           for _, e in durable.manifest.live_entries()}
    users = {e.shard_id: e.name for _, e in durable.manifest.live_entries()
             if e.name}
    return {"path": path, "shard_ids": shard_ids, "ref": ref,
            "users": users}


def _inject_shard_fault(durable, shard_id, fault, seed):
    """Corrupt or delete ONE shard's bytes inside its slab file."""
    from repro.runtime.chaos import DiskFaults

    path, off, length = durable.shard_location(shard_id)
    faults = DiskFaults(seed=seed)
    if fault == "zero":
        faults.corrupt_region(path, off, length)       # "deleted" shard
    elif fault == "rot":
        with open(path, "rb") as f:
            blob = f.read()
        bit = 8 * off + seed % max(8 * length, 1)      # flip inside the shard
        from repro.runtime.chaos import flip_bit
        with open(path, "wb") as f:
            f.write(flip_bit(blob, bit))
    else:  # "truncate": tear the slab inside this shard — only valid for
        # the LAST shard of the slab (else siblings are damaged too)
        faults.torn_write(path, off + seed % max(length, 1))


@given(st.data())
@settings(max_examples=12, deadline=None)
def test_durable_single_fault_repairs_double_fault_typed(data):
    import shutil
    import tempfile

    from repro.core.framing import IntegrityError, UnrepairableError
    from repro.store import DurableStore, Scrubber

    if not _DURABLE_TEMPLATE:
        _DURABLE_TEMPLATE.update(_durable_template())
    tpl = _DURABLE_TEMPLATE
    work = tempfile.mkdtemp(prefix="durable_case_")
    try:
        base = f"{work}/fleet"
        shutil.copytree(tpl["path"], base)
        durable = DurableStore.open(base)
        seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
        victim = data.draw(st.sampled_from(tpl["shard_ids"]), label="victim")
        last = max(
            tpl["shard_ids"],
            key=lambda s: durable.shard_location(s)[1],
        )
        fault = data.draw(
            st.sampled_from(
                ["zero", "rot", "truncate"] if victim == last
                else ["zero", "rot"]
            ),
            label="fault",
        )
        double = data.draw(st.booleans(), label="double")
        _inject_shard_fault(durable, victim, fault, seed)
        if double:
            second = data.draw(
                st.sampled_from([s for s in tpl["shard_ids"] if s != victim]),
                label="second",
            )
            # the second fault must not also hit the first victim's bytes,
            # so zero exactly that shard's region
            _inject_shard_fault(durable, second, "zero", seed)

        out = Scrubber(durable).scrub_all()
        if not double:
            # single fault: scrub repairs, reload is bit-exact vs the
            # pre-fault fleet (parity + every sibling byte recovered)
            assert out["unrepairable"] == 0, out
            for sid, want in tpl["ref"].items():
                assert durable.read_shard(sid) == want, sid
            loaded = durable.load_store(lazy=False)
            assert set(loaded.user_ids) == set(tpl["users"].values())
        else:
            # double fault in one group: typed UnrepairableError from the
            # repair path...
            with pytest.raises(UnrepairableError):
                durable.read_shard(victim, repair=True)
            assert out["unrepairable"] >= 1, out
            # ...and ZERO silent wrongs anywhere: every shard read either
            # returns the pre-fault bytes or raises a typed error
            silent_wrong = 0
            for sid, want in tpl["ref"].items():
                try:
                    got = durable.read_shard(sid)
                except IntegrityError:
                    continue
                if got != want:
                    silent_wrong += 1
            assert silent_wrong == 0
    finally:
        shutil.rmtree(work, ignore_errors=True)


# ---------------------------------------------------------------------------
# residency (ISSUE 10): budget invariant + bit-exactness under arbitrary
# serve / demote / prefetch / re-register interleavings
# ---------------------------------------------------------------------------

_RESIDENCY_TEMPLATE: dict = {}


def _residency_template():
    """One streaming-built durable fleet on disk plus a per-user oracle
    (predictions + serialized delta bytes), built once; every example
    copies the directory fresh."""
    import tempfile

    from repro.store import DurableStore, build_store_streaming
    from repro.store.fleet import make_synthetic_fleet

    fleet = make_synthetic_fleet(
        n_users=8, d=5, n_bins=12, seed=31, n_trees=(3, 5), max_depth=3,
    )
    root = tempfile.mkdtemp(prefix="residency_prop_")
    path = f"{root}/fleet"
    durable = build_store_streaming(
        fleet, path, wave_users=3, k_max=4, seed=0, slab_shards=8,
    )
    ref = durable.load_store(lazy=False)
    users = sorted(ref.user_ids)
    rng = np.random.default_rng(7)
    x = rng.integers(
        0, int(ref.shared.n_bins_per_feature[0]),
        (6, ref.shared.n_features),
    ).astype(np.int32)
    oracle = {u: ref.predict(u, x) for u in users}
    delta_bytes = {u: ref._deltas[u].to_bytes() for u in users}
    sizes = {u: len(b) for u, b in delta_bytes.items()}
    return {"path": path, "users": users, "x": x, "oracle": oracle,
            "delta_bytes": delta_bytes, "sizes": sizes}


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_residency_interleavings_bit_exact_within_budget(data):
    import shutil
    import tempfile

    from repro.store import DurableStore, Prefetcher, attach_residency
    from repro.store.delta import UserDelta

    if not _RESIDENCY_TEMPLATE:
        _RESIDENCY_TEMPLATE.update(_residency_template())
    tpl = _RESIDENCY_TEMPLATE
    users, x, oracle = tpl["users"], tpl["x"], tpl["oracle"]
    total = sum(tpl["sizes"].values())
    work = tempfile.mkdtemp(prefix="residency_case_")
    try:
        base = f"{work}/fleet"
        shutil.copytree(tpl["path"], base)
        durable = DurableStore.open(base)
        store = durable.load_store(lazy=True)
        budget = data.draw(
            st.integers(min(tpl["sizes"].values()), total), label="budget"
        )
        mgr = attach_residency(store, durable, budget_bytes=budget)
        pf = Prefetcher(mgr, background=False)  # deterministic inline warm
        ops = data.draw(
            st.lists(
                st.tuples(
                    st.sampled_from(
                        ["serve", "demote", "prefetch", "reregister"]
                    ),
                    st.sampled_from(users),
                ),
                min_size=1, max_size=30,
            ),
            label="ops",
        )
        for op, u in ops:
            if op == "serve":
                assert np.array_equal(store.predict(u, x), oracle[u]), u
            elif op == "demote":
                mgr.demote(u)  # may refuse (placeholder/dirty) — fine
            elif op == "prefetch":
                pf.request([u])
                mgr.absorb_staged()  # serve-thread absorption point
            else:  # re-register the SAME model (user_version bump):
                # marks the user dirty, so a later demote must write back
                store.add_delta(
                    u, UserDelta.from_bytes(tpl["delta_bytes"][u])
                )
            # THE invariant: outside a pinned serve, accounted resident
            # bytes never exceed the budget, whatever the interleaving
            assert mgr.accounted_bytes() <= budget, (op, u)
        # every user still serves bit-exactly afterwards
        for u in users:
            assert np.array_equal(store.predict(u, x), oracle[u]), u
            assert mgr.accounted_bytes() <= budget
        st_ = mgr.stats()
        assert st_["resident_bytes"] <= budget
        assert st_["over_budget_events"] == 0
        pf.close()
    finally:
        shutil.rmtree(work, ignore_errors=True)

"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
ref.py pure-jnp oracle (kernels execute with interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import (
    flash_attention,
    flash_attention_reference,
)
from repro.kernels.quantize.ops import dequantize_tensor, quantize_tensor
from repro.kernels.quantize.ref import quantize_reference
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_reference
from repro.kernels.tree_predict.ref import forest_predict_reference
from repro.kernels.tree_predict.tree_predict import forest_predict


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,s,h,kv,hd",
        [(2, 256, 4, 2, 64), (1, 128, 8, 8, 128), (2, 100, 4, 1, 32),
         (1, 384, 2, 2, 64)],
    )
    @pytest.mark.parametrize("window", [None, 64])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_reference(self, b, s, h, kv, hd, window, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
        k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
        v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
        got = flash_attention(q, k, v, causal=True, window=window)
        ref = flash_attention_reference(q, k, v, causal=True, window=window)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol,
        )

    def test_first_row_attends_only_to_itself(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64))
        k = jax.random.normal(ks[1], (1, 128, 2, 64))
        v = jax.random.normal(ks[2], (1, 128, 2, 64))
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out[0, 0]), np.asarray(v[0, 0]), atol=1e-5
        )


class TestWKV6:
    @pytest.mark.parametrize(
        "b,s,h,hd,chunk",
        [(2, 128, 2, 32, 32), (1, 96, 4, 64, 32), (1, 64, 1, 16, 16),
         (2, 70, 2, 32, 32)],  # non-multiple of chunk -> padded path
    )
    def test_matches_reference(self, b, s, h, hd, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        r = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))
        u = jax.random.normal(ks[4], (h, hd)) * 0.1
        s0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
        y, sf = wkv6(r, k, v, w, u, s0, chunk=chunk)
        fold = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        uf = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, hd)
        yr, sr = wkv6_reference(
            fold(r), fold(k), fold(v), fold(w), uf, s0.reshape(b * h, hd, hd)
        )
        np.testing.assert_allclose(
            np.asarray(y),
            np.asarray(yr.reshape(b, h, s, hd).transpose(0, 2, 1, 3)),
            atol=1e-4, rtol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(sf.reshape(b * h, hd, hd)), np.asarray(sr),
            atol=1e-4, rtol=1e-4,
        )

    def test_state_threading_across_chunks(self):
        """Running one 128-seq call must equal two chained 64-seq calls."""
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        b, s, h, hd = 1, 128, 2, 32
        r = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, hd)))
        u = jax.random.normal(ks[4], (h, hd)) * 0.1
        s0 = jnp.zeros((b, h, hd, hd))
        y_full, s_full = wkv6(r, k, v, w, u, s0, chunk=32)
        y1, s1 = wkv6(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u, s0, chunk=32)
        y2, s2 = wkv6(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u, s1, chunk=32)
        np.testing.assert_allclose(
            np.asarray(y_full), np.asarray(jnp.concatenate([y1, y2], 1)),
            atol=1e-4, rtol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-4)


class TestTreePredict:
    @pytest.mark.parametrize("t,n,d,depth", [(8, 300, 6, 5), (3, 64, 4, 3),
                                             (16, 100, 10, 6)])
    def test_matches_reference(self, t, n, d, depth, rng):
        h = (1 << (depth + 1)) - 1
        feature = rng.integers(0, d, (t, h)).astype(np.int32)
        threshold = rng.integers(0, 16, (t, h)).astype(np.int32)
        fit = rng.normal(size=(t, h)).astype(np.float32)
        # random internal pattern, consistent heap (children exist in array)
        is_internal = rng.random((t, h)) < 0.6
        is_internal[:, (h - 1) // 2 :] = False  # last level = leaves
        xb = rng.integers(0, 16, (n, d)).astype(np.int32)
        got = forest_predict(
            jnp.asarray(xb), jnp.asarray(feature), jnp.asarray(threshold),
            jnp.asarray(fit), jnp.asarray(is_internal), max_depth=depth,
        )
        ref = forest_predict_reference(
            jnp.asarray(xb), jnp.asarray(feature), jnp.asarray(threshold),
            jnp.asarray(fit), jnp.asarray(is_internal), depth,
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_end_to_end_vs_forest_predict(self):
        from repro.data.tabular import TabularSpec, make_dataset
        from repro.forest import fit_binner, predict_forest, train_forest
        from repro.kernels.tree_predict.ops import predict_forest_kernel

        spec = TabularSpec("t", 400, 5, "classification", 2, 1)
        x, y, cat = make_dataset(spec, seed=1)
        binner = fit_binner(x, n_bins=16, categorical=cat)
        model = train_forest(
            x, y, binner, n_trees=6, max_depth=5, task="classification",
            n_classes=2, seed=0, chunk=6,
        )
        np.testing.assert_array_equal(
            predict_forest_kernel(model, x[:200]),
            predict_forest(model, x[:200]),
        )


class TestQuantize:
    @pytest.mark.parametrize("shape", [(1000,), (64, 100), (3, 7, 11)])
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_matches_reference(self, shape, bits):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3
        q, recon, (lo, step) = quantize_tensor(x, bits)
        qr, _ = quantize_reference(x, lo, step, 1 << bits)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        # §7 guarantee: |recon - x| <= step/2 (up to fp rounding)
        assert float(jnp.abs(recon - x).max()) <= step / 2 + 1e-4
        np.testing.assert_allclose(
            np.asarray(dequantize_tensor(q, lo, step)), np.asarray(recon),
            atol=1e-5,
        )

    def test_dither_changes_codes_but_bounded_error(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (512,))
        q0, _, (lo, step) = quantize_tensor(x, 6, dither=False)
        q1, recon1, _ = quantize_tensor(x, 6, dither=True, seed=7)
        assert not np.array_equal(np.asarray(q0), np.asarray(q1))
        assert float(jnp.abs(recon1 - x).max()) <= step + 1e-4

    def test_distortion_scales_as_2_pow_minus_b(self):
        """§7: quantization distortion variance ~ step^2/12 ~ 4^-b."""
        x = jax.random.uniform(jax.random.PRNGKey(2), (20000,))
        errs = []
        for bits in (4, 6, 8):
            _, recon, _ = quantize_tensor(x, bits)
            errs.append(float(jnp.mean((recon - x) ** 2)))
        assert errs[0] / errs[1] == pytest.approx(16, rel=0.2)
        assert errs[1] / errs[2] == pytest.approx(16, rel=0.2)

"""System tests: Algorithm 1 end-to-end (lossless), prediction from the
compressed format (§5), clustering behaviour (§3.2), lossy scheme (§7)."""
import numpy as np
import pytest

from repro.core import (
    CompressedForest,
    compress_forest,
    decompress_forest,
    entropy_bits,
    iter_trees,
    predict_compressed,
    quantize_fits,
    subsample_trees,
)
from repro.core.bregman import cluster_models, kl_kmeans

from conftest import random_forest


class TestLossless:
    @pytest.mark.parametrize(
        "task,n_classes", [("classification", 2), ("classification", 5),
                           ("regression", 2)]
    )
    def test_roundtrip(self, task, n_classes):
        forest = random_forest(seed=3, task=task, n_classes=n_classes)
        comp = compress_forest(forest)
        back = decompress_forest(CompressedForest.from_bytes(comp.to_bytes()))
        assert forest.equals(back)

    def test_roundtrip_deep_narrow(self):
        forest = random_forest(seed=7, n_trees=5, d=2, max_depth=14, n_bins=4)
        comp = compress_forest(forest)
        assert decompress_forest(
            CompressedForest.from_bytes(comp.to_bytes())
        ).equals(forest)

    def test_single_leaf_trees(self):
        forest = random_forest(seed=1, n_trees=4, max_depth=0)
        comp = compress_forest(forest)
        assert decompress_forest(
            CompressedForest.from_bytes(comp.to_bytes())
        ).equals(forest)

    def test_size_report_buckets_sum(self):
        forest = random_forest(seed=5)
        rep = compress_forest(forest).size_report()
        assert rep["total"] == (
            rep["structure"] + rep["var_names"] + rep["split_values"]
            + rep["fits"] + rep["dictionaries"]
        )
        # serialization framing overhead should be small
        assert rep["total_serialized"] < rep["total"] * 1.4 + 256


class TestCompressedPrediction:
    def test_identical_predictions(self, rng):
        forest = random_forest(seed=11, n_trees=15)
        comp = compress_forest(forest)
        x = rng.integers(0, 16, size=(64, 5))
        got = predict_compressed(comp, x)
        votes = np.zeros((64, 2), np.int64)
        for t in forest.trees:
            for i in range(64):
                votes[i, int(t.predict_one(x[i]))] += 1
        assert np.array_equal(got, votes.argmax(1))

    def test_streaming_trees_equal_original(self):
        forest = random_forest(seed=13, n_trees=8)
        comp = compress_forest(forest)
        for orig, streamed in zip(forest.trees, iter_trees(comp)):
            assert orig.equals(streamed)


class TestClustering:
    def test_identical_models_collapse_to_one_cluster(self):
        base = np.array([50, 30, 15, 5], float)
        counts = np.tile(base, (10, 1))
        res = cluster_models(counts, alpha_bits=20.0, k_max=6)
        assert res.k == 1
        assert res.coding_loss_bits < 1e-6

    def test_distinct_models_separate_when_alpha_small(self):
        a = np.array([1000, 1, 1, 1], float)
        b = np.array([1, 1, 1, 1000], float)
        counts = np.stack([a, a, a, b, b, b])
        res = cluster_models(counts, alpha_bits=1.0, k_max=4)
        assert res.k >= 2
        g1 = set(res.assignments[:3])
        g2 = set(res.assignments[3:])
        assert g1.isdisjoint(g2)

    def test_large_alpha_forces_few_clusters(self):
        """Paper §6: 64-bit dictionary lines (large alpha) => 2-3 clusters;
        cheaper lines => more clusters."""
        rng = np.random.default_rng(0)
        counts = rng.integers(1, 60, size=(30, 8)).astype(float) * 10
        k_cheap = cluster_models(counts, alpha_bits=2.0, k_max=12).k
        k_costly = cluster_models(counts, alpha_bits=5000.0, k_max=12).k
        assert k_costly <= k_cheap
        assert k_costly <= 3

    def test_centroid_is_weighted_mean(self):
        counts = np.array([[80, 20], [20, 80]], float)
        _, cent, _ = kl_kmeans(counts, k=1)
        assert np.allclose(cent[0], [0.5, 0.5], atol=1e-6)

    def test_objective_beats_no_clustering_and_single_model(self):
        """Eq. (6) at the chosen K is <= both extremes (K=1, K=M)."""
        rng = np.random.default_rng(1)
        half1 = rng.multinomial(500, [0.7, 0.1, 0.1, 0.1], size=8).astype(float)
        half2 = rng.multinomial(500, [0.1, 0.1, 0.1, 0.7], size=8).astype(float)
        counts = np.vstack([half1, half2])
        alpha = 30.0
        res = cluster_models(counts, alpha_bits=alpha, k_max=16)
        # K = M extreme
        loss_m = 0.0
        dict_m = alpha * sum((c > 0).sum() for c in counts)
        # K = 1 extreme
        _, _, loss_1 = kl_kmeans(counts, 1)
        dict_1 = alpha * ((counts.sum(0) > 0).sum())
        assert res.objective_bits <= loss_m + dict_m + 1e-6
        assert res.objective_bits <= loss_1 + dict_1 + 1e-6


class TestLossy:
    def test_subsample(self):
        forest = random_forest(seed=17, n_trees=30, task="regression")
        sub = subsample_trees(forest, 10, seed=0)
        assert sub.n_trees == 10

        def stream_bytes(n):
            # dictionaries are a fixed overhead shared by any |A0| (SS7
            # assumes it away); linear scaling applies to the coded streams
            rep = compress_forest(
                subsample_trees(forest, n, seed=0)
            ).size_report()
            return rep["total"] - rep["dictionaries"]

        # SS7 claims the coded size is linear in |A0| ("linear threads" of
        # Figs 2-3).  Fixed per-stream costs offset the line, so check the
        # affine interpolation: size(20) ~ midpoint of size(10), size(30).
        s10, s20, s30 = stream_bytes(10), stream_bytes(20), stream_bytes(30)
        assert s10 < s20 < s30
        mid = 0.5 * (s10 + s30)
        assert abs(s20 - mid) < 0.15 * mid
        # and the marginal cost per tree is roughly constant
        assert 0.5 < (s30 - s20) / (s20 - s10) < 2.0

    def test_quantization_distortion_bound(self):
        forest = random_forest(seed=19, n_trees=10, task="regression")
        values = forest.fit_values
        span = values.max() - values.min()
        for bits in (4, 6, 8):
            _, max_err = quantize_fits(forest, bits)
            assert max_err <= span / (1 << bits) / 2 + 1e-12

    def test_quantized_forest_compresses_smaller(self):
        forest = random_forest(
            seed=23, n_trees=20, task="regression", n_fit_values=500
        )
        full = compress_forest(forest).size_report()
        q4, _ = quantize_fits(forest, 4)
        small = compress_forest(q4).size_report()
        assert (
            small["fits"] + small["dictionaries"]
            < full["fits"] + full["dictionaries"]
        )

    def test_quantized_still_lossless_roundtrip(self):
        """Lossy = preprocess-then-lossless: the quantized forest itself
        roundtrips exactly."""
        forest = random_forest(seed=29, n_trees=8, task="regression")
        q, _ = quantize_fits(forest, 5)
        comp = compress_forest(q)
        assert decompress_forest(
            CompressedForest.from_bytes(comp.to_bytes())
        ).equals(q)
